"""Async serving front line — handles, streamed progress, failure isolation.

    PYTHONPATH=src python examples/serve_async.py [n_subjects]

Walks the front-line story (DESIGN.md §13) on top of the multi-tenant
service from examples/serve_life.py:

  1. ``submit_async`` returns a :class:`JobHandle` immediately; the
     frontend's background driver thread owns the tick loop and
     micro-batches compatible tenants while the producer keeps submitting,
  2. one handle's per-slice progress events are streamed live,
  3. a poisoned tenant (truncated signal vector) is submitted alongside
     healthy ones: quarantine bisection fails it alone, every batch-mate
     completes, and the captured exception is read off the handle,
  4. a deliberately tiny admission queue shows backpressure: with
     ``backpressure="shed"`` the lowest-priority pending job is evicted
     and its handle resolves as ``shed``.
"""
import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import obs
from repro.core.life import LifeConfig
from repro.data.dmri import synth_cohort
from repro.serve import JobFailedError, LifeFrontend

N_ITERS = 40


def main():
    try:
        n_subjects = int(sys.argv[1]) if len(sys.argv) > 1 else 4
    except ValueError:
        sys.exit(f"usage: {sys.argv[0]} [n_subjects]")

    obs.enable()
    print(f"1. synthesizing {n_subjects}-subject cohort...")
    cohort = synth_cohort(n_subjects, base_seed=0, n_fibers=256, n_theta=64,
                          n_atoms=64, grid=(14, 14, 14))
    cfg = LifeConfig(executor="opt", n_iters=N_ITERS,
                     plan_cache_dir=tempfile.mkdtemp())

    print("2. async submission — handles come back before any solve runs...")
    with LifeFrontend(cfg, slice_iters=10, max_queue=16) as fe:
        handles = {}
        for i, p in enumerate(cohort):
            handles[f"tenant-{i}"] = fe.submit_async(
                p, job_id=f"tenant-{i}", n_iters=N_ITERS,
                priority=5 if i == 1 else 0)
        # a tenant with a truncated signal vector can never solve: the
        # batch build fails, quarantine bisection probes each member solo,
        # and only this one is condemned (DESIGN.md §13.3)
        bad_problem = dataclasses.replace(
            cohort[0], b=np.asarray(cohort[0].b)[:-3])
        bad = fe.submit_async(bad_problem, job_id="poisoned",
                              n_iters=N_ITERS)

        print("3. streaming tenant-0's per-slice progress...")
        for ev in handles["tenant-0"].events():
            if ev["type"] == "progress":
                print(f"   tenant-0: {ev['done']}/{ev['n_iters']} iters, "
                      f"loss {ev['loss']:.5f}")
            else:
                print(f"   tenant-0: terminal event {ev['type']!r}")

        print("4. collecting results — healthy tenants all complete...")
        for jid, h in sorted(handles.items()):
            w, losses = h.result(timeout=600)
            print(f"   {jid}: status {h.status()!r}, "
                  f"final loss {losses[-1]:.5f}, "
                  f"{int((np.asarray(w) > 1e-6).sum())} fibers kept")

        err = bad.exception(timeout=600)
        assert isinstance(err, JobFailedError)
        print(f"   poisoned: status {bad.status()!r} — "
              f"{type(err.error).__name__} captured on the handle, "
              f"nobody else was harmed")

    admitted = obs.value("serve.jobs.admitted")
    completed = obs.value("serve.jobs.completed")
    failed = obs.value("serve.jobs.failed")
    print(f"   counters: admitted={admitted:g} completed={completed:g} "
          f"failed={failed:g}")

    print("5. backpressure='shed' on a one-slot queue...")
    with LifeFrontend(cfg, slice_iters=10, max_queue=1,
                      backpressure="shed", start=False) as fe:
        lo = fe.submit_async(cohort[0], job_id="lo", n_iters=4, priority=0)
        hi = fe.submit_async(cohort[1], job_id="hi", n_iters=4, priority=5)
        fe.start()
        hi.result(timeout=600)
        print(f"   lo: status {lo.status()!r} (evicted by the higher-"
              f"priority arrival); hi: status {hi.status()!r}")

    print("done.")


if __name__ == "__main__":
    main()
