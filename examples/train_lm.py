"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --steps 200

Uses the full framework path — config system, deterministic sharded data
pipeline, AdamW with warmup+cosine, checkpointing with resume — on a ~100M
llama-style config derived from the deepseek-7b family.
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.checkpoint import manager as CK
from repro.configs.base import ArchConfig
from repro.data.tokens import DataConfig, synth_batch_for
from repro.launch import steps as ST
from repro.optim.adamw import OptConfig

CONFIG_100M = ArchConfig(
    name="llama-100m", family="dense",
    n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=32000, dtype="float32", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--small", action="store_true",
                    help="~10M variant: a few hundred steps complete in "
                         "minutes on one CPU core (same code path)")
    args = ap.parse_args()

    cfg = CONFIG_100M
    if args.small:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, name="llama-10m", n_layers=4, d_model=256,
                          n_heads=4, n_kv_heads=4, d_ff=1024,
                          vocab_size=8000)
    n_params = cfg.param_count()
    print(f"model: {cfg.name} ({n_params/1e6:.0f}M params)")
    opt = OptConfig(lr=3e-4, warmup_steps=20, decay_steps=args.steps,
                    weight_decay=0.01)
    data = DataConfig(seed=0, seq_len=args.seq_len, global_batch=args.batch)

    params, opt_state = ST.init_all(cfg, opt, jax.random.PRNGKey(0))
    start = 0
    if CK.latest_step(args.ckpt_dir) is not None:
        start, flat, _ = CK.restore(args.ckpt_dir)
        tree = CK.unflatten_like(
            jax.eval_shape(lambda: {"p": params, "o": opt_state}), flat)
        params, opt_state = (jax.tree.map(jax.numpy.asarray, tree["p"]),
                             jax.tree.map(jax.numpy.asarray, tree["o"]))
        print(f"resumed from step {start}")

    step_fn = jax.jit(ST.make_train_step(cfg, opt))
    losses = []
    t_start = time.time()
    for step in range(start, args.steps):
        batch = synth_batch_for(cfg, data, step)
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
        if step % 20 == 0 or step == args.steps - 1:
            tput = data.global_batch * data.seq_len / max(
                (time.time() - t_start) / max(len(losses), 1), 1e-9)
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"({tput:,.0f} tok/s)", flush=True)
        if (step + 1) % 100 == 0:
            CK.save(args.ckpt_dir, step + 1, {"p": params, "o": opt_state})
    CK.save(args.ckpt_dir, args.steps, {"p": params, "o": opt_state})
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"checkpoints in {args.ckpt_dir}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
