"""Connectome pruning end to end — solve, prune, virtual-lesion (§15).

    PYTHONPATH=src python examples/prune_connectome.py [n_fibers]

The science story the stack exists for (DESIGN.md §15):

  1. solve one subject to convergence (iteration count decided by the
     loss, not a fixed budget),
  2. prune: extract the surviving support and compact Phi onto it,
  3. cross-validate: held-out RMSE over disjoint voxel folds vs the
     null model,
  4. virtual-lesion a spatially coherent bundle: re-solve warm-started
     from the converged weights (lesioned entries zeroed) and print the
     evidence table — the warm re-solve takes a fraction of the cold
     iteration count.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.life import LifeConfig, LifeEngine
from repro.data.dmri import fiber_bundles, synth_connectome
from repro.science import (crossval_rmse, prune_connectome,
                           solve_to_convergence, virtual_lesion,
                           weight_summary)


def main():
    try:
        n_fibers = int(sys.argv[1]) if len(sys.argv) > 1 else 192
    except ValueError:
        sys.exit(f"usage: {sys.argv[0]} [n_fibers]")

    print(f"1. synthesizing a {n_fibers}-fiber candidate connectome...")
    problem = synth_connectome(n_fibers=n_fibers, n_theta=32, n_atoms=48,
                               grid=(12, 12, 12), seed=7, noise=0.02)
    cfg = LifeConfig(executor="opt", plan_cache_dir=tempfile.mkdtemp())

    print("2. solving to convergence...")
    solve = solve_to_convergence(LifeEngine(problem, cfg), rtol=1e-5,
                                 chunk=8, max_iters=400)
    print(f"   {solve.iters} iterations, final loss "
          f"{solve.losses[-1]:.5f} (converged={solve.converged})")

    print("3. pruning...")
    pruned = prune_connectome(problem, solve.w, threshold=1e-3)
    print(f"   {pruned.describe()}")
    s = weight_summary(solve.w, threshold=1e-3)
    print(f"   surviving weights: min {s['w_min']:.4f} / median "
          f"{s['w_median']:.4f} / max {s['w_max']:.4f}")

    print("4. 3-fold cross-validated RMSE...")
    cv = crossval_rmse(problem, cfg, k=3, n_iters=40)
    print(f"   {cv.describe()}")

    print("5. virtual lesion with warm-started re-solve...")
    bundle = fiber_bundles(problem, bundle_size=8, seed=1)[0]
    report = virtual_lesion(problem, bundle, cfg, w_full=solve.w,
                            rtol=1e-5, chunk=8, max_iters=400)
    for line in report.describe().splitlines():
        print(f"   {line}")
    assert np.all(report.w_lesioned[bundle] == 0.0)
    print(f"   warm re-solve used {report.iters_warm} iterations vs "
          f"{solve.iters} for the cold full solve")

    print("done.")


if __name__ == "__main__":
    main()
