"""Quickstart: prune a synthetic connectome with LiFE in ~30 seconds.

    PYTHONPATH=src python examples/quickstart.py

Walks the whole paper pipeline: synthetic dMRI/tractography -> STD encoding
(Phi tensor + dictionary) -> runtime-autotuned restructuring -> SBBNNLS with
weight compaction -> pruned connectome vs ground truth.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.life import LifeConfig, LifeEngine
from repro.data.dmri import synth_connectome


def main():
    print("1. synthesizing connectome (PROB tractography, 512 fibers)...")
    problem = synth_connectome(n_fibers=512, n_theta=96, n_atoms=96,
                               grid=(16, 16, 16), algorithm="PROB", seed=0)
    print(f"   Phi: {problem.phi.n_coeffs} coefficients, "
          f"{problem.stats['phi_mbytes']:.1f} MB, "
          f"{problem.stats['nnz_per_fiber']:.1f} nnz/fiber")

    print("2. building engine (runtime-autotuned restructuring)...")
    eng = LifeEngine(problem, LifeConfig(executor="auto", n_iters=100,
                                         compact_every=25))
    print(f"   DSC plan: {eng.dsc_plan.describe()}")
    print(f"   WC  plan: {eng.wc_plan.describe()}")

    print("3. running SBBNNLS...")
    w, losses = eng.run()
    print(f"   loss {losses[0]:.3f} -> {losses[-1]:.5f} "
          f"({len(losses)} iterations)")
    print(f"   inspector overhead: {eng.inspector_seconds:.2f}s "
          f"(amortized across iterations, paper §4.1.2)")

    stats = eng.prune_stats(w)
    print(f"4. pruned connectome: kept {int(stats['kept'])}/"
          f"{int(stats['total'])} fibers | precision "
          f"{stats['precision']:.2f} recall {stats['recall']:.2f}")
    w_np = np.asarray(w)
    print(f"   w sparsity: {(w_np == 0).mean():.1%} zeros "
          f"(drives the compaction win)")


if __name__ == "__main__":
    main()
