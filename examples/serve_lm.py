"""Serve a small model with batched requests (prefill + greedy decode).

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T

SERVE_CFG = ArchConfig(
    name="serve-demo-60m", family="dense",
    n_layers=6, d_model=384, n_heads=6, n_kv_heads=2,   # GQA
    d_ff=1536, vocab_size=32000, dtype="float32", remat=False)


def main():
    cfg = SERVE_CFG
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.param_count()/1e6:.0f}M params, GQA "
          f"{cfg.n_heads}/{cfg.n_kv_heads}")
    rng = np.random.default_rng(0)
    B, S_pre, gen = 8, 64, 32
    s_max = S_pre + gen
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_pre)),
                          jnp.int32)

    prefill = jax.jit(lambda p, b: T.prefill(cfg, p, b))
    decode = jax.jit(lambda p, b: T.decode_step(cfg, p, b))

    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": prompts})
    for kn in ("k", "v"):
        kv = cache[kn]
        cache[kn] = jnp.pad(kv, ((0, 0), (0, 0), (0, s_max - kv.shape[2]),
                                 (0, 0), (0, 0)))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    jax.block_until_ready(tok)
    print(f"prefill {B}x{S_pre}: {(time.perf_counter()-t0)*1e3:.0f}ms "
          f"(includes compile)")

    idx = jnp.asarray(S_pre, jnp.int32)
    generated = [np.asarray(tok)]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        logits, cache = decode(params, dict(tokens=tok, cache=cache,
                                            cache_index=idx))
        cache.pop("index")
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        generated.append(np.asarray(tok))
        idx = idx + 1
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    out = np.concatenate(generated, axis=1)
    print(f"decode {gen} tokens x {B} requests: {dt*1e3:.0f}ms "
          f"-> {B*gen/dt:,.0f} tok/s")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
