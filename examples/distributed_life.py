"""Distributed LiFE: the paper's workload on a 2-D device mesh.

    PYTHONPATH=src python examples/distributed_life.py

Runs the 2-D (voxel x fiber) shard_map partition of SBBNNLS on 8 placeholder
host devices — the same code path the 512-chip dry-run lowers — and checks it
against the single-device engine.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.life import LifeConfig, LifeEngine
from repro.data.dmri import synth_connectome
from repro.distributed import life_shard as LS


def main():
    problem = synth_connectome(n_fibers=512, n_theta=96, n_atoms=96,
                               grid=(16, 16, 16), algorithm="PROB", seed=0)
    R, C = 4, 2
    mesh = compat.make_mesh((R, C), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {mesh.devices.size} devices")

    t0 = time.time()
    shards = LS.build_life_shards(problem.phi, 96, R=R, C=C)
    print(f"inspector: 2-D partition in {time.time()-t0:.2f}s — "
          f"{R}x{C} cells, <= {shards.dsc_values.shape[-1]} nnz/cell "
          f"(equal-nnz, sub-vector-snapped)")

    step = LS.make_sharded_step(mesh, dict(nv_local=shards.nv_local,
                                           nf_local=shards.nf_local,
                                           n_theta=96))
    args = LS.sharded_state(mesh, shards, problem)
    jstep = jax.jit(step)

    w = args["w"]
    with mesh:
        for it in range(50):
            w, loss = jstep(args["da"], args["dv"], args["df"], args["dw"],
                            args["wa"], args["wv"], args["wf"], args["ww"],
                            args["d"], args["b"], w,
                            jnp.asarray(it, jnp.int32))
            if it % 10 == 0:
                print(f"  iter {it:3d} loss {float(loss):.4f}")
    w_full = LS.unshard_w(shards, np.asarray(w))

    eng = LifeEngine(problem, LifeConfig(executor="opt", n_iters=50))
    w_ref, losses = eng.run()
    err = np.abs(w_full - np.asarray(w_ref)).max()
    print(f"distributed vs single-device max |dw|: {err:.2e}")
    assert err < 1e-2
    print("OK — 2-D mesh partition reproduces the single-device solution")


if __name__ == "__main__":
    main()
