"""Serve a multi-subject cohort through the batched LiFE engine.

    PYTHONPATH=src python examples/serve_subjects.py [n_subjects]

The production-scale deployment story: many subjects arrive sharing one
acquisition protocol (same gradient scheme -> same dictionary, same candidate
fiber count).  Instead of running SBBNNLS once per subject, the batched
engine pads every subject's Phi tensor to a common coefficient count and
solves the whole cohort in one vmapped computation — reporting throughput in
subjects/sec.  A persistent plan cache makes re-serving the same dataset
(new process, same data) skip the inspector work entirely.
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.batched import BatchedLifeEngine
from repro.core.life import LifeConfig, LifeEngine
from repro.data.dmri import synth_cohort


def main():
    try:
        n_subjects = int(sys.argv[1]) if len(sys.argv) > 1 else 4
    except ValueError:
        sys.exit(f"usage: {sys.argv[0]} [n_subjects]")
    print(f"1. synthesizing {n_subjects}-subject cohort "
          "(shared acquisition, per-subject anatomy)...")
    cohort = synth_cohort(n_subjects, base_seed=0, n_fibers=256, n_theta=64,
                          n_atoms=64, grid=(14, 14, 14))
    ncs = [p.phi.n_coeffs for p in cohort]
    print(f"   Nc per subject: {ncs} (padded to {max(ncs)})")

    cfg = LifeConfig(executor="opt", n_iters=60,
                     plan_cache_dir=tempfile.mkdtemp())

    print("2. baseline: sequential per-subject engines...")
    engines = [LifeEngine(p, cfg) for p in cohort]
    for e in engines:
        e.run(n_iters=2)                      # warm the compile caches
    t0 = time.perf_counter()
    seq = [e.run() for e in engines]
    t_seq = time.perf_counter() - t0
    print(f"   {n_subjects / t_seq:.2f} subjects/sec sequential")

    print("3. batched engine: one vmapped SBBNNLS for the cohort...")
    beng = BatchedLifeEngine(cohort, cfg)
    beng.run(n_iters=2)                       # warm the compile cache
    t0 = time.perf_counter()
    W, losses = beng.run()
    t_bat = time.perf_counter() - t0
    print(f"   {n_subjects / t_bat:.2f} subjects/sec batched "
          f"({t_seq / t_bat:.2f}x vs sequential)")

    for s, (w_seq, _) in enumerate(seq):
        np.testing.assert_allclose(np.asarray(W[s]), np.asarray(w_seq),
                                   rtol=1e-4, atol=1e-5)
    print("   batched weights match the per-subject runs")

    print("4. per-subject pruning results:")
    for s, stats in enumerate(beng.prune_stats(W)):
        print(f"   subject {s}: kept {int(stats['kept'])}/"
              f"{int(stats['total'])} fibers | precision "
              f"{stats['precision']:.2f} recall {stats['recall']:.2f} "
              f"| final loss {losses[s, -1]:.5f}")


if __name__ == "__main__":
    main()
