"""Serve LiFE solves as a multi-tenant service — with a kill-and-resume demo.

    PYTHONPATH=src python examples/serve_life.py [n_subjects]

Walks the whole serving story (DESIGN.md §8):

  1. jobs with different priorities, deadlines and formats are submitted
     continuously; the scheduler buckets batch-compatible subjects into one
     vmapped solve and time-slices between buckets,
  2. every few ticks the service checkpoints all in-flight solver states,
  3. the service is "killed" mid-solve and a fresh instance resumes every
     job from the checkpoint — finishing with weights identical to an
     uninterrupted run.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.life import LifeConfig
from repro.data.dmri import synth_cohort
from repro.serve import LifeService

N_ITERS = 60


def main():
    try:
        n_subjects = int(sys.argv[1]) if len(sys.argv) > 1 else 4
    except ValueError:
        sys.exit(f"usage: {sys.argv[0]} [n_subjects]")

    print(f"1. synthesizing {n_subjects}-subject cohort...")
    cohort = synth_cohort(n_subjects, base_seed=0, n_fibers=256, n_theta=64,
                          n_atoms=64, grid=(14, 14, 14))
    cfg = LifeConfig(executor="opt", n_iters=N_ITERS,
                     plan_cache_dir=tempfile.mkdtemp())

    print("2. uninterrupted service run (reference)...")
    ref = LifeService(cfg, slice_iters=10)
    for i, p in enumerate(cohort):
        # tenant 0 is latency-sensitive (deadline), tenant 1 is high
        # priority, the last tenant wants the SELL fast path
        ref.submit(p, job_id=f"tenant-{i}", n_iters=N_ITERS,
                   priority=5 if i == 1 else 0,
                   deadline=2.0 if i == 0 else None,
                   format="sell" if i == n_subjects - 1 else "coo")
    ref_results = ref.run()
    for jid in sorted(ref_results):
        w, losses = ref_results[jid]
        print(f"   {jid}: final loss {losses[-1]:.5f}, "
              f"{int((np.asarray(w) > 1e-6).sum())} fibers kept")

    print("3. same jobs, but the service dies mid-solve...")
    ckpt_dir = tempfile.mkdtemp()
    svc = LifeService(cfg, ckpt_dir=ckpt_dir, checkpoint_every=1,
                      slice_iters=10)
    for i, p in enumerate(cohort):
        svc.submit(p, job_id=f"tenant-{i}", n_iters=N_ITERS,
                   priority=5 if i == 1 else 0,
                   deadline=2.0 if i == 0 else None,
                   format="sell" if i == n_subjects - 1 else "coo")
    for _ in range(3):
        svc.step()                       # a few time slices, checkpointed
    done = {j.job_id: j.done for j in svc.scheduler.jobs()}
    print(f"   progress at kill: {done}")
    del svc                              # the crash

    print("4. new service instance resumes from the checkpoint...")
    svc2 = LifeService(cfg, ckpt_dir=ckpt_dir, checkpoint_every=1,
                       slice_iters=10)
    print(f"   resumable jobs: {list(svc2.resumable_jobs)}")
    for i, p in enumerate(cohort):       # clients resubmit their data
        svc2.submit(p, job_id=f"tenant-{i}",
                    format="sell" if i == n_subjects - 1 else "coo")
    results = svc2.run()

    print("5. resumed weights vs uninterrupted run:")
    for jid in sorted(results):
        w_res, _ = results[jid]
        w_ref, _ = ref_results[jid]
        err = float(np.max(np.abs(np.asarray(w_res) - np.asarray(w_ref))))
        print(f"   {jid}: max |dw| = {err:.2e}")
        assert err <= 1e-6, f"{jid} diverged after resume"
    print("   every tenant resumed bit-compatibly")


if __name__ == "__main__":
    main()
