"""k-fold cross-validated prediction error (DESIGN.md §15.2).

Split contract: folds partition the *voxel* axis — every voxel id
appears in exactly one fold (disjoint + covering), so held-out rows of
the measured signal are never seen by the training solve.  Fibers are
shared across folds by construction (a streamline traverses many
voxels); that is what makes held-out prediction meaningful — weights
learned on the training voxels predict the left-out rows through the
same fibers.

Restriction (:func:`restrict_to_voxels`) produces a self-consistent
:class:`~repro.data.dmri.LifeProblem`: coefficients outside the voxel
subset are dropped, surviving voxel ids are remapped to a dense
``[0, len(voxels))`` range, and the signal matrix is sliced to the same
rows in the same order.  The restricted problem runs through any
executor×format config unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import spmv
from repro.core.std import PhiTensor
from repro.data.dmri import LifeProblem


def kfold_voxel_folds(n_voxels: int, k: int,
                      seed: int = 0) -> List[np.ndarray]:
    """Partition ``range(n_voxels)`` into ``k`` disjoint, covering folds.

    Args:
        n_voxels: size of the voxel axis being split.
        k: number of folds; fold sizes differ by at most one.
        seed: RNG seed for the shuffle (same seed -> same folds).

    Returns:
        List of ``k`` sorted int64 arrays; their concatenation is a
        permutation of ``range(n_voxels)``.

    Raises:
        ValueError: if ``k`` is not in ``[2, n_voxels]``.
    """
    if not 2 <= k <= n_voxels:
        raise ValueError(f"k must be in [2, {n_voxels}], got {k}")
    perm = np.random.default_rng(seed).permutation(n_voxels)
    return [np.sort(perm[i::k]).astype(np.int64) for i in range(k)]


def restrict_to_voxels(problem: LifeProblem,
                       voxels: Sequence[int]) -> LifeProblem:
    """The sub-problem over a voxel subset (ids remapped densely).

    Args:
        problem: the full problem.
        voxels: voxel ids to keep (deduplicated and sorted internally).

    Returns:
        A :class:`~repro.data.dmri.LifeProblem` whose Phi holds only
        coefficients in ``voxels`` (ids remapped to ``[0, len(voxels))``
        in sorted order), with the signal rows sliced to match.  The
        fiber id space is unchanged, so weight vectors carry over.

    Raises:
        ValueError: if ``voxels`` is empty or contains out-of-range ids.
    """
    vox = np.unique(np.asarray(voxels, np.int64))
    if vox.size == 0:
        raise ValueError("voxel subset is empty")
    if vox[0] < 0 or vox[-1] >= problem.phi.n_voxels:
        raise ValueError(f"voxel ids must be in [0, {problem.phi.n_voxels}), "
                         f"got range [{vox[0]}, {vox[-1]}]")
    phi = problem.phi
    old_v = np.asarray(phi.voxels, np.int64)
    keep = np.nonzero(np.isin(old_v, vox))[0]
    new_v = np.searchsorted(vox, old_v[keep])
    sub = PhiTensor(
        atoms=jnp.asarray(np.asarray(phi.atoms)[keep], jnp.int32),
        voxels=jnp.asarray(new_v, jnp.int32),
        fibers=jnp.asarray(np.asarray(phi.fibers)[keep], jnp.int32),
        values=jnp.asarray(np.asarray(phi.values)[keep]),
        n_atoms=phi.n_atoms, n_voxels=int(vox.size),
        n_fibers=phi.n_fibers)
    stats = dict(problem.stats)
    stats["n_coeffs"] = float(sub.n_coeffs)
    stats["n_voxels_touched"] = float(np.unique(new_v).size)
    return LifeProblem(phi=sub, dictionary=problem.dictionary,
                       b=problem.b[jnp.asarray(vox)],
                       w_true=problem.w_true, stats=stats)


def heldout_rmse(problem: LifeProblem, w) -> float:
    """RMSE of the predicted signal ``M w`` against the measured signal.

    Uses the reference (naive COO) SpMV so evaluation never depends on
    the executor/format under test.
    """
    pred = spmv.dsc_naive(problem.phi, problem.dictionary,
                          jnp.asarray(w, problem.dictionary.dtype))
    err = np.asarray(pred) - np.asarray(problem.b)
    return float(np.sqrt(np.mean(err ** 2)))


@dataclasses.dataclass(frozen=True)
class CrossvalResult:
    """Per-fold held-out errors plus the null-model reference.

    ``null_rmse`` is the RMSE of the empty connectome (``w = 0``; the
    signal is demeaned, so this is the RMS of the held-out rows) —
    a cross-validated solve that beats it carries real evidence.
    """

    fold_rmse: List[float]
    null_rmse: float
    k: int
    n_iters: int

    @property
    def mean_rmse(self) -> float:
        """Mean held-out RMSE across folds."""
        return float(np.mean(self.fold_rmse))

    @property
    def relative_rmse(self) -> float:
        """``mean_rmse / null_rmse`` (< 1.0 = better than no connectome)."""
        return self.mean_rmse / max(self.null_rmse, 1e-30)

    def describe(self) -> str:
        """One-line human-readable summary."""
        return (f"{self.k}-fold crossval: rmse={self.mean_rmse:.5f} "
                f"(null {self.null_rmse:.5f}, "
                f"ratio {self.relative_rmse:.3f})")


def crossval_rmse(problem: LifeProblem, config=None, *, k: int = 4,
                  seed: int = 0, n_iters: Optional[int] = None,
                  cache=None) -> CrossvalResult:
    """k-fold cross-validated RMSE of a LiFE solve.

    For each fold: train on the complement's voxels through a
    :class:`~repro.core.life.LifeEngine` built from ``config`` (any
    executor×format combination), then score the held-out fold with the
    reference SpMV.

    Args:
        problem: the full problem to cross-validate.
        config: :class:`~repro.core.life.LifeConfig` for the training
            solves (default config when None).
        k: number of voxel folds.
        seed: fold-assignment seed.
        n_iters: training iterations per fold (``config.n_iters`` when
            None).
        cache: optional shared
            :class:`~repro.core.plan_cache.PlanCache`.

    Returns:
        A :class:`CrossvalResult` with per-fold and null-model RMSE.
    """
    from repro.core.life import LifeConfig, LifeEngine
    cfg = config if config is not None else LifeConfig()
    iters = cfg.n_iters if n_iters is None else n_iters
    all_vox = np.arange(problem.phi.n_voxels, dtype=np.int64)
    fold_rmse: List[float] = []
    null_sq: List[float] = []
    for fold in kfold_voxel_folds(problem.phi.n_voxels, k, seed):
        train = restrict_to_voxels(problem, np.setdiff1d(all_vox, fold))
        test = restrict_to_voxels(problem, fold)
        engine = LifeEngine(train, cfg, cache)
        w, _ = engine.run(iters)
        fold_rmse.append(heldout_rmse(test, w))
        null_sq.append(float(np.mean(np.asarray(test.b) ** 2)))
    return CrossvalResult(fold_rmse=fold_rmse,
                          null_rmse=float(np.sqrt(np.mean(null_sq))),
                          k=k, n_iters=iters)
