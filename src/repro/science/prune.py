"""Pruned connectomes from converged SBBNNLS weights (DESIGN.md §15.1).

Pruning semantics: a fiber survives iff it is *structurally present*
(contributes at least one Phi coefficient) **and** its converged weight
exceeds the threshold.  The structural clause matters for edited
connectomes — a fiber whose coefficients were all removed (a virtual
lesion) has a zero column, so the solver's gradient never moves its
weight; without the structural test a cold-started solve would report
such a fiber at its initial weight 1.0 despite contributing nothing to
the signal.

The support is a deterministic function of the weight vector alone, so
two solves that agree on weights (e.g. the same seed run through coo,
sell, and fcoo — the conformance matrix pins their trajectories
together) produce bit-identical supports.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.restructure import compact_by_weight
from repro.core.std import PhiTensor
from repro.data.dmri import LifeProblem


@dataclasses.dataclass(frozen=True)
class PrunedConnectome:
    """One pruning result: surviving support + Phi compacted onto it.

    ``support`` is sorted ascending and int64; ``weights`` aligns with it
    elementwise.  ``phi`` holds only coefficients of surviving fibers but
    keeps the original fiber id space (``n_fibers`` unchanged), so
    weight vectors stay shape-compatible with the unpruned problem —
    the invariant every warm start relies on (DESIGN.md §15.3).
    """

    support: np.ndarray          # (n_kept,) int64, sorted fiber ids
    weights: np.ndarray          # (n_kept,) float weights on the support
    phi: PhiTensor               # compacted to the surviving support
    n_fibers_total: int
    threshold: float

    @property
    def n_kept(self) -> int:
        """Number of surviving fibers."""
        return int(self.support.size)

    @property
    def keep_fraction(self) -> float:
        """Surviving fibers / total fibers."""
        return self.n_kept / max(1, self.n_fibers_total)

    def weight_of(self, fiber_id: int) -> float:
        """The pruned weight of one fiber (exactly 0.0 off the support)."""
        i = np.searchsorted(self.support, fiber_id)
        if i < self.support.size and self.support[i] == fiber_id:
            return float(self.weights[i])
        return 0.0

    def describe(self) -> str:
        """One-line human-readable summary."""
        return (f"pruned connectome: {self.n_kept}/{self.n_fibers_total} "
                f"fibers kept ({100 * self.keep_fraction:.1f}%), "
                f"{self.phi.n_coeffs} coefficients, "
                f"threshold={self.threshold:g}")


def prune_connectome(problem: LifeProblem, w,
                     threshold: float = 1e-6) -> PrunedConnectome:
    """Extract the pruned connectome from a converged weight vector.

    Args:
        problem: the solved :class:`~repro.data.dmri.LifeProblem`.
        w: converged weights, shape ``(n_fibers,)`` (jax or numpy).
        threshold: a fiber survives iff ``w[fiber] > threshold`` and it
            has at least one Phi coefficient.

    Returns:
        A :class:`PrunedConnectome` whose ``phi`` is the input Phi
        compacted (via
        :func:`~repro.core.restructure.compact_by_weight`) onto the
        surviving support.

    Raises:
        ValueError: if ``w`` does not match the problem's fiber count.
    """
    w_np = np.asarray(w)
    nf = problem.phi.n_fibers
    if w_np.shape != (nf,):
        raise ValueError(f"w has shape {w_np.shape}, expected ({nf},)")
    structural = np.zeros(nf, bool)
    structural[np.asarray(problem.phi.fibers)] = True
    kept = (w_np > threshold) & structural
    support = np.nonzero(kept)[0].astype(np.int64)
    phi = compact_by_weight(problem.phi, w_np, threshold)
    return PrunedConnectome(support=support,
                            weights=w_np[support].copy(),
                            phi=phi, n_fibers_total=nf,
                            threshold=float(threshold))


def weight_summary(w, threshold: float = 1e-6) -> Dict[str, float]:
    """Summary statistics of a weight vector's surviving mass.

    Args:
        w: weight vector (jax or numpy).
        threshold: support cut, as in :func:`prune_connectome`.

    Returns:
        Dict with ``kept``/``total``/``keep_fraction`` counts and the
        min/median/max/sum of the surviving weights (zeros when the
        support is empty).
    """
    w_np = np.asarray(w)
    on = w_np[w_np > threshold]
    out = dict(kept=float(on.size), total=float(w_np.size),
               keep_fraction=float(on.size) / max(1, w_np.size))
    if on.size:
        out.update(w_min=float(on.min()), w_median=float(np.median(on)),
                   w_max=float(on.max()), w_sum=float(on.sum()))
    else:
        out.update(w_min=0.0, w_median=0.0, w_max=0.0, w_sum=0.0)
    return out
