"""Virtual-lesion evaluation with warm-started re-solves (DESIGN.md §15.3).

A virtual lesion asks: how much worse does the model explain the signal
when one fiber bundle is removed?  The procedure:

1. remove the bundle's coefficients from Phi (the fiber id space is
   kept — ``n_fibers`` unchanged — so weight vectors stay compatible),
2. re-solve, warm-starting from the previous converged weights with the
   lesioned entries zeroed (a lesioned fiber has a zero column, so its
   gradient is zero and the weight stays *exactly* zero),
3. report evidence as the RMSE delta on the bundle's voxel footprint —
   the voxels the lesioned streamlines traversed, where the loss of
   explanatory power is concentrated.

The warm start is the point: the lesioned optimum is close to the full
optimum everywhere off the bundle, so the re-solve converges in a
fraction of the cold iteration count (the table17 CI gate pins
warm <= cold).  The previous state may come from a live solve or from a
service checkpoint (:func:`repro.checkpoint.manager.restore_job`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.std import PhiTensor
from repro.data.dmri import LifeProblem
from repro.science.crossval import heldout_rmse, restrict_to_voxels
from repro.science.incremental import ConvergedSolve, solve_to_convergence

import jax.numpy as jnp


def lesion_problem(problem: LifeProblem,
                   fiber_ids: Sequence[int]) -> LifeProblem:
    """Remove a fiber bundle's coefficients, keeping the fiber id space.

    Args:
        problem: the full problem.
        fiber_ids: fiber ids to lesion.

    Returns:
        A :class:`~repro.data.dmri.LifeProblem` whose Phi has no
        coefficients on the lesioned fibers but the same ``n_fibers``
        (weight-vector shape compatibility — the warm-start invariant),
        the same signal, and ``w_true`` zeroed on the bundle.

    Raises:
        ValueError: on an empty bundle or out-of-range fiber ids.
    """
    ids = np.unique(np.asarray(fiber_ids, np.int64))
    if ids.size == 0:
        raise ValueError("lesion bundle is empty")
    if ids[0] < 0 or ids[-1] >= problem.phi.n_fibers:
        raise ValueError(f"fiber ids must be in [0, {problem.phi.n_fibers}),"
                         f" got range [{ids[0]}, {ids[-1]}]")
    phi = problem.phi
    fib = np.asarray(phi.fibers, np.int64)
    keep = np.nonzero(~np.isin(fib, ids))[0]
    sub = phi.take(jnp.asarray(keep, jnp.int32))
    w_true = np.asarray(problem.w_true).copy()
    w_true[ids] = 0.0
    stats = dict(problem.stats)
    stats["n_coeffs"] = float(sub.n_coeffs)
    return LifeProblem(phi=sub, dictionary=problem.dictionary,
                       b=problem.b,
                       w_true=jnp.asarray(w_true, problem.w_true.dtype),
                       stats=stats, grid=problem.grid)


def warm_start_weights(w_prev, fiber_ids: Sequence[int]) -> np.ndarray:
    """Previous weights with the lesioned entries zeroed.

    This is the valid warm start for the lesioned problem: off-bundle
    weights carry over (the optimum moved little there), on-bundle
    weights are pinned at zero where the gradient can never move them.
    The solver state built from it resets the iteration counter — BB
    step history from the unlesioned operator is not reused.
    """
    w0 = np.asarray(w_prev).copy()
    w0[np.asarray(fiber_ids, np.int64)] = 0.0
    return w0


def bundle_footprint(problem: LifeProblem,
                     fiber_ids: Sequence[int]) -> np.ndarray:
    """Sorted unique voxel ids traversed by the bundle's coefficients."""
    fib = np.asarray(problem.phi.fibers, np.int64)
    mask = np.isin(fib, np.asarray(fiber_ids, np.int64))
    return np.unique(np.asarray(problem.phi.voxels, np.int64)[mask])


@dataclasses.dataclass
class LesionReport:
    """Evidence for one virtual lesion.

    ``evidence`` is the RMSE increase on the bundle's voxel footprint
    when the bundle is removed and the model re-fit; positive evidence
    means the bundle explains signal no other fiber can absorb.
    """

    bundle: np.ndarray           # lesioned fiber ids
    footprint: np.ndarray        # voxel ids the bundle traversed
    rmse_full: float             # footprint RMSE, full connectome
    rmse_lesioned: float         # footprint RMSE, lesioned + re-fit
    evidence: float              # rmse_lesioned - rmse_full
    iters_warm: int              # re-solve iterations (warm-started)
    iters_full: int              # full solve iterations (0 if w was given)
    w_full: np.ndarray
    w_lesioned: np.ndarray

    def describe(self) -> str:
        """Evidence table (one row per quantity), ready to print."""
        rows = [
            ("bundle fibers", f"{self.bundle.size}"),
            ("footprint voxels", f"{self.footprint.size}"),
            ("rmse (full)", f"{self.rmse_full:.6f}"),
            ("rmse (lesioned)", f"{self.rmse_lesioned:.6f}"),
            ("evidence (delta)", f"{self.evidence:+.6f}"),
            ("warm re-solve iters", f"{self.iters_warm}"),
        ]
        if self.iters_full:
            rows.append(("cold full-solve iters", f"{self.iters_full}"))
        width = max(len(k) for k, _ in rows)
        return "\n".join(f"{k:<{width}}  {v}" for k, v in rows)


def virtual_lesion(problem: LifeProblem, bundle: Sequence[int],
                   config=None, *, w_full=None,
                   ckpt_dir: Optional[str] = None,
                   job_id: Optional[str] = None,
                   rtol: float = 1e-4, chunk: int = 8,
                   max_iters: int = 400, cache=None) -> LesionReport:
    """Run one virtual-lesion evaluation.

    The previous converged weights come from (in precedence order) the
    ``w_full`` argument, a checkpointed service job
    (``ckpt_dir``/``job_id`` — the solve warm-starts from the previous
    checkpointed :class:`~repro.core.sbbnnls.SbbnnlsState` rather than
    from zero), or a cold full solve run here.

    Args:
        problem: the full problem.
        bundle: fiber ids to lesion.
        config: :class:`~repro.core.life.LifeConfig` for the solves
            (default config when None).
        w_full: previous converged full-connectome weights.
        ckpt_dir: service checkpoint directory holding the full solve.
        job_id: job id inside that checkpoint.
        rtol / chunk / max_iters: convergence parameters (see
            :func:`~repro.science.incremental.solve_to_convergence`).
        cache: optional shared plan cache.

    Returns:
        A :class:`LesionReport` with the RMSE-delta evidence and the
        warm re-solve iteration count.

    Raises:
        KeyError: if ``job_id`` is not present in the checkpoint.
        ValueError: on an invalid bundle (see :func:`lesion_problem`).
    """
    from repro.core.life import LifeConfig, LifeEngine
    cfg = config if config is not None else LifeConfig()
    ids = np.unique(np.asarray(bundle, np.int64))
    iters_full = 0
    if w_full is None and ckpt_dir is not None:
        from repro.checkpoint.manager import restore_job
        if job_id is None:
            raise ValueError("ckpt_dir given without job_id")
        arrays, _meta = restore_job(ckpt_dir, job_id)
        w_full = np.asarray(arrays["w"])
    if w_full is None:
        cold = solve_to_convergence(LifeEngine(problem, cfg, cache),
                                    rtol=rtol, chunk=chunk,
                                    max_iters=max_iters)
        w_full = cold.w
        iters_full = cold.iters
    w_full = np.asarray(w_full)

    lesioned = lesion_problem(problem, ids)
    warm: ConvergedSolve = solve_to_convergence(
        LifeEngine(lesioned, cfg, cache),
        w0=warm_start_weights(w_full, ids),
        rtol=rtol, chunk=chunk, max_iters=max_iters)

    footprint = bundle_footprint(problem, ids)
    rmse_full = heldout_rmse(restrict_to_voxels(problem, footprint), w_full)
    rmse_lesioned = heldout_rmse(restrict_to_voxels(lesioned, footprint),
                                 warm.w)
    return LesionReport(bundle=ids, footprint=footprint,
                        rmse_full=rmse_full, rmse_lesioned=rmse_lesioned,
                        evidence=rmse_lesioned - rmse_full,
                        iters_warm=warm.iters, iters_full=iters_full,
                        w_full=w_full, w_lesioned=warm.w)
