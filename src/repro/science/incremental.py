"""Warm-started incremental solves (DESIGN.md §15.3–§15.4).

Three layers of "don't start from zero":

* :func:`solve_to_convergence` — the convergence-driven driver the
  science workloads share: step an engine in chunks until the best loss
  stops improving, counting iterations.  Warm vs cold comparisons (the
  table17 gate) are this function with and without a ``w0``.
* :func:`resubmit_delta` — a Phi-delta resubmission: an edited problem
  (lesioned tractogram, new acquisition of the same subject) goes back
  through the async serving front line as a repeat-visit job whose
  ``w0`` is the previous converged weights.  The serving layer sees the
  same geometry, so plan-cache entries and learned predictions warm-hit.
* :func:`multires_solve` — coarse-to-fine multi-resolution: solve on a
  voxel-coarsened problem first, then warm-start the fine solve from
  the coarse weights (weights are per-fiber, so they transfer across
  voxel resolutions unchanged).  Each level's result is checkpointed
  through :mod:`repro.checkpoint.manager`; a killed multires run resumes
  at the first unfinished level.

Warm-start state-reuse rule (also enforced by the serving layer): a
previous weight vector is a valid start for an edited Phi iff the fiber
id space is unchanged; the iteration counter is always reset
(``sbbnnls_init``) because the BB step history was computed under a
different operator.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.data.dmri import LifeProblem, coarsen_problem


@dataclasses.dataclass
class ConvergedSolve:
    """Result of one convergence-driven solve.

    ``iters`` counts SBBNNLS iterations actually run (a multiple of the
    chunk size); ``converged`` is False when ``max_iters`` elapsed
    before the stopping rule fired.
    """

    state: object                # final SbbnnlsState
    iters: int
    losses: np.ndarray           # per-iteration loss trace
    converged: bool

    @property
    def w(self) -> np.ndarray:
        """Final weights as a host array."""
        return np.asarray(self.state.w)


def solve_to_convergence(engine, w0=None, *, rtol: float = 1e-4,
                         chunk: int = 8,
                         max_iters: int = 400) -> ConvergedSolve:
    """Step ``engine`` until the best loss stops improving.

    The stopping rule compares the best (minimum) loss seen so far
    across chunks — robust to BB's non-monotone per-iteration losses:
    after each chunk, stop once the improvement over the previous best
    is within ``rtol`` (relative).  A warm start near the fixed point
    therefore stops after two chunks; a cold start keeps going while
    real progress is being made.

    Args:
        engine: a :class:`~repro.core.life.LifeEngine` (or anything
            with ``init_state``/``step`` and a bound problem).
        w0: optional warm-start weights (host or device array); None
            starts from the engine's all-ones default.
        rtol: relative best-loss improvement below which the solve is
            declared converged.
        chunk: iterations per step call (convergence granularity).
        max_iters: hard iteration cap.

    Returns:
        A :class:`ConvergedSolve` with the final state and the
        iteration count — the quantity the warm-vs-cold CI gate
        compares.
    """
    dtype = engine.problem.dictionary.dtype
    state = engine.init_state(
        None if w0 is None else jnp.asarray(w0, dtype))
    losses: List[np.ndarray] = []
    best: Optional[float] = None
    done = 0
    converged = False
    while done < max_iters:
        k = min(chunk, max_iters - done)
        state, ls = engine.step(state, k)
        losses.append(np.asarray(ls))
        done += k
        cur = float(np.min(ls))
        if best is not None and best - cur <= rtol * max(abs(best), 1e-30):
            converged = True
            break
        best = cur if best is None else min(best, cur)
    return ConvergedSolve(state=state, iters=done,
                          losses=np.concatenate(losses), converged=converged)


def resubmit_delta(frontend, problem: LifeProblem, w_prev, *,
                   lesioned: Optional[Sequence[int]] = None,
                   **submit_kwargs):
    """Resubmit an edited problem as a warm-started repeat-visit job.

    Args:
        frontend: a running
            :class:`~repro.serve.frontend.LifeFrontend`.
        problem: the edited problem (same fiber id space as the solve
            that produced ``w_prev``).
        w_prev: previous converged weights, shape ``(n_fibers,)``.
        lesioned: fiber ids whose weights are zeroed in the warm start
            (they no longer have coefficients, so their gradient is
            zero and they stay exactly zero — DESIGN.md §15.3).
        **submit_kwargs: forwarded to
            :meth:`~repro.serve.frontend.LifeFrontend.submit_async`
            (n_iters, priority, format, ...).

    Returns:
        The :class:`~repro.serve.frontend.JobHandle` of the warm job.

    Raises:
        ValueError: if ``w_prev`` does not match the problem's fiber
            count.
    """
    w0 = np.asarray(w_prev).copy()
    if w0.shape != (problem.phi.n_fibers,):
        raise ValueError(f"w_prev has shape {w0.shape}, expected "
                         f"({problem.phi.n_fibers},)")
    if lesioned is not None:
        w0[np.asarray(lesioned, np.int64)] = 0.0
    return frontend.submit_async(problem, w0=w0, **submit_kwargs)


@dataclasses.dataclass
class MultiresResult:
    """Per-level iteration counts plus the final fine-level solve."""

    levels: List[dict]           # [{"factor", "n_voxels", "iters", ...}]
    final: ConvergedSolve
    resumed_at: int              # first level actually run (ckpt resume)

    @property
    def total_iters(self) -> int:
        """Iterations summed over all levels run in this incarnation."""
        return int(sum(lv["iters"] for lv in self.levels))

    def describe(self) -> str:
        """One-line per-level summary."""
        steps = " -> ".join(
            f"{lv['factor']}x/{lv['n_voxels']}vox:{lv['iters']}it"
            f"{'' if lv.get('ran', True) else ' (ckpt)'}"
            for lv in self.levels)
        return f"multires {steps}"


def multires_solve(problem: LifeProblem, config=None, *,
                   factors: Tuple[int, ...] = (2,),
                   grid: Optional[Tuple[int, int, int]] = None,
                   rtol: float = 1e-4, chunk: int = 8,
                   max_iters: int = 400, ckpt_dir: Optional[str] = None,
                   keep: int = 3, cache=None) -> MultiresResult:
    """Coarse-to-fine solve: each level warm-starts the next.

    Levels are the problem coarsened by each ``factors`` entry (coarsest
    first) followed by the full-resolution problem.  Weights are
    per-fiber, so a level's converged weights warm-start the next level
    directly.  With ``ckpt_dir`` set, every finished level is saved
    through the checkpoint manager (atomic, retained) and a rerun skips
    levels already on disk — the multi-resolution resume flow of
    DESIGN.md §15.4.

    Args:
        problem: the full-resolution problem; its ``grid`` (or the
            ``grid`` argument) is required for coarsening.
        config: :class:`~repro.core.life.LifeConfig` shared by all
            levels (default config when None).
        factors: coarsening factors, strictly decreasing, all > 1.
        grid: voxel grid override when ``problem.grid`` is unset.
        rtol / chunk / max_iters: per-level convergence parameters
            (see :func:`solve_to_convergence`).
        ckpt_dir: checkpoint directory enabling level-wise resume.
        keep: checkpoint retention (levels kept on disk).
        cache: optional shared plan cache for the level engines.

    Returns:
        A :class:`MultiresResult`; ``final`` is the full-resolution
        solve.

    Raises:
        ValueError: on a non-decreasing or <= 1 factor sequence.
    """
    from repro.core.life import LifeConfig, LifeEngine
    cfg = config if config is not None else LifeConfig()
    if any(f <= 1 for f in factors):
        raise ValueError(f"factors must all be > 1, got {factors}")
    if list(factors) != sorted(factors, reverse=True):
        raise ValueError(f"factors must be coarsest-first (decreasing), "
                         f"got {factors}")
    probs = [coarsen_problem(problem, f, grid=grid) for f in factors]
    probs.append(problem)
    level_factors = list(factors) + [1]

    w: Optional[np.ndarray] = None
    start = 0
    levels: List[dict] = []
    if ckpt_dir:
        latest = ckpt.load_latest(ckpt_dir)
        if latest is not None:
            step, flat, manifest = latest
            saved = manifest.get("multires", {})
            if saved.get("factors") == list(level_factors) and "w" in flat:
                start = int(step) + 1
                w = np.asarray(flat["w"])
                for li in range(start):
                    levels.append(dict(factor=level_factors[li],
                                       n_voxels=probs[li].phi.n_voxels,
                                       iters=0, converged=True, ran=False))

    result: Optional[ConvergedSolve] = None
    for li in range(start, len(probs)):
        engine = LifeEngine(probs[li], cfg, cache)
        result = solve_to_convergence(engine, w0=w, rtol=rtol, chunk=chunk,
                                      max_iters=max_iters)
        w = result.w
        levels.append(dict(factor=level_factors[li],
                           n_voxels=probs[li].phi.n_voxels,
                           iters=result.iters, converged=result.converged,
                           ran=True))
        if ckpt_dir:
            ckpt.save(ckpt_dir, li, {"w": w},
                      meta={"multires": {"factors": list(level_factors),
                                         "level": li}},
                      keep=keep)
    if result is None:
        # every level (including the fine one) was already checkpointed:
        # re-derive the final state from the stored weights without
        # re-running — the resume path's fast exit
        engine = LifeEngine(probs[-1], cfg, cache)
        state = engine.init_state(jnp.asarray(w, probs[-1].dictionary.dtype))
        result = ConvergedSolve(state=state, iters=0,
                                losses=np.zeros((0,)), converged=True)
    return MultiresResult(levels=levels, final=result, resumed_at=start)
