"""Connectome-pruning science workloads (DESIGN.md §15).

LiFE exists to prune brain connectivity graphs: the solver layers below
(engines, formats, tuning, serving) are means to four science outputs,
which this package provides as first-class workloads:

* :mod:`~repro.science.prune` — pruned connectomes from converged
  weights: nonzero-support extraction, fiber-weight summaries, and Phi
  compaction to the surviving support.
* :mod:`~repro.science.crossval` — k-fold cross-validated RMSE over
  disjoint voxel folds, evaluated through any executor×format config.
* :mod:`~repro.science.lesion` — virtual-lesion evaluation: remove a
  fiber bundle, warm-start the re-solve from the previous (optionally
  checkpointed) state, report evidence as the held RMSE delta on the
  bundle's voxel footprint.
* :mod:`~repro.science.incremental` — convergence-driven solves,
  Phi-delta resubmission through the async serving front line, and
  coarse-to-fine multi-resolution solves riding the checkpoint/resume
  machinery.

Everything here composes the existing stack rather than adding solver
code: warm starts are plain ``sbbnnls_init(w0)`` states (iteration
parity reset — BB step history is invalid under an edited operator, see
DESIGN.md §15.3), and served warm starts ride ``Job.w0``.
"""
from repro.science.crossval import (CrossvalResult, crossval_rmse,
                                    heldout_rmse, kfold_voxel_folds,
                                    restrict_to_voxels)
from repro.science.incremental import (ConvergedSolve, MultiresResult,
                                       multires_solve, resubmit_delta,
                                       solve_to_convergence)
from repro.science.lesion import (LesionReport, bundle_footprint,
                                  lesion_problem, virtual_lesion,
                                  warm_start_weights)
from repro.science.prune import (PrunedConnectome, prune_connectome,
                                 weight_summary)

__all__ = [
    "CrossvalResult", "crossval_rmse", "heldout_rmse", "kfold_voxel_folds",
    "restrict_to_voxels",
    "ConvergedSolve", "MultiresResult", "multires_solve", "resubmit_delta",
    "solve_to_convergence",
    "LesionReport", "bundle_footprint", "lesion_problem", "virtual_lesion",
    "warm_start_weights",
    "PrunedConnectome", "prune_connectome", "weight_summary",
]
