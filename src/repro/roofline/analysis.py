"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), TPU v5e constants:

  compute    = HLO_FLOPs_global / (chips * 197e12 bf16 FLOP/s)
  memory     = HLO_bytes_global / (chips * 819e9 B/s HBM)
  collective = collective_bytes_per_chip / 50e9 B/s per ICI link

`compiled.cost_analysis()` reports per-partition (per-chip) flops/bytes under
SPMD, so global = per_chip * chips.  Collective bytes are NOT in
cost_analysis: we parse the post-SPMD `compiled.as_text()` and sum data moved
per collective with ring-algorithm factors:

  all-gather:          result_bytes * (g-1)/g
  reduce-scatter:      result_bytes * (g-1)        (operand = result * g)
  all-reduce:          2 * size_bytes * (g-1)/g
  all-to-all:          size_bytes * (g-1)/g
  collective-permute:  size_bytes

where g is the replica-group size parsed from the instruction.  This is the
standard ring/bidirectional model; absolute numbers are approximations, the
*relative* movement across perf iterations is what the hillclimb optimizes.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any, Dict, Optional

HW = dict(
    peak_flops=197e12,        # bf16 FLOP/s per v5e chip
    hbm_bw=819e9,             # B/s per chip
    link_bw=50e9,             # B/s per ICI link
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|((?:[a-z0-9_]+)\[[0-9,]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return default


def collective_bytes(hlo_text: str, n_chips: int) -> Dict[str, float]:
    """Per-chip bytes moved over ICI, by collective kind."""
    out: Dict[str, float] = {"all-gather": 0.0, "all-reduce": 0.0,
                             "reduce-scatter": 0.0, "all-to-all": 0.0,
                             "collective-permute": 0.0}
    counts: Dict[str, int] = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str = m.group(1) or m.group(2)
        kind = m.group(3).lower()
        size = _shape_bytes(type_str)
        g = _group_size(line, n_chips)
        if g <= 1:
            continue
        if kind == "all-gather":
            moved = size * (g - 1) / g
        elif kind == "reduce-scatter":
            moved = size * (g - 1)
        elif kind == "all-reduce":
            moved = 2 * size * (g - 1) / g
        elif kind == "all-to-all":
            moved = size * (g - 1) / g
        else:  # collective-permute
            moved = size
        out[kind] += moved
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts  # type: ignore[assignment]
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    model_flops: float
    useful_ratio: float
    dominant: str
    bound_s: float

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def roofline(flops_per_chip: float, bytes_per_chip: float,
             coll_bytes_per_chip: float, n_chips: int,
             model_flops_global: float) -> Roofline:
    compute_s = flops_per_chip / HW["peak_flops"]
    memory_s = bytes_per_chip / HW["hbm_bw"]
    collective_s = coll_bytes_per_chip / HW["link_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    hlo_global = flops_per_chip * n_chips
    useful = model_flops_global / hlo_global if hlo_global else 0.0
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        flops_per_chip=flops_per_chip, bytes_per_chip=bytes_per_chip,
        coll_bytes_per_chip=coll_bytes_per_chip,
        model_flops=model_flops_global, useful_ratio=useful,
        dominant=dominant, bound_s=max(terms.values()))


def model_flops(cfg, shape_name: str, seq: int, batch: int, kind: str) -> float:
    """MODEL_FLOPS: 6*N*D train (fwd+bwd), 2*N_active*D inference."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = seq * batch
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = seq * batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * batch


def mfu_fraction(r: Roofline, n_chips: int, kind: str) -> float:
    """Achievable model-FLOPs utilization upper bound implied by the terms:
    useful model flops / (chips * peak * bound-time)."""
    denom = n_chips * HW["peak_flops"] * max(r.bound_s, 1e-30)
    return r.model_flops / denom
