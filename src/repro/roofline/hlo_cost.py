"""Trip-count-aware HLO cost model (the dry-run "profiler").

`compiled.cost_analysis()` counts a `while` body ONCE, so any scanned-layer
model under-reports FLOPs/bytes/collectives by ~n_layers x (verified in
tests/test_hlo_cost.py).  This module re-derives costs from the post-SPMD
`compiled.as_text()` with loop multipliers:

  1. parse computations and each instruction's result shape,
  2. build the call graph (while body/condition, fusion calls, conditionals),
  3. extract while trip counts from the loop-condition constant,
  4. multiplier(comp) = product of trip counts on the call path from ENTRY,
  5. FLOPs: dot instructions (2 * prod(out) * prod(contracting dims)),
     convolutions (crude window model), rare on this workload;
  6. bytes: per instruction result + operand bytes at fusion/top-level
     granularity (fusion internals stay in registers/VMEM — matching the
     "bytes accessed" HBM-traffic semantics);
  7. collectives: ring-model bytes (see analysis.collective_bytes) times the
     multiplier of the computation they sit in.

Conditional branches are counted at the max over branches (a scanned-layer
`cond` executes exactly one branch per iteration).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.compat import xla_cost_analysis  # noqa: F401  (re-export: the
# ground-truth accessor lives beside the cost model; older jax returns a
# per-partition *list* from Compiled.cost_analysis(), newer a bare dict)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COMP_START = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*\{\s*$")
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
# result type (tuple or single, with optional layout braces) followed by op
_SHAPE = re.compile(
    r"^(\(.*?\)|[a-z0-9_]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([a-z][\w\-]*)\(")
_ONE_SHAPE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_CALLS = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_WHILE = re.compile(r"\bwhile\(")
_DOT_ATTR = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DOT_BATCH = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_COLL_KIND = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _parse_shapes(text: str) -> List[Tuple[str, List[int]]]:
    return [(dt, [int(d) for d in dims.split(",") if d])
            for dt, dims in _ONE_SHAPE.findall(text)]


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _parse_shapes(text):
        if dt in _DTYPE_BYTES:
            n = 1
            for d in dims:
                n *= d
            total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_type: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]          # instr name -> result type string


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_START.match(line.strip())
            if m:
                cur = Computation(m.group(1), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        sm = _SHAPE.match(rest)
        if not sm:
            continue
        result_type, op = sm.group(1), sm.group(2)
        cur.instrs.append(Instr(name, op, result_type, line))
        cur.shapes[name] = result_type
    return comps


def _operands(line: str) -> List[str]:
    """Operand instruction names of a call like op(%a, %b.2, s32[] %c)."""
    m = re.search(r"\b[a-z][\w\-]*\((.*)$", line)
    if not m:
        return []
    args = m.group(1)
    # cut at the closing paren of the operand list (attrs follow after "),")
    depth = 1
    out = []
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args = args[:i]
                break
    return re.findall(r"%([\w\.\-]+)", args)


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition = scan length bound."""
    best = 1
    for ins in cond.instrs:
        for c in _CONST_INT.findall(ins.line):
            best = max(best, int(c))
    return best


def _multipliers(comps: Dict[str, Computation]
                 ) -> Tuple[Dict[str, float], set]:
    """Returns (multiplier per computation, fusion-internal computations).

    Fusion-internal comps (reached via calls=/to_apply=) stay in registers —
    their FLOPs are real but their operands/results are not HBM traffic (the
    enclosing fusion instruction accounts for that)."""
    entry = None
    for name in comps:
        # jax entry is usually 'main.N'; fall back to the last computation
        if name.startswith("main"):
            entry = name
    if entry is None:
        entry = list(comps)[-1]
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    internal: set = set()

    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(len(comps)):
        changed = False
        for cname, comp in comps.items():
            base = mult.get(cname, 0.0)
            if base == 0.0:
                continue
            for ins in comp.instrs:
                trips = 1.0
                called = _CALLS.findall(ins.line)
                if _WHILE.search(ins.line):
                    cond_m = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                    if cond_m and cond_m.group(1) in comps:
                        trips = float(_trip_count(comps[cond_m.group(1)]))
                for target in called:
                    if target not in comps:
                        continue
                    line_n = ins.line.replace("%", "")
                    is_body = f"body={target}" in line_n
                    is_fusion = (f"calls={target}" in line_n
                                 or f"to_apply={target}" in line_n)
                    if is_fusion and target not in internal:
                        internal.add(target)
                        changed = True
                    m_new = base * (trips if is_body else 1.0)
                    if m_new > mult.get(target, 0.0):
                        mult[target] = m_new
                        changed = True
                bm = _BRANCHES.search(ins.line)
                if bm:
                    for target in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                        if target in comps and base > mult.get(target, 0.0):
                            mult[target] = base
                            changed = True
        if not changed:
            break
    return mult, internal


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    out_elems = 1
    for _, dims in _parse_shapes(ins.result_type):
        for d in dims:
            out_elems *= d
    ops = _operands(ins.line)
    if not ops:
        return 0.0
    lhs_type = shapes.get(ops[0], "")
    lhs_shapes = _parse_shapes(lhs_type)
    if not lhs_shapes:
        return 0.0
    lhs_dims = lhs_shapes[0][1]
    cm = _DOT_ATTR.search(ins.line)
    contract = 1
    if cm:
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


_SKIP_BYTES_OPS = ("tuple", "get-tuple-element", "parameter", "constant",
                   "bitcast", "while", "call", "iota", "after-all",
                   "conditional", "custom-call")

# ops that move no data under XLA's own HloCostAnalysis accounting
_SKIP_BYTES_OPS_XLA = ("tuple", "get-tuple-element", "parameter", "constant",
                       "bitcast")


def _instr_bytes_xla(ins: Instr, shapes: Dict[str, str]) -> float:
    """XLA-compatible bytes for one instruction: result + every operand,
    no HBM-traffic modelling (no gather/update discounts, scalars counted).
    This reproduces Compiled.cost_analysis()["bytes accessed"] on unrolled
    graphs — the ground truth the tests compare against — while
    :func:`_instr_bytes` keeps the HBM-approximation the roofline uses."""
    if ins.op in _SKIP_BYTES_OPS_XLA:
        return 0.0
    return _shape_bytes(ins.result_type) + sum(
        _shape_bytes(shapes.get(o, "")) for o in _operands(ins.line))


def _instr_bytes(ins: Instr, shapes: Dict[str, str]) -> float:
    """Approximate HBM bytes for one instruction (matches XLA's
    bytes-accessed semantics for the patterns this workload emits):

      * slice-like ops (dynamic-slice / gather, incl. fusions rooted at
        them): 2 x slice size — the big operand is NOT streamed;
      * update-like ops (dynamic-update-slice, scatter, incl. fusions):
        2 x smallest non-scalar operand (the update) — the result aliases
        the big buffer in place;
      * everything else: result + operands (post-fusion HLO, so elementwise
        chains are single instructions and intermediates don't hit HBM).
    """
    if ins.op in _SKIP_BYTES_OPS:
        return 0.0
    tag = ins.name + " " + ins.op
    result = _shape_bytes(ins.result_type)
    op_bytes = [_shape_bytes(shapes.get(o, "")) for o in _operands(ins.line)]
    op_bytes = [b for b in op_bytes if b > 4]       # drop scalars/indices
    if "dynamic-update-slice" in tag or "scatter" in tag:
        upd = min(op_bytes) if op_bytes else result
        return 2.0 * upd
    if "dynamic-slice" in tag or "gather" in tag:
        return 2.0 * result
    return result + sum(op_bytes)


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return default


@dataclasses.dataclass
class HloCost:
    flops: float                    # per chip, loop-corrected
    bytes_accessed: float           # per chip, loop-corrected (HBM approx)
    bytes_accessed_xla: float       # loop-corrected, XLA visitor accounting
    collective: Dict[str, float]    # per chip bytes moved, by kind
    collective_total: float
    dots: int
    loops: Dict[str, float]         # multiplier per computation (diagnostics)


def analyze(hlo: str, n_chips: int) -> HloCost:
    comps = parse_computations(hlo)
    mult, internal = _multipliers(comps)
    flops = 0.0
    bytes_acc = 0.0
    bytes_xla = 0.0
    coll: Dict[str, float] = {"all-gather": 0.0, "all-reduce": 0.0,
                              "reduce-scatter": 0.0, "all-to-all": 0.0,
                              "collective-permute": 0.0}
    n_dots = 0
    seen_async: set = set()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0.0:
            continue
        for ins in comp.instrs:
            if ins.op == "dot":
                f = _dot_flops(ins, comp.shapes)
                flops += m * f
                n_dots += 1
            if cname not in internal:
                bytes_acc += m * _instr_bytes(ins, comp.shapes)
                bytes_xla += m * _instr_bytes_xla(ins, comp.shapes)
            km = _COLL_KIND.search(ins.line)
            if km and "-done" not in ins.line.split("=")[1][:60]:
                kind = km.group(1)
                key = (cname, ins.name.replace("-start", ""))
                if key in seen_async:
                    continue
                seen_async.add(key)
                size = _shape_bytes(ins.result_type)
                g = _group_size(ins.line, n_chips)
                if g <= 1:
                    continue
                if kind == "all-gather":
                    moved = size * (g - 1) / g
                elif kind == "reduce-scatter":
                    moved = size * (g - 1)
                elif kind == "all-reduce":
                    moved = 2 * size * (g - 1) / g
                elif kind == "all-to-all":
                    moved = size * (g - 1) / g
                else:
                    moved = size
                coll[kind] += m * moved
    return HloCost(
        flops=flops, bytes_accessed=bytes_acc, bytes_accessed_xla=bytes_xla,
        collective=coll, collective_total=sum(coll.values()), dots=n_dots,
        loops={k: v for k, v in mult.items() if v > 1.0})
