"""Generate the EXPERIMENTS.md roofline tables from dry-run JSON records.

    PYTHONPATH=src python -m repro.roofline.report [--dir results/dryrun]
"""
import argparse
import glob
import json
import os
from typing import Dict, List


def load(results_dir: str) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*", "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b: float) -> str:
    return f"{b/1e9:.2f}"


def table(recs: List[Dict], mesh_kind: str) -> str:
    rows = []
    header = ("| arch | shape | kind | compute s | memory s | coll s | "
              "dominant | useful | mem GB/dev | MFU-UB |\n"
              "|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("mesh_kind") != mesh_kind:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r.get('arch','?')} | {r.get('shape','?')} | — | "
                        f"SKIP | | | | | | |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r.get('arch','?')} | {r.get('shape','?')} | — | "
                        f"ERROR | | | | | | |")
            continue
        rl = r["roofline"]
        mem = r["memory"].get("total_bytes_per_device", 0) / 1e9
        mfu = r.get("mfu_upper_bound", 0.0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {rl['compute_s']:.4f} | {rl['memory_s']:.4f} "
            f"| {rl['collective_s']:.4f} | **{rl['dominant']}** "
            f"| {rl['useful_ratio']:.2f} | {mem:.1f} "
            f"| {mfu:.3f} |")
    return header + "\n" + "\n".join(sorted(rows))


def summary(recs: List[Dict]) -> str:
    ok = [r for r in recs if r["status"] == "ok"]
    err = [r for r in recs if r["status"] == "error"]
    skip = [r for r in recs if r["status"] == "skipped"]
    lines = [f"- cells: {len(recs)} total, {len(ok)} compiled ok, "
             f"{len(skip)} documented skips, {len(err)} errors"]
    by_dom: Dict[str, int] = {}
    for r in ok:
        d = r["roofline"]["dominant"]
        by_dom[d] = by_dom.get(d, 0) + 1
    lines.append(f"- dominant bottleneck distribution: {by_dom}")
    worst = sorted(ok, key=lambda r: -(r.get("mfu_upper_bound") or 0))
    if worst:
        best = worst[0]
        lines.append(
            f"- best MFU upper bound: {best['arch']}/{best['shape']} "
            f"@ {best['mfu_upper_bound']:.3f}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"))
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Summary\n")
    print(summary(recs))
    for mk in ("pod", "multipod"):
        print(f"\n## {mk} mesh\n")
        print(table(recs, mk))


if __name__ == "__main__":
    main()
