"""Sharding hints for model code — explicit, launcher-controlled.

Model functions are mesh-agnostic; the launcher (dryrun/train/serve) calls
`activate(mesh)` before tracing, and `residual(x)` / `constrain(x, spec)`
become with_sharding_constraint under that mesh (no-ops otherwise, so smoke
tests on 1 device trace the same code).

`residual(x)` applies the **sequence-parallel residual stream** layout
P(batch_axes, 'model', None) between layers: the per-layer activations saved
for the backward pass shard over the TP axis, cutting saved-activation HBM by
|model| (measured 54.9 GB -> per-device feasible on the 4k train dry-run; see
EXPERIMENTS.md §Perf).  GSPMD inserts the all-gather before attention/MLP and
the reduce-scatter after — the Megatron-SP schedule.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_ACTIVE: dict = {"axis_names": (), "axis_sizes": {}}


def activate(mesh) -> None:
    _ACTIVE["axis_names"] = tuple(mesh.axis_names)
    _ACTIVE["axis_sizes"] = {a: int(mesh.shape[a]) for a in mesh.axis_names}


def deactivate() -> None:
    _ACTIVE["axis_names"] = ()
    _ACTIVE["axis_sizes"] = {}


def batch_axes() -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in _ACTIVE["axis_names"])


def axis_size(axes) -> int:
    if not axes:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= _ACTIVE["axis_sizes"].get(a, 1)
    return n


def active() -> bool:
    return bool(_ACTIVE["axis_names"])


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint if the axes exist and divide the dims."""
    if not active():
        return x
    parts = []
    for dim, axes in zip(x.shape, spec):
        if axes is None:
            parts.append(None)
            continue
        ax = tuple(a for a in ((axes,) if isinstance(axes, str) else axes)
                   if a in _ACTIVE["axis_names"])
        if ax and dim % axis_size(ax) == 0:
            parts.append(ax if len(ax) > 1 else ax[0])
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(x, P(*parts))


def residual(x: jax.Array) -> jax.Array:
    """Sequence-parallel residual stream: (B, S, d) -> P(batch, model, None)."""
    if not active() or x.ndim != 3:
        return x
    return constrain(x, batch_axes(), "model", None)


def gathered(x: jax.Array) -> jax.Array:
    """Layer-entry activation layout: P(batch, None, None).  Together with
    `residual` this forms the Megatron-SP schedule: all-gather(seq) once at
    layer entry, reduce-scatter at exit — instead of per-matmul resharding."""
    if not active() or x.ndim != 3:
        return x
    return constrain(x, batch_axes(), None, None)


def attn_heads(t: jax.Array) -> jax.Array:
    """TP layout for (B, S, H, hd) attention tensors: heads over `model` when
    divisible, else fully replicated heads (batch-parallel attention — no
    waste since batch already shards over the batch axes)."""
    if not active() or t.ndim != 4:
        return t
    tp = axis_size("model")
    if t.shape[2] % tp == 0:
        return constrain(t, batch_axes(), None, "model", None)
    return constrain(t, batch_axes(), None, None, None)
