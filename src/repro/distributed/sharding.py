"""Mesh-axis sharding rules: params, optimizer state (ZeRO-1), batches, caches.

Axis convention (launch/mesh.py): `model` is the TP/EP axis (16), `data`
(+`pod`) are the batch/FSDP/ZeRO axes.  Rules follow DESIGN.md §4:

  * TP on attention head / FFN feature dims when divisible by |model|,
    head_dim fallback otherwise (qwen1.5's 20 heads);
  * KV projections replicated when n_kv < |model| (granite MQA);
  * MoE experts sharded over `model` (EP); the 1T config additionally
    FSDP-shards expert weights over `data`;
  * ZeRO-1: optimizer moments take the param spec plus a `data`(+`pod`)
    sharding on the first still-free divisible dim — GSPMD then lowers the
    gradient reduction as reduce-scatter + per-shard update + all-gather;
  * caches: batch over (`pod`,`data`) when divisible, else sequence; KV heads
    over `model` when divisible, else sequence over `model`.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES

FSDP_PARAM_THRESHOLD = 100e9      # params above this FSDP-shard over `data`


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def param_spec(cfg: ArchConfig, mesh: Mesh, path: str, shape: Tuple[int, ...]
               ) -> P:
    tp = "model" if "model" in mesh.axis_names else None
    tp_n = axis_size(mesh, tp)
    name = path.split("/")[-1]
    div = lambda dim: tp is not None and shape[dim] % tp_n == 0

    if name in ("embed",):
        return P(tp if div(0) else None, None)
    if name == "lm_head":
        return P(None, tp if div(1) else None)
    if name == "heads":                    # (C, d, V) audio heads
        return P(None, None, tp if div(2) else None)
    if name == "pos_embed":
        return P(None, None)
    if name in ("scale", "bias", "a_log", "d_skip", "dt_bias", "norm_scale",
                "conv_bx", "conv_bb", "conv_bc"):
        return P(*([None] * len(shape)))
    if name == "router":
        return P(None, None)
    if "moe" in path and "shared" not in path and name in ("wi_gate",
                                                           "wi_up", "wo"):
        # EP over `model`; the 1T config additionally FSDP-shards the
        # d_model dim over the batch axes (params cannot fit TP-only)
        fsdp = cfg.param_count() > FSDP_PARAM_THRESHOLD
        baxes = batch_axes(mesh)
        dax: Any = None
        if fsdp and baxes and shape[1] % axis_size(mesh, baxes) == 0:
            dax = baxes if len(baxes) > 1 else baxes[0]
        return P(tp if shape[0] % tp_n == 0 else None, dax, None)
    if name in ("wq", "wk", "wv", "wi", "wi_gate", "wi_up",
                "wz", "wx", "wb", "wc", "wdt"):
        return P(None, tp if div(1) else None)
    if name in ("wo", "out_proj"):
        return P(tp if div(0) else None, None)
    if name in ("bq", "bk", "bv"):
        return P(tp if div(0) else None)
    if name in ("conv_wx", "conv_wb", "conv_wc"):   # (K, C)
        return P(None, tp if div(1) else None)
    return P(*([None] * len(shape)))


def param_specs(cfg: ArchConfig, mesh: Mesh, params_shape: Any) -> Any:
    """PartitionSpec tree for a params(-shaped) tree.

    Stacked layer params have a leading layer dim: rules apply to the
    trailing dims.  We detect stacking by path prefix ('layers'/'tail').
    """
    def rule(path, leaf):
        ps = _path_str(path)
        shape = tuple(leaf.shape)
        stack_dims = 0
        if ps.startswith("layers/") or ps.startswith("tail/"):
            stack_dims = 2 if cfg.family == "hybrid" and ps.startswith("layers/") else 1
        spec = param_spec(cfg, mesh, ps, shape[stack_dims:])
        return P(*([None] * stack_dims), *spec)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_state_specs(cfg: ArchConfig, mesh: Mesh, opt_state_shape: Any) -> Any:
    """ZeRO-1: every moment leaf shards over the batch axes on its first
    divisible dim and over `model` on the next (the moment update is
    elementwise, so any dims work — including the scan-stacked layer dim).
    GSPMD then lowers the gradient reduction feeding each shard as
    reduce-scatter."""
    baxes = batch_axes(mesh)
    bsize = axis_size(mesh, baxes)
    tp = "model" if "model" in mesh.axis_names else None
    tp_n = axis_size(mesh, tp)

    def widen(path, leaf):
        if leaf.ndim == 0:
            return P()
        shape = tuple(leaf.shape)
        parts: list = [None] * len(shape)
        want = [baxes if len(baxes) > 1 else baxes[0]] + ([tp] if tp else [])
        sizes = [bsize] + ([tp_n] if tp else [])
        j = 0
        for i, dim in enumerate(shape):
            if j >= len(want):
                break
            if dim % sizes[j] == 0 and dim >= max(sizes[j], 2):
                parts[i] = want[j]
                j += 1
        return P(*parts)

    return jax.tree_util.tree_map_with_path(widen, opt_state_shape)


# ----------------------------------------------------------------------------
# Batch / cache shardings
# ----------------------------------------------------------------------------

def batch_specs(cfg: ArchConfig, mesh: Mesh, shape_name: str) -> Dict[str, Any]:
    seq, batch, kind = SHAPES[shape_name]
    baxes = batch_axes(mesh)
    bsize = axis_size(mesh, baxes)
    b_ax = baxes if batch % bsize == 0 else None
    tp = "model" if "model" in mesh.axis_names else None
    tp_n = axis_size(mesh, tp)

    if kind in ("train", "prefill"):
        specs: Dict[str, Any] = {}
        if cfg.family == "audio":
            specs["frame_embeds"] = P(b_ax, None, None)
            if kind == "train":
                specs["codes"] = P(b_ax, None, None)
            return specs
        specs["tokens"] = P(b_ax, None)
        if cfg.family == "vlm":
            specs["image_embeds"] = P(b_ax, None, None)
            specs["positions"] = P(None, b_ax, None)
        if kind == "train":
            specs["labels"] = P(b_ax, None)
        return specs

    # decode: one token + cache
    specs = {"cache_index": P()}
    if cfg.family == "audio":
        specs["frame_embeds"] = P(b_ax, None, None)
    else:
        specs["tokens"] = P(b_ax, None)
    if cfg.family == "vlm":
        specs["positions"] = P(None, b_ax, None)
    cache: Dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "audio", "vlm", "hybrid"):
        kv_div = cfg.n_kv_heads % tp_n == 0 if tp else False
        if b_ax is not None:
            s_ax = None if kv_div else tp
            kv_ax = tp if kv_div else None
            cache["k"] = P(None, b_ax, s_ax, kv_ax, None)
        else:
            # B too small: sequence takes the batch axes (+model if KV
            # unshardable)
            s_ax = baxes + ((tp,) if (tp and not kv_div) else ())
            kv_ax = tp if kv_div else None
            cache["k"] = P(None, None, s_ax, kv_ax, None)
        cache["v"] = cache["k"]
    if cfg.family in ("ssm", "hybrid"):
        h_div = cfg.ssm_heads % tp_n == 0 if tp else False
        c_tot = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        cache["ssm"] = P(None, b_ax, tp if h_div else None, None, None)
        cache["conv"] = P(None, b_ax, None,
                          tp if c_tot % tp_n == 0 else None)
    specs["cache"] = cache
    return specs


def logical_to_shardings(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda s: isinstance(s, P))
