"""Distributed LiFE: 2-D (voxel x fiber) mesh partition of SBBNNLS.

The paper's computation partitioning (§4.1.3) lifted from threads to the
device mesh (its MPI-LiFE comparison point, §7.1.3, rebuilt jax-native):

  * voxel ranges shard over the batch axes (`pod`,`data`) — R row groups,
  * fiber ranges shard over `model`                        — C col groups,
  * each device owns the Phi coefficients in its (voxel-range x fiber-range)
    cell, TWICE (voxel-sorted for DSC, fiber-sorted for WC — the per-op
    restructuring), with *localized* indices,
  * DSC: local sorted-segment-sum -> psum over `model`  (fiber reduction),
  * WC : local sorted-segment-sum -> psum over rows     (voxel reduction),
  * SBBNNLS dot products: local vdot + psum over the axis the operand is
    sharded on (w-like: `model`; y-like: rows).

Boundaries are equal-nnz and snapped to sub-vector boundaries
(inspector.shard_boundaries via formats/shard.py:partition_cuts) — the
synchronization-free mapping of §4.2.1.2 at mesh granularity; padding
coefficients carry value 0 and are inert through both ops and the solver.

Cell materialization goes through the PhiFormat subsystem (DESIGN.md §9):
:func:`build_life_shards` and the registry's ``shard``/``shard-sell``
executors encode each (voxel-range x fiber-range) cell with
``formats/shard.py:ShardPhi`` — inner sorted-COO cells for the segment-sum
path here, inner SELL tiles for :func:`make_sharded_sell_ops`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.sbbnnls import projected_gradient
from repro.core.std import PhiTensor
from repro.data.dmri import LifeProblem

Array = jax.Array


@dataclasses.dataclass
class LifeShards:
    """Host-side 2-D partition (R x C cells, padded to common sizes)."""
    # each (R, C, nnz_max) int32/float32; *_local indices are cell-relative
    dsc_atoms: np.ndarray
    dsc_voxels_local: np.ndarray
    dsc_fibers_local: np.ndarray
    dsc_values: np.ndarray
    wc_atoms: np.ndarray
    wc_voxels_local: np.ndarray
    wc_fibers_local: np.ndarray
    wc_values: np.ndarray
    nv_local: int
    nf_local: int
    n_theta: int
    R: int
    C: int
    voxel_cuts: np.ndarray      # (R+1,) global voxel boundaries
    fiber_cuts: np.ndarray      # (C+1,)


def build_life_shards(phi: PhiTensor, n_theta: int, R: int, C: int,
                      cache=None) -> LifeShards:
    """Materialize the 2-D partition through the format subsystem.

    Both per-op layouts (voxel-sorted for DSC, fiber-sorted for WC) are
    :class:`~repro.formats.shard.ShardPhi` encodes over inner COO cells —
    the partition boundaries come from one shared
    :func:`~repro.formats.shard.partition_cuts` plan (persistent-cache-backed
    when ``cache`` is given), so this function is now a thin adapter from
    the PhiFormat world to the historical LifeShards operand names.
    """
    from repro.formats.shard import encode_pair, partition_cuts

    plan = partition_cuts(phi, R, C, cell_format="coo", cache=cache)
    dsc, wc = encode_pair(phi, cell_format="coo", plan=plan)
    return LifeShards(
        dsc_atoms=dsc.arrays["atoms"], dsc_voxels_local=dsc.arrays["voxels"],
        dsc_fibers_local=dsc.arrays["fibers"], dsc_values=dsc.arrays["values"],
        wc_atoms=wc.arrays["atoms"], wc_voxels_local=wc.arrays["voxels"],
        wc_fibers_local=wc.arrays["fibers"], wc_values=wc.arrays["values"],
        nv_local=plan.nv_local, nf_local=plan.nf_local, n_theta=n_theta,
        R=R, C=C, voxel_cuts=plan.voxel_cuts, fiber_cuts=plan.fiber_cuts)


def shard_b(shards: LifeShards, b: np.ndarray) -> np.ndarray:
    """(Nv, Ntheta) -> (R * nv_local, Ntheta) row-padded layout."""
    out = np.zeros((shards.R * shards.nv_local, b.shape[1]), b.dtype)
    for r in range(shards.R):
        lo, hi = shards.voxel_cuts[r], shards.voxel_cuts[r + 1]
        out[r * shards.nv_local: r * shards.nv_local + (hi - lo)] = b[lo:hi]
    return out


def shard_w(shards: LifeShards, w: np.ndarray) -> np.ndarray:
    out = np.zeros((shards.C * shards.nf_local,), w.dtype)
    for c in range(shards.C):
        lo, hi = shards.fiber_cuts[c], shards.fiber_cuts[c + 1]
        out[c * shards.nf_local: c * shards.nf_local + (hi - lo)] = w[lo:hi]
    return out


def unshard_w(shards: LifeShards, w_padded: np.ndarray) -> np.ndarray:
    segs = []
    for c in range(shards.C):
        lo, hi = shards.fiber_cuts[c], shards.fiber_cuts[c + 1]
        segs.append(w_padded[c * shards.nf_local:
                             c * shards.nf_local + (hi - lo)])
    return np.concatenate(segs)


# ----------------------------------------------------------------------------
# shard_map SBBNNLS
# ----------------------------------------------------------------------------

def _row_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_sharded_step(mesh: Mesh, shards_meta: Dict[str, int],
                      use_reduce_scatter: bool = False):
    """Builds the jit-able distributed SBBNNLS iteration.

    shards_meta: dict(nv_local=, nf_local=, n_theta=).
    Inputs (global layouts):
      phi cell arrays: (R, C, nnz) sharded (rows, model, None)
      d:        (Na, Ntheta) replicated
      b:        (R*nv_local, Ntheta) sharded (rows, None)
      w:        (C*nf_local,) sharded (model,)
      it:       scalar int32
    Returns (w_new, loss) with the same shardings.
    """
    rows = _row_axes(mesh)
    nv_l = shards_meta["nv_local"]
    nf_l = shards_meta["nf_local"]

    cell = P(rows, "model", None)
    yspec = P(rows, None)
    wspec = P("model")

    def dsc_local(a, v, f, w_vals, d, w_loc):
        scaled = jnp.take(w_loc, f) * w_vals
        contrib = jnp.take(d, a, axis=0) * scaled[:, None]
        y = jax.ops.segment_sum(contrib, v, num_segments=nv_l,
                                indices_are_sorted=True)
        return jax.lax.psum(y, "model")

    def wc_local(a, v, f, w_vals, d, y_loc):
        dots = jnp.einsum("ct,ct->c", jnp.take(d, a, axis=0),
                          jnp.take(y_loc, v, axis=0))
        w = jax.ops.segment_sum(dots * w_vals, f, num_segments=nf_l,
                                indices_are_sorted=True)
        return jax.lax.psum(w, rows)

    def dot_y(x, y):
        return jax.lax.psum(jnp.vdot(x, y), rows)

    def dot_w(x, y):
        return jax.lax.psum(jnp.vdot(x, y), "model")

    def step(da, dv, df, dw, wa, wv, wf, ww, d, b_loc, w_loc, it):
        # squeeze the per-device cell dims
        sq = lambda x: x.reshape(x.shape[-1])
        da, dv, df, dw = map(sq, (da, dv, df, dw))
        wa, wv, wf, ww = map(sq, (wa, wv, wf, ww))
        w_loc = w_loc.reshape(-1)
        b2 = b_loc.reshape(b_loc.shape[-2], b_loc.shape[-1])

        y = dsc_local(da, dv, df, dw, d, w_loc) - b2          # DSC
        g = wc_local(wa, wv, wf, ww, d, y)                    # WC
        gt = projected_gradient(w_loc, g)
        v = dsc_local(da, dv, df, dw, d, gt)                  # DSC

        def odd(_):
            return _safe(dot_w(gt, gt), dot_y(v, v))

        def even(_):
            vv = wc_local(wa, wv, wf, ww, d, v)               # WC
            vv = projected_gradient(w_loc, vv)
            return _safe(dot_y(v, v), dot_w(vv, vv))

        alpha = jax.lax.cond(it % 2 == 1, odd, even, operand=None)
        w_new = jnp.maximum(w_loc - alpha * gt, 0.0)
        loss = 0.5 * dot_y(y, y)
        return w_new, loss

    specs_in = (cell, cell, cell, cell, cell, cell, cell, cell,
                P(None, None), yspec, wspec, P())
    specs_out = (P("model"), P())
    return compat.shard_map(step, mesh=mesh, in_specs=specs_in,
                            out_specs=specs_out)


def make_sharded_ops(mesh: Mesh, shards_meta: Dict[str, int]):
    """Per-op shard_map'd SpMVs for the executor-registry `shard` path.

    Same cell layout and collectives as :func:`make_sharded_step`, but
    exposed as standalone DSC / WC closures so the registry can bind them to
    the single-process matvec/rmatvec protocol (the solver then runs
    undistributed while each SpMV fans out over the mesh).

    Returns (dsc_fn, wc_fn):
      dsc_fn(a, v, f, vals, d, w_padded)  -> (R*nv_local, Ntheta)
      wc_fn(a, v, f, vals, d, y_padded)   -> (C*nf_local,)
    """
    rows = _row_axes(mesh)
    nv_l = shards_meta["nv_local"]
    nf_l = shards_meta["nf_local"]
    cell = P(rows, "model", None)

    def dsc_op(a, v, f, vals, d, w_loc):
        sq = lambda x: x.reshape(x.shape[-1])
        a, v, f, vals = map(sq, (a, v, f, vals))
        scaled = jnp.take(w_loc.reshape(-1), f) * vals
        contrib = jnp.take(d, a, axis=0) * scaled[:, None]
        y = jax.ops.segment_sum(contrib, v, num_segments=nv_l,
                                indices_are_sorted=True)
        return jax.lax.psum(y, "model")

    def wc_op(a, v, f, vals, d, y_loc):
        sq = lambda x: x.reshape(x.shape[-1])
        a, v, f, vals = map(sq, (a, v, f, vals))
        y2 = y_loc.reshape(y_loc.shape[-2], y_loc.shape[-1])
        dots = jnp.einsum("ct,ct->c", jnp.take(d, a, axis=0),
                          jnp.take(y2, v, axis=0))
        w = jax.ops.segment_sum(dots * vals, f, num_segments=nf_l,
                                indices_are_sorted=True)
        return jax.lax.psum(w, rows)

    dsc_fn = compat.shard_map(
        dsc_op, mesh=mesh,
        in_specs=(cell, cell, cell, cell, P(None, None), P("model")),
        out_specs=P(rows, None))
    wc_fn = compat.shard_map(
        wc_op, mesh=mesh,
        in_specs=(cell, cell, cell, cell, P(None, None), P(rows, None)),
        out_specs=P("model"))
    return dsc_fn, wc_fn


def make_sharded_sell_ops(mesh: Mesh, shards_meta: Dict[str, int], *,
                          row_tile: int, slot_tile: int, out_dtype=None,
                          interpret: bool = True):
    """shard_map'd SpMVs over per-cell SELL tiles (the `shard-sell` path).

    Same mesh layout and collectives as :func:`make_sharded_ops`, but each
    device's cell is a blocked-ELL slot array feeding the existing Pallas
    SELL kernels (``kernels/dsc.py:dsc_sell_pallas`` /
    ``kernels/wc.py:wc_sell_pallas``) instead of a sorted-COO segment sum —
    the DESIGN.md §7 fast path lifted to mesh granularity (§9).

    Inputs (global layouts; ``T_p`` = lane-padded Ntheta):
      cell slot arrays: (R, C, rows_padded, width) sharded (rows, model, ., .)
      d_padded:         (Na, T_p) replicated
      w:                (C*nf_local,) sharded (model,)     [dsc]
      y_padded:         (R*nv_local, T_p) sharded (rows,)  [wc]
    Returns (dsc_fn, wc_fn):
      dsc_fn(atoms, fibers, values, d_padded, w)  -> (R*nv_local, T_p)
      wc_fn(atoms, voxels, values, d_padded, y)   -> (C*nf_local,)
    """
    from repro.kernels import dsc as dsc_kernel
    from repro.kernels import wc as wc_kernel

    rows = _row_axes(mesh)
    nv_l = shards_meta["nv_local"]
    nf_l = shards_meta["nf_local"]
    cell = P(rows, "model", None, None)
    sq = lambda x: x.reshape(x.shape[-2], x.shape[-1])

    def dsc_op(a, f, vals, d, w_loc):
        a, f, vals = map(sq, (a, f, vals))
        scaled = jnp.take(w_loc.reshape(-1), f) * vals   # padding slots stay 0
        y = dsc_kernel.dsc_sell_pallas(
            a, scaled, d, row_tile=row_tile, slot_tile=slot_tile,
            out_dtype=out_dtype, interpret=interpret)
        return jax.lax.psum(y[:nv_l], "model")

    def wc_op(a, v, vals, d, y_loc):
        a, v, vals = map(sq, (a, v, vals))
        y2 = y_loc.reshape(y_loc.shape[-2], y_loc.shape[-1])
        # pre-gather of local Y rows; padding slots gather row 0, value 0
        yg = jnp.take(y2, v, axis=0)
        w = wc_kernel.wc_sell_pallas(
            a, yg, vals, d, row_tile=row_tile, slot_tile=slot_tile,
            out_dtype=out_dtype, interpret=interpret)
        return jax.lax.psum(w.reshape(-1)[:nf_l], rows)

    dsc_fn = compat.shard_map(
        dsc_op, mesh=mesh,
        in_specs=(cell, cell, cell, P(None, None), P("model")),
        out_specs=P(rows, None))
    wc_fn = compat.shard_map(
        wc_op, mesh=mesh,
        in_specs=(cell, cell, cell, P(None, None), P(rows, None)),
        out_specs=P("model"))
    return dsc_fn, wc_fn


def _safe(num, den):
    return jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)


def make_sharded_step_1d(mesh: Mesh, shards_meta: Dict[str, int]):
    """Paper-faithful 1-D coefficient partitioning (the MPI-LiFE analogue,
    §7.1.3): every device owns a coefficient block; Y and w are REPLICATED
    and every SpMV ends in a psum over the whole mesh.  This is the
    §Perf baseline the 2-D (voxel x fiber) partition improves on: its
    collective volume scales with the full Y and w vectors instead of the
    per-shard outputs.

    Inputs: coefficient block arrays shaped (n_dev, nnz_cell) sharded over
    all axes on dim 0; d, b (Nv, Ntheta), w (Nf,) replicated.
    """
    all_axes = _row_axes(mesh) + ("model",)
    nv = shards_meta["n_voxels"]
    nf = shards_meta["n_fibers"]

    def dsc_local(a, v, f, vals, d, w):
        scaled = jnp.take(w, f) * vals
        contrib = jnp.take(d, a, axis=0) * scaled[:, None]
        y = jax.ops.segment_sum(contrib, v, num_segments=nv,
                                indices_are_sorted=True)
        return jax.lax.psum(y, all_axes)              # full-Y reduction

    def wc_local(a, v, f, vals, d, y):
        dots = jnp.einsum("ct,ct->c", jnp.take(d, a, axis=0),
                          jnp.take(y, v, axis=0))
        w = jax.ops.segment_sum(dots * vals, f, num_segments=nf,
                                indices_are_sorted=False)
        return jax.lax.psum(w, all_axes)              # full-w reduction

    def step(a, v, f, vals, d, b, w, it):
        sq = lambda x: x.reshape(x.shape[-1])
        a, v, f, vals = map(sq, (a, v, f, vals))
        y = dsc_local(a, v, f, vals, d, w) - b
        g = wc_local(a, v, f, vals, d, y)
        gt = projected_gradient(w, g)
        vv1 = dsc_local(a, v, f, vals, d, gt)

        def odd(_):
            return _safe(jnp.vdot(gt, gt), jnp.vdot(vv1, vv1))

        def even(_):
            vv2 = projected_gradient(w, wc_local(a, v, f, vals, d, vv1))
            return _safe(jnp.vdot(vv1, vv1), jnp.vdot(vv2, vv2))

        alpha = jax.lax.cond(it % 2 == 1, odd, even, operand=None)
        w_new = jnp.maximum(w - alpha * gt, 0.0)
        return w_new, 0.5 * jnp.vdot(y, y)

    cell = P(all_axes, None)
    return compat.shard_map(
        step, mesh=mesh,
        in_specs=(cell, cell, cell, cell, P(None, None), P(None, None),
                  P(None), P()),
        out_specs=(P(None), P()))


def life_input_specs_1d(mesh: Mesh, *, n_voxels: int = 247_356,
                        n_fibers: int = 500_000, n_theta: int = 96,
                        n_atoms: int = 1_024, nnz: int = 400_000_000):
    n_dev = int(mesh.devices.size)
    nnz_cell = -(-nnz // n_dev)
    f = jax.ShapeDtypeStruct
    return dict(
        a=f((n_dev, nnz_cell), jnp.int32), v=f((n_dev, nnz_cell), jnp.int32),
        fi=f((n_dev, nnz_cell), jnp.int32),
        vals=f((n_dev, nnz_cell), jnp.float32),
        d=f((n_atoms, n_theta), jnp.float32),
        b=f((n_voxels, n_theta), jnp.float32),
        w=f((n_fibers,), jnp.float32), it=f((), jnp.int32),
        meta=dict(n_voxels=n_voxels, n_fibers=n_fibers, n_theta=n_theta),
    )


def sharded_state(mesh: Mesh, shards: LifeShards, problem: LifeProblem,
                  w0: Optional[np.ndarray] = None):
    """device_put the shard tensors under the mesh shardings."""
    rows = _row_axes(mesh)
    cell = NamedSharding(mesh, P(rows, "model", None))
    ysh = NamedSharding(mesh, P(rows, None))
    wsh = NamedSharding(mesh, P("model"))
    rep = NamedSharding(mesh, P(None, None))
    put = jax.device_put
    args = dict(
        da=put(shards.dsc_atoms, cell), dv=put(shards.dsc_voxels_local, cell),
        df=put(shards.dsc_fibers_local, cell), dw=put(shards.dsc_values, cell),
        wa=put(shards.wc_atoms, cell), wv=put(shards.wc_voxels_local, cell),
        wf=put(shards.wc_fibers_local, cell), ww=put(shards.wc_values, cell),
        d=put(np.asarray(problem.dictionary), rep),
        b=put(shard_b(shards, np.asarray(problem.b)), ysh),
        w=put(shard_w(shards, w0 if w0 is not None else
                      np.ones(problem.phi.n_fibers, np.float32)), wsh),
    )
    return args


def life_input_specs(mesh: Mesh, *, n_voxels: int = 247_356,
                     n_fibers: int = 500_000, n_theta: int = 96,
                     n_atoms: int = 1_024, nnz: int = 400_000_000
                     ) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins at paper scale (Table 9, iFOD1/500k) for the
    dry-run: 2.5e5 voxels, 5e5 fibers, 4e8 coefficients."""
    rows = _row_axes(mesh)
    R = int(np.prod([mesh.shape[a] for a in rows]))
    C = int(mesh.shape["model"])
    nv_l = -(-n_voxels // R)
    nf_l = -(-n_fibers // C)
    nnz_cell = -(-nnz // (R * C))
    f = jax.ShapeDtypeStruct
    cell_i = lambda: f((R, C, nnz_cell), jnp.int32)
    cell_f = lambda: f((R, C, nnz_cell), jnp.float32)
    return dict(
        da=cell_i(), dv=cell_i(), df=cell_i(), dw=cell_f(),
        wa=cell_i(), wv=cell_i(), wf=cell_i(), ww=cell_f(),
        d=f((n_atoms, n_theta), jnp.float32),
        b=f((R * nv_l, n_theta), jnp.float32),
        w=f((C * nf_l,), jnp.float32),
        it=f((), jnp.int32),
        meta=dict(nv_local=nv_l, nf_local=nf_l, n_theta=n_theta),
    )
