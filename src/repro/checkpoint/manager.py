"""Checkpoint manager: atomic save, restore, reshard-on-load, retention.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, written to a `.tmp`
sibling and atomically renamed (crash mid-save never corrupts the latest
checkpoint).  Restore returns host numpy trees; `place()` re-device_puts them
under *any* mesh/sharding — that is the elastic-restart path: a job restarted
on a different device count reshards transparently (DESIGN.md §5).

At real scale this module's role is played by per-host array shards
(tensorstore/OCDBT); the manifest/atomic-rename/reshard logic is the part
that carries over and is what the fault-tolerance tests exercise.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

SEP = "/"

# numpy .npz cannot serialize ml_dtypes types; store bit-views + a dtype map
_EXTENDED_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _flatten(tree: Any) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if str(arr.dtype) in _EXTENDED_DTYPES:
            arr = arr.view(_EXTENDED_DTYPES[str(arr.dtype)][1])
        flat[key] = arr
    return flat, dtypes


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save(ckpt_dir: str, step: int, tree: Any,
         meta: Optional[Dict[str, Any]] = None, keep: int = 3) -> str:
    """Atomic checkpoint write; prunes to the most recent `keep` steps.

    Overwrite-safe: saving a step that already exists (e.g. the service's
    final checkpoint landing on the same tick a periodic checkpoint just
    wrote) *replaces* it without ever destroying the old snapshot before
    the new one is in place — the existing directory is renamed aside to
    ``.old``, the fresh one renamed in, then the old removed.  A crash
    anywhere in that window leaves a complete snapshot on disk (the
    ``.old``/``.tmp`` suffixes are invisible to `all_steps`/`restore`)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, dtypes = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {"step": step, "n_arrays": len(flat),
                "bytes": int(sum(a.nbytes for a in flat.values())),
                "dtypes": dtypes,
                **(meta or {})}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    old = final + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    if os.path.exists(final):
        os.rename(final, old)
    os.rename(tmp, final)
    shutil.rmtree(old, ignore_errors=True)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    """Completed checkpoint steps only — in-flight ``.tmp`` and
    replaced-but-not-yet-removed ``.old`` directories are not steps."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        suffix = name[len("step_"):]
        if name.startswith("step_") and suffix.isdigit():
            out.append(int(suffix))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None
            ) -> Tuple[int, Dict[str, np.ndarray], Dict[str, Any]]:
    """Returns (step, flat arrays keyed by path, manifest)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    for key, dt in manifest.get("dtypes", {}).items():
        if dt in _EXTENDED_DTYPES and key in flat:
            flat[key] = flat[key].view(_EXTENDED_DTYPES[dt][0])
    return step, flat, manifest


def load_latest(ckpt_dir: str
                ) -> Optional[Tuple[int, Dict[str, np.ndarray], Dict[str, Any]]]:
    """`restore` of the latest step, or None when no checkpoint exists.

    The serving resume path: a freshly started service probes its checkpoint
    directory and either adopts the in-flight solver states or starts empty —
    without treating the cold-start case as an error."""
    if latest_step(ckpt_dir) is None:
        return None
    return restore(ckpt_dir)


def restore_job(ckpt_dir: str, job_id: str, step: Optional[int] = None
                ) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """One job's solver arrays + manifest meta from a service snapshot.

    Reads a :class:`~repro.serve.service.LifeService` checkpoint (arrays
    keyed ``<job_id>/<leaf>``, per-job metadata under the manifest's
    ``jobs`` map) and extracts a single job — the science workloads use
    it to warm-start an edited re-solve from the previous checkpointed
    :class:`~repro.core.sbbnnls.SbbnnlsState` without standing up a
    service (DESIGN.md §15.3).

    Args:
        ckpt_dir: the service's checkpoint directory.
        job_id: job to extract.
        step: checkpoint step (latest when None).

    Returns:
        ``(arrays, meta)`` — arrays keyed by leaf name (``w``, ``it``,
        ``loss``, optionally ``losses``), meta the job's manifest entry
        (dataset digest, format, done, ...).

    Raises:
        KeyError: when the job is not in the snapshot.
        FileNotFoundError: when no checkpoint exists.
    """
    _, flat, manifest = restore(ckpt_dir, step)
    meta = manifest.get("jobs", {}).get(job_id)
    if meta is None:
        known = sorted(manifest.get("jobs", {}))
        raise KeyError(f"job {job_id!r} not in checkpoint "
                       f"(has {known})")
    prefix = job_id + SEP
    arrays = {k[len(prefix):]: v for k, v in flat.items()
              if k.startswith(prefix)}
    return arrays, meta


def unflatten_like(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    """Rebuild a pytree shaped like `template` from restored arrays."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def place(tree: Any, shardings: Any) -> Any:
    """device_put a host tree under (possibly different-mesh) shardings —
    the reshard-on-load / elastic-restart path."""
    return jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
