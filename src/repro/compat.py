"""jax version compatibility shims (single place, imported everywhere).

The repo targets the newest jax API surface (``jax.shard_map``,
``jax.sharding.AxisType``, list-free ``cost_analysis``) but must run on the
pinned container version as well.  Every call site goes through this module
instead of feature-testing jax inline.
"""
from __future__ import annotations

from typing import Sequence

import jax


def auto_axis_types(n: int):
    """(AxisType.Auto,) * n on jax versions that have explicit-sharding
    modes; None (omit the kwarg) on versions that predate AxisType."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n


def make_mesh(shape: Sequence[int], axis_names: Sequence[str]):
    """jax.make_mesh with Auto axis types when the kwarg exists."""
    types = auto_axis_types(len(axis_names))
    if types is None:
        return jax.make_mesh(tuple(shape), tuple(axis_names))
    return jax.make_mesh(tuple(shape), tuple(axis_names), axis_types=types)


def shard_map(f, *, mesh, in_specs, out_specs):
    """Replication-check-free shard_map across the API renames:
    jax.shard_map(check_vma=) > jax.shard_map(check_rep=) >
    jax.experimental.shard_map.shard_map(check_rep=)."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    for kwargs in ({"check_vma": False}, {"check_rep": False}, {}):
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
        except TypeError:
            continue
    raise RuntimeError("no compatible shard_map signature found")


def xla_cost_analysis(compiled) -> dict:
    """Normalize Compiled.cost_analysis(): older jax returns a one-element
    list of per-partition dicts, newer returns the dict directly."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca)
