"""Background refinement queue: measured autotune demoted to spare cycles.

The predicted cold-start path answers a cache miss with zero measurements;
the measurements still happen, just not on the critical path.  When a
selector or tuner serves a ``reason="predicted"`` plan it enqueues a
refinement task here, and the serve frontend's driver thread drains one
task per idle tick (``LifeFrontend._drive``: only when no job is pending,
admitted, or active — refinement never competes with real work).  Each
task re-runs the *measured* pipeline and overwrites the plan-cache entry
in place, so the next engine rebuild replays a searched plan and the next
``train_predictor`` harvest gains a measured example.

The queue is deliberately dumb: bounded, deduplicated by ``(kind, key)``,
tasks are plain closures, and a task that raises is counted and dropped —
a refinement failure must never take down the driver thread that hosts it.
Anything (a test, a CLI, a cron job) may also drain it synchronously via
:func:`run_pending`.
"""
from __future__ import annotations

import threading
from typing import Callable, List, Optional, Set, Tuple

from repro import obs

DEFAULT_MAX_TASKS = 256


class RefineQueue:
    """Bounded, deduplicating FIFO of refinement closures."""

    def __init__(self, max_tasks: int = DEFAULT_MAX_TASKS):
        self.max_tasks = max_tasks
        self._lock = threading.Lock()
        self._tasks: List[Tuple[Tuple[str, str], Callable[[], None]]] = []
        self._keys: Set[Tuple[str, str]] = set()

    def push(self, kind: str, key: str, fn: Callable[[], None]) -> bool:
        """Enqueue ``fn`` under identity ``(kind, key)``.  Returns False
        (and drops) when the identity is already queued or the queue is
        full — re-predicting the same dataset must not duplicate work."""
        ident = (kind, key)
        with self._lock:
            if ident in self._keys or len(self._tasks) >= self.max_tasks:
                return False
            self._tasks.append((ident, fn))
            self._keys.add(ident)
        obs.counter("learn.refine.queued", kind=kind).inc()
        return True

    def run_one(self) -> bool:
        """Pop and run the oldest task; True if one ran (even if it failed)."""
        with self._lock:
            if not self._tasks:
                return False
            ident, fn = self._tasks.pop(0)
            self._keys.discard(ident)
        try:
            fn()
            obs.counter("learn.refine.completed", kind=ident[0]).inc()
        except Exception:
            # refinement is best-effort by design: the predicted plan keeps
            # serving and the task is dropped, not retried in a hot loop
            obs.counter("learn.refine.failed", kind=ident[0]).inc()
        return True

    def __len__(self) -> int:
        with self._lock:
            return len(self._tasks)

    def clear(self) -> None:
        with self._lock:
            self._tasks.clear()
            self._keys.clear()


#: process-global queue the selector/tuner push to and the frontend drains
QUEUE = RefineQueue()


def run_pending(limit: Optional[int] = None) -> int:
    """Synchronously drain up to ``limit`` tasks (all, when None)."""
    n = 0
    while (limit is None or n < limit) and QUEUE.run_one():
        n += 1
    return n
