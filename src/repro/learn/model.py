"""Dependency-free predictor over plan-cache harvests.

Two tiny models, both numpy-only (no sklearn — the container pins its
dependency set):

* :class:`CentroidClassifier` — nearest-centroid over z-scored log1p
  features.  Predicts the winning *format* (and, reused, the winning
  executor family).  Centroids degrade gracefully: prediction can be
  restricted to the caller's ``allowed`` candidate set, and returns
  ``None`` when no allowed class was ever trained — the caller falls back
  down the ladder (heuristic, then measurement) instead of guessing.
* :class:`NearestExample` — 1-nearest-neighbour lookup that replays the
  *tile params* of the most similar trained dataset.  Tile spaces are
  discrete grids keyed by executor, so regression would invent invalid
  points; copying the nearest winner's exact params is both simpler and
  always a legal configuration.

Both serialize to plain JSON (``Predictor.to_json``/``from_json``) so the
trained model lives next to the plan cache as ``predictor.json`` — readable
in a pager, diffable in review, and immune to the cache's ``.npz``-only
pruning.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .features import FEATURE_NAMES, FEATURE_SCHEMA, feature_vector

_EPS = 1e-9


def _standardize(x: np.ndarray, mean: np.ndarray, std: np.ndarray) -> np.ndarray:
    return (x - mean) / np.maximum(std, _EPS)


@dataclass
class CentroidClassifier:
    """Nearest-centroid over standardized features."""

    mean: np.ndarray
    std: np.ndarray
    labels: Tuple[str, ...]
    centroids: np.ndarray  # (n_labels, n_features), standardized space
    counts: Tuple[int, ...]

    @classmethod
    def fit(cls, x: np.ndarray, y: Sequence[str]) -> "CentroidClassifier":
        labels = tuple(sorted(set(y)))
        mean = x.mean(axis=0)
        std = x.std(axis=0)
        xs = _standardize(x, mean, std)
        cents, counts = [], []
        for lab in labels:
            mask = np.asarray([yi == lab for yi in y])
            cents.append(xs[mask].mean(axis=0))
            counts.append(int(mask.sum()))
        return cls(mean=mean, std=std, labels=labels,
                   centroids=np.asarray(cents), counts=tuple(counts))

    def predict(self, x: np.ndarray,
                allowed: Optional[Sequence[str]] = None) -> Optional[str]:
        """Closest trained class to ``x``, restricted to ``allowed``.

        Returns None when no allowed class has a centroid — the caller
        must fall back, never receive an out-of-set label.
        """
        idx = [i for i, lab in enumerate(self.labels)
               if allowed is None or lab in allowed]
        if not idx:
            return None
        xs = _standardize(np.asarray(x, np.float64), self.mean, self.std)
        d = np.linalg.norm(self.centroids[idx] - xs, axis=1)
        return self.labels[idx[int(np.argmin(d))]]

    def to_json(self) -> dict:
        return {
            "mean": self.mean.tolist(),
            "std": self.std.tolist(),
            "labels": list(self.labels),
            "centroids": self.centroids.tolist(),
            "counts": list(self.counts),
        }

    @classmethod
    def from_json(cls, obj: Mapping) -> "CentroidClassifier":
        return cls(mean=np.asarray(obj["mean"], np.float64),
                   std=np.asarray(obj["std"], np.float64),
                   labels=tuple(obj["labels"]),
                   centroids=np.asarray(obj["centroids"], np.float64),
                   counts=tuple(int(c) for c in obj["counts"]))


@dataclass
class NearestExample:
    """1-NN replay of tile params from the most similar trained dataset.

    Examples are grouped by ``(executor, backend)`` group key: a winning
    row_tile for `kernel-sell` on cpu says nothing about `kernel-fcoo`
    seg tiles, so neighbours never cross groups.
    """

    mean: np.ndarray
    std: np.ndarray
    # group key -> (features (n, f), payloads list)
    groups: Dict[str, Tuple[np.ndarray, List[dict]]] = field(default_factory=dict)

    @staticmethod
    def group_key(executor: str, backend: str) -> str:
        return f"{executor}@{backend}"

    @classmethod
    def fit(cls, x: np.ndarray, keys: Sequence[str],
            payloads: Sequence[dict]) -> "NearestExample":
        mean = x.mean(axis=0)
        std = x.std(axis=0)
        xs = _standardize(x, mean, std)
        groups: Dict[str, Tuple[np.ndarray, List[dict]]] = {}
        for key in sorted(set(keys)):
            mask = np.asarray([k == key for k in keys])
            groups[key] = (xs[mask],
                           [p for k, p in zip(keys, payloads) if k == key])
        return cls(mean=mean, std=std, groups=groups)

    def predict(self, x: np.ndarray, executor: str,
                backend: str) -> Optional[dict]:
        entry = self.groups.get(self.group_key(executor, backend))
        if entry is None:
            return None
        feats, payloads = entry
        xs = _standardize(np.asarray(x, np.float64), self.mean, self.std)
        d = np.linalg.norm(feats - xs, axis=1)
        return dict(payloads[int(np.argmin(d))])

    def to_json(self) -> dict:
        return {
            "mean": self.mean.tolist(),
            "std": self.std.tolist(),
            "groups": {k: {"features": feats.tolist(), "payloads": payloads}
                       for k, (feats, payloads) in sorted(self.groups.items())},
        }

    @classmethod
    def from_json(cls, obj: Mapping) -> "NearestExample":
        groups = {}
        for key, entry in obj["groups"].items():
            groups[key] = (np.asarray(entry["features"], np.float64),
                           [dict(p) for p in entry["payloads"]])
        return cls(mean=np.asarray(obj["mean"], np.float64),
                   std=np.asarray(obj["std"], np.float64),
                   groups=groups)


@dataclass
class Predictor:
    """Trained selection model: format classifier + tune-param replayer.

    Either half may be None when the harvest had no examples for it (e.g.
    a cache full of heuristic FormatPlans but no searched TunePlans).
    """

    format_model: Optional[CentroidClassifier] = None
    tune_model: Optional[NearestExample] = None
    n_format_examples: int = 0
    n_tune_examples: int = 0

    def predict_format(self, stats: Mapping[str, float],
                       allowed: Sequence[str]) -> Optional[str]:
        if self.format_model is None:
            return None
        x = feature_vector(stats)
        if x is None:
            return None
        return self.format_model.predict(x, allowed=allowed)

    def predict_tune(self, stats: Mapping[str, float], executor: str,
                     backend: str) -> Optional[dict]:
        if self.tune_model is None:
            return None
        x = feature_vector(stats)
        if x is None:
            return None
        return self.tune_model.predict(x, executor=executor, backend=backend)

    def to_json(self) -> dict:
        return {
            "schema": FEATURE_SCHEMA,
            "feature_names": list(FEATURE_NAMES),
            "format_model": (self.format_model.to_json()
                             if self.format_model else None),
            "tune_model": (self.tune_model.to_json()
                           if self.tune_model else None),
            "n_format_examples": self.n_format_examples,
            "n_tune_examples": self.n_tune_examples,
        }

    @classmethod
    def from_json(cls, obj: Mapping) -> Optional["Predictor"]:
        """None (not an error) on schema mismatch: an old predictor must
        be retrained, never scored against reordered features."""
        if obj.get("schema") != FEATURE_SCHEMA:
            return None
        if tuple(obj.get("feature_names", ())) != FEATURE_NAMES:
            return None
        fm = obj.get("format_model")
        tm = obj.get("tune_model")
        return cls(
            format_model=CentroidClassifier.from_json(fm) if fm else None,
            tune_model=NearestExample.from_json(tm) if tm else None,
            n_format_examples=int(obj.get("n_format_examples", 0)),
            n_tune_examples=int(obj.get("n_tune_examples", 0)),
        )
