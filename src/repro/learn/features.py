"""Feature schema for learned format/executor selection (DESIGN.md §14).

Chen et al. (arXiv:1805.11938) predict the winning SpMV format from matrix
features; ours come for free: ``core/inspector.py:phi_stats`` already
computes run-length and density statistics for every selection decision,
and the selector persists them inside each :class:`~repro.formats.base
.FormatPlan` (and, since the learn subsystem landed, each searched
:class:`~repro.tune.plan.TunePlan`).  This module pins the *order* and the
*transform* of those statistics so a model trained from harvested plans and
a predictor consulted at cold start score the exact same vector.

``FEATURE_SCHEMA`` versions the (names, transform) pair: a persisted
predictor records it, and loading refuses a mismatch — silently scoring
features in a different order would be a wrong-but-plausible prediction,
the worst failure mode a zero-measurement path can have.
"""
from __future__ import annotations

from typing import Mapping, Optional

import numpy as np

#: bump on any change to FEATURE_NAMES or the transform below
FEATURE_SCHEMA = 1

#: phi_stats keys, in scoring order (see core/inspector.py:phi_stats)
FEATURE_NAMES = (
    "n_coeffs", "nc_per_voxel", "nc_per_fiber", "nc_per_atom",
    "dsc.rows_touched", "dsc.run_mean", "dsc.run_p99", "dsc.run_max",
    "dsc.sell_width", "dsc.sell_overhead",
    "wc.rows_touched", "wc.run_mean", "wc.run_p99", "wc.run_max",
    "wc.sell_width", "wc.sell_overhead",
)


def feature_vector(stats: Mapping[str, float]) -> Optional[np.ndarray]:
    """``phi_stats`` dict -> float64 feature vector, or None when any
    feature is missing (a plan persisted before the key existed must be
    skipped by harvesting, not padded with a guess).

    Every statistic is a nonnegative magnitude (counts, widths, ratios)
    with a heavy-tailed spread across datasets, so the transform is
    ``log1p``: centroid distances then compare scale *ratios* rather than
    letting ``n_coeffs`` drown the run-length shape features.
    """
    try:
        xs = [float(stats[name]) for name in FEATURE_NAMES]
    except (KeyError, TypeError, ValueError):
        return None
    x = np.asarray(xs, np.float64)
    if not np.all(np.isfinite(x)):
        return None
    return np.log1p(np.maximum(x, 0.0))
