"""Harvest training pairs from the plan cache; train/persist the predictor.

Every measured or heuristic selection the repo makes already persists its
evidence: FormatPlans carry the ``phi_stats`` dict they were decided under,
and (since plan-cache v2) searched TunePlans do too.  Harvesting walks the
cache directory via :meth:`PlanCache.iter_plans` and turns those into
supervised pairs:

* format examples — (features, chosen format) from FormatPlans whose
  ``reason`` is "heuristic" or "autotune".  "explicit" plans are excluded
  (the user forced the format; nothing was learned about the data) and so
  are "predicted" plans (training on the model's own outputs would launder
  guesses into ground truth).
* tune examples — (features, (executor, backend), winning params + dtype)
  from TunePlans whose ``reason`` is "search".  "default"/"untuned"/
  "predicted" plans carry no measured signal.

``train_predictor`` fits the models and writes ``predictor.json`` next to
the plan entries (atomic tmp+rename, mirroring the cache's own writes; the
``.json`` suffix keeps it invisible to the cache's ``.npz``-only pruning).
``load_predictor`` memoizes by file mtime so the serving hot path pays one
stat() per cold start, not one JSON parse.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import List, Optional, Tuple

import numpy as np

from repro import obs

from .features import feature_vector
from .model import CentroidClassifier, NearestExample, Predictor

PREDICTOR_FILENAME = "predictor.json"

#: FormatPlan reasons that constitute training signal
_FORMAT_TRAIN_REASONS = ("heuristic", "autotune")
#: TunePlan reasons that constitute training signal
_TUNE_TRAIN_REASONS = ("search",)

# load memo: directory -> (mtime_ns, Predictor-or-None)
_LOAD_MEMO: dict = {}


def harvest(cache) -> Tuple[List, List]:
    """Walk ``cache`` and return (format_examples, tune_examples).

    format example: ``(x: ndarray, label: str)``
    tune example:   ``(x: ndarray, group_key: str, payload: dict)`` where
    payload is the winning tile params plus ``compute_dtype``.
    """
    fmt_examples, tune_examples = [], []
    for kind, plan in cache.iter_plans():
        x = feature_vector(plan.stats)
        if x is None:
            continue
        if kind == "format" and plan.reason in _FORMAT_TRAIN_REASONS:
            fmt_examples.append((x, plan.format))
        elif kind == "tune" and plan.reason in _TUNE_TRAIN_REASONS:
            payload = {str(k): int(v) for k, v in plan.params.items()}
            payload["compute_dtype"] = plan.compute_dtype
            key = NearestExample.group_key(plan.executor, plan.backend)
            tune_examples.append((x, key, payload))
    return fmt_examples, tune_examples


def predictor_path(directory: str) -> str:
    return os.path.join(directory, PREDICTOR_FILENAME)


def train_predictor(cache) -> Optional[Predictor]:
    """Harvest ``cache``, fit, persist ``predictor.json``; None when the
    cache holds no usable examples at all (nothing is written)."""
    if not getattr(cache, "enabled", False):
        return None
    fmt_examples, tune_examples = harvest(cache)
    if not fmt_examples and not tune_examples:
        return None

    format_model = None
    if fmt_examples:
        x = np.stack([e[0] for e in fmt_examples])
        y = [e[1] for e in fmt_examples]
        format_model = CentroidClassifier.fit(x, y)
    tune_model = None
    if tune_examples:
        x = np.stack([e[0] for e in tune_examples])
        keys = [e[1] for e in tune_examples]
        payloads = [e[2] for e in tune_examples]
        tune_model = NearestExample.fit(x, keys, payloads)

    predictor = Predictor(format_model=format_model, tune_model=tune_model,
                          n_format_examples=len(fmt_examples),
                          n_tune_examples=len(tune_examples))
    _write_predictor(cache.directory, predictor)
    if obs.SWITCH.on:
        obs.gauge("learn.train.format_examples").set(len(fmt_examples))
        obs.gauge("learn.train.tune_examples").set(len(tune_examples))
    return predictor


def _write_predictor(directory: str, predictor: Predictor) -> None:
    tmp = None
    try:
        os.makedirs(directory, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=directory, suffix=".json.tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(predictor.to_json(), f, indent=1)
        os.replace(tmp, predictor_path(directory))
    except OSError:
        # fail-open like the plan cache itself: an unwritable directory
        # degrades to "no predictor", never to an engine error
        if tmp is not None and os.path.exists(tmp):
            os.unlink(tmp)


def load_predictor(directory: Optional[str]) -> Optional[Predictor]:
    """Load (memoized by mtime) the trained predictor beside a plan cache.

    Returns None when the directory is unset, the file is absent/corrupt,
    or the persisted feature schema no longer matches — every failure mode
    degrades to the next rung of the selection ladder.
    """
    if not directory:
        return None
    path = predictor_path(directory)
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        _LOAD_MEMO.pop(directory, None)
        return None
    memo = _LOAD_MEMO.get(directory)
    if memo is not None and memo[0] == mtime:
        return memo[1]
    try:
        with open(path) as f:
            predictor = Predictor.from_json(json.load(f))
    except (OSError, ValueError, KeyError, TypeError):
        predictor = None
    _LOAD_MEMO[directory] = (mtime, predictor)
    return predictor


def clear_load_memo() -> None:
    """Test hook: forget memoized predictors (e.g. across tmp dirs)."""
    _LOAD_MEMO.clear()
