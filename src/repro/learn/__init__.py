"""Learned zero-measurement format/executor selection (DESIGN.md §14).

The paper times three runs per candidate to pick its restructuring; at
serving scale that sweep stalls every cold-start dataset.  This package
closes the loop the plan cache already feeds: harvest (phi_stats features
-> chosen plan) pairs from persisted FormatPlans/TunePlans, fit a tiny
dependency-free model, and answer cache misses from it with **zero**
measurements (``reason="predicted"``), demoting measured autotune to a
background refinement that upgrades the cache in place.

Modules: :mod:`features` (schema), :mod:`model` (centroid classifier +
nearest-example params), :mod:`harvest` (cache walk, train, load),
:mod:`refine` (the background queue the serve frontend drains).
"""
from repro.learn.features import (FEATURE_NAMES, FEATURE_SCHEMA,  # noqa: F401
                                  feature_vector)
from repro.learn.harvest import (PREDICTOR_FILENAME, clear_load_memo,  # noqa: F401
                                 harvest, load_predictor, predictor_path,
                                 train_predictor)
from repro.learn.model import (CentroidClassifier, NearestExample,  # noqa: F401
                               Predictor)
from repro.learn.refine import QUEUE, RefineQueue, run_pending  # noqa: F401
