"""Metrics registry: labeled counters, gauges, and quantile histograms.

Dependency-free (stdlib only) and built around two contracts:

* **Zero cost when off.**  Every mutating method starts with a single
  ``SWITCH.on`` attribute check and returns immediately when observability
  is disabled — no allocation, no arithmetic, nothing for the garbage
  collector (tests/test_obs.py pins this with tracemalloc).  Instruments
  are fetched once (``registry.counter(...)`` memoizes on name + labels)
  and held by the instrumented object, so the hot path never touches the
  registry either.

* **Identity-preserving reset.**  ``reset()`` zeroes values *in place*:
  a scheduler that cached its counter at construction keeps a live handle
  across resets, which is what lets ``benchmarks/table13_service.py``
  replay one trace per arrival rate against fresh numbers without
  rebuilding the service stack.

Histograms keep ``count``/``sum``/``min``/``max`` exact and estimate
quantiles from a bounded reservoir (default 4096 samples): below the cap
the estimate is *exact* (verified against ``np.percentile`` under
hypothesis), past it samples are replaced uniformly at random by a
per-instrument deterministic generator, so repeated runs of the same trace
report the same quantiles.  :func:`quantile` is the one interpolation rule
(numpy's default ``linear``) shared by the histograms, the benchmark
timing summaries (``benchmarks/common.time_fn``), and the serving table —
the percentile logic exists exactly once in the repo.
"""
from __future__ import annotations

import math
import random
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.obs.runtime import SWITCH

LabelKey = Tuple[str, Tuple[Tuple[str, str], ...]]

#: quantiles serialized for every histogram in snapshot()
SNAPSHOT_QUANTILES = (50.0, 90.0, 95.0, 99.0)


def quantile(values: Sequence[float], q: float) -> float:
    """The q-th percentile (q in [0, 100]) under linear interpolation —
    numerically identical to ``np.percentile(values, q)`` with the default
    method.  The single percentile implementation every consumer shares."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"q must be in [0, 100], got {q}")
    xs = sorted(values)
    if not xs:
        return math.nan
    rank = (len(xs) - 1) * (q / 100.0)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    if lo == hi:
        return float(xs[lo])
    frac = rank - lo
    return float(xs[lo]) * (1.0 - frac) + float(xs[hi]) * frac


class Counter:
    """Monotonically increasing labeled counter."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if not SWITCH.on:
            return
        self.value += n

    def _reset(self) -> None:
        self.value = 0.0


class Gauge:
    """Last-value-wins labeled gauge."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        if not SWITCH.on:
            return
        self.value = v

    def inc(self, n: float = 1.0) -> None:
        if not SWITCH.on:
            return
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        if not SWITCH.on:
            return
        self.value -= n

    def _reset(self) -> None:
        self.value = 0.0


class Histogram:
    """Streaming distribution: exact moments + reservoir quantiles."""

    __slots__ = ("name", "labels", "count", "sum", "min", "max",
                 "_samples", "_cap", "_rng")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...],
                 max_samples: int = 4096):
        self.name = name
        self.labels = labels
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._samples: List[float] = []
        self._cap = max_samples
        # deterministic per-instrument stream: same trace -> same quantiles
        self._rng = random.Random(zlib.crc32(name.encode()))

    def observe(self, v: float) -> None:
        if not SWITCH.on:
            return
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self._samples) < self._cap:
            self._samples.append(v)
        else:
            j = self._rng.randrange(self.count)
            if j < self._cap:
                self._samples[j] = v

    def quantile(self, q: float) -> float:
        """Estimated q-th percentile (exact while count <= max_samples)."""
        return quantile(self._samples, q)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def _reset(self) -> None:
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._samples.clear()


class MetricsRegistry:
    """Name + labels -> instrument, memoized; snapshot() serializes all.

    One process-global instance lives at ``repro.obs.METRICS``; private
    registries are only for tests.
    """

    def __init__(self):
        self._counters: Dict[LabelKey, Counter] = {}
        self._gauges: Dict[LabelKey, Gauge] = {}
        self._histograms: Dict[LabelKey, Histogram] = {}

    @staticmethod
    def _key(name: str, labels: Dict[str, object]) -> LabelKey:
        return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))

    def counter(self, name: str, **labels) -> Counter:
        key = self._key(name, labels)
        inst = self._counters.get(key)
        if inst is None:
            inst = self._counters[key] = Counter(name, key[1])
        return inst

    def gauge(self, name: str, **labels) -> Gauge:
        key = self._key(name, labels)
        inst = self._gauges.get(key)
        if inst is None:
            inst = self._gauges[key] = Gauge(name, key[1])
        return inst

    def histogram(self, name: str, max_samples: int = 4096,
                  **labels) -> Histogram:
        key = self._key(name, labels)
        inst = self._histograms.get(key)
        if inst is None:
            inst = self._histograms[key] = Histogram(name, key[1],
                                                     max_samples)
        return inst

    # -- read side ---------------------------------------------------------
    def value(self, name: str, **labels) -> float:
        """Current value of a counter or gauge (counters win on a name
        collision; 0.0 when the instrument was never created)."""
        key = self._key(name, labels)
        if key in self._counters:
            return self._counters[key].value
        if key in self._gauges:
            return self._gauges[key].value
        return 0.0

    def total(self, name: str, **match) -> float:
        """Sum of every counter named ``name`` whose labels include all of
        ``match`` (e.g. all plan-cache hits across plan kinds)."""
        want = {(k, str(v)) for k, v in match.items()}
        return sum(c.value for (n, labels), c in self._counters.items()
                   if n == name and want <= set(labels))

    # -- lifecycle ---------------------------------------------------------
    def reset(self) -> None:
        """Zero every instrument in place — held references stay live."""
        for group in (self._counters, self._gauges, self._histograms):
            for inst in group.values():
                inst._reset()

    def snapshot(self) -> dict:
        """JSON-ready dump of every instrument (DESIGN.md §12.3).

        Histograms serialize their exact moments plus the
        :data:`SNAPSHOT_QUANTILES` estimates; empty histograms serialize
        with ``count = 0`` and no quantiles (NaN is not valid JSON)."""

        def _entry(inst) -> dict:
            return dict(name=inst.name, labels=dict(inst.labels))

        hists = []
        for h in self._histograms.values():
            e = _entry(h)
            e["count"] = h.count
            e["sum"] = h.sum
            if h.count:
                e["min"] = h.min
                e["max"] = h.max
                e["mean"] = h.mean
                e["quantiles"] = {f"p{q:g}": h.quantile(q)
                                  for q in SNAPSHOT_QUANTILES}
            hists.append(e)
        return dict(
            schema="obs-1",
            counters=[dict(_entry(c), value=c.value)
                      for c in self._counters.values()],
            gauges=[dict(_entry(g), value=g.value)
                    for g in self._gauges.values()],
            histograms=hists,
        )


def snapshot_value(snap: dict, kind: str, name: str,
                   labels: Optional[dict] = None) -> Optional[float]:
    """Look one counter/gauge value out of a serialized snapshot (the read
    path ``benchmarks/check_regression.py`` gates through)."""
    want = {k: str(v) for k, v in (labels or {}).items()}
    entries: Iterable[dict] = snap.get(kind, ())
    for e in entries:
        if e.get("name") == name and want.items() <= e.get("labels",
                                                           {}).items():
            return float(e["value"])
    return None
