"""Observability: metrics + span tracing for the whole serving stack.

One process-global :class:`~repro.obs.metrics.MetricsRegistry` and one
:class:`~repro.obs.trace.Tracer`, off by default (``enable()`` or
``$REPRO_OBS=1`` arms them), with a hard overhead contract: disabled
instrument calls are allocation-free no-ops, so the scheduler, the plan
cache, the tuner and the engine step loop are instrumented
unconditionally (DESIGN.md §12).

Hot-path idiom — fetch instruments once, hold them, guard any *extra*
work (timing reads, byte-count lookups) behind ``SWITCH.on``::

    from repro import obs

    class Scheduler:
        def __init__(self):
            self._m_admitted = obs.counter("serve.jobs.admitted")

        def submit(self, job):
            self._m_admitted.inc()          # no-op when disabled
            if obs.SWITCH.on:               # guard the monotonic() reads
                ...

``snapshot()`` serializes every instrument into the JSON structure the
bench harness embeds in its schema-1 payload (``benchmarks/run.py
--json``/``--metrics``) and CI gates on (``check_regression.py
--metrics``).
"""
from __future__ import annotations

from repro.obs.metrics import (MetricsRegistry, quantile,  # noqa: F401
                               snapshot_value)
from repro.obs.runtime import SWITCH, disable, enable, enabled  # noqa: F401
from repro.obs.trace import Tracer  # noqa: F401

#: process-global instances — the ones the production stack instruments
METRICS = MetricsRegistry()
TRACER = Tracer()

# bound convenience accessors: obs.counter(...) etc.
counter = METRICS.counter
gauge = METRICS.gauge
histogram = METRICS.histogram
value = METRICS.value
total = METRICS.total
span = TRACER.span


def record_cache_stats(stats, prefix: str = "plan_cache") -> None:
    """Mirror a :class:`~repro.core.plan_cache.CacheStats` into gauges.

    The stats object counts every lookup since the cache was built —
    including ones made while observability was disabled — so engines and
    services surface it as authoritative gauges at snapshot time rather
    than relying on the live lookup counters alone."""
    METRICS.gauge(f"{prefix}.hits").set(float(stats.hits))
    METRICS.gauge(f"{prefix}.misses").set(float(stats.misses))
    METRICS.gauge(f"{prefix}.hit_rate").set(float(stats.hit_rate))


def snapshot() -> dict:
    """Serialize every metric (+ trace accounting) to a JSON-ready dict."""
    snap = METRICS.snapshot()
    snap["spans"] = dict(recorded=sum(1 for _ in _iter_spans()),
                         roots=len(TRACER.roots), dropped=TRACER.dropped)
    return snap


def _iter_spans():
    stack = list(TRACER.roots)
    while stack:
        s = stack.pop()
        stack.extend(s.children)
        yield s


def reset() -> None:
    """Zero every metric in place and drop all recorded spans."""
    METRICS.reset()
    TRACER.reset()
