"""Context-manager span tracing with Chrome-trace export.

A span is a named, attributed interval on the monotonic clock
(``time.monotonic_ns`` — wall-clock jumps can never produce negative
durations).  Nesting follows ``with`` structure: the tracer keeps an open
stack, a span entered while another is open becomes its child, and the
roots form the trace.  The taxonomy the repo emits is documented in
DESIGN.md §12.4 (``scheduler.tick`` > ``scheduler.slice`` >
``engine.step``; ``service.checkpoint``; ``tune.search``;
``engine.build``).

Disabled-path contract: ``Tracer.span()`` returns a shared no-op context
manager when the switch is off — no span object is allocated, entering
and exiting it does nothing.  Attributes are therefore passed as an
optional dict argument (``span("engine.step", {"k": 8})``), not as
``**kwargs``, so a disabled call site does not even build a dict.

Export is Chrome-trace JSON (``chrome://tracing`` / Perfetto "trace event
format", complete events): timestamps and durations in microseconds,
attributes in ``args``.  Nesting round-trips through the flat event list
by interval containment — tests/test_obs.py reconstructs the tree from a
dumped trace and checks it against the structured ``as_dict`` export.

The tracer bounds memory: past ``max_spans`` recorded spans, new spans
are counted in ``dropped`` instead of stored (a serving process must not
grow a trace forever).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

from repro.obs.runtime import SWITCH


class Span:
    """One timed interval; a context manager bound to its tracer."""

    __slots__ = ("name", "attrs", "start_ns", "end_ns", "children",
                 "_tracer")

    def __init__(self, tracer: "Tracer", name: str,
                 attrs: Optional[Dict[str, object]] = None):
        self.name = name
        self.attrs: Dict[str, object] = {} if attrs is None else dict(attrs)
        self.start_ns = 0
        self.end_ns = 0
        self.children: List[Span] = []
        self._tracer = tracer

    def set_attr(self, key: str, value: object) -> None:
        """Attach a result computed inside the span (e.g. achieved GB/s)."""
        self.attrs[key] = value

    @property
    def duration_s(self) -> float:
        return (self.end_ns - self.start_ns) / 1e9

    def __enter__(self) -> "Span":
        self._tracer._push(self)
        self.start_ns = time.monotonic_ns()
        return self

    def __exit__(self, *exc) -> None:
        self.end_ns = time.monotonic_ns()
        self._tracer._pop(self)

    def as_dict(self) -> dict:
        return dict(name=self.name, attrs=dict(self.attrs),
                    start_us=self.start_ns / 1e3,
                    dur_us=(self.end_ns - self.start_ns) / 1e3,
                    children=[c.as_dict() for c in self.children])


class _NoopSpan:
    """Shared disabled-path span: allocation-free enter/exit/set_attr."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None

    def set_attr(self, key, value):
        return None


_NOOP_SPAN = _NoopSpan()


class Tracer:
    """Span factory + the open-span stack + the finished-span forest."""

    def __init__(self, max_spans: int = 100_000):
        self.roots: List[Span] = []
        self.dropped = 0
        self.max_spans = max_spans
        self._stack: List[Span] = []
        self._recorded = 0

    def span(self, name: str,
             attrs: Optional[Dict[str, object]] = None):
        """Open a span: ``with tracer.span("engine.step", {"k": 8}):``.

        Returns the shared no-op context manager when observability is
        disabled."""
        if not SWITCH.on:
            return _NOOP_SPAN
        return Span(self, name, attrs)

    # -- stack maintenance (called by Span.__enter__/__exit__) -------------
    def _push(self, span: Span) -> None:
        self._stack.append(span)

    def _pop(self, span: Span) -> None:
        # tolerate interleaved exits (generators, exceptions): unwind to
        # the span being closed rather than assuming strict LIFO
        while self._stack:
            top = self._stack.pop()
            if top is span:
                break
        if self._recorded >= self.max_spans:
            self.dropped += 1
            return
        if self._stack:
            self._stack[-1].children.append(span)
        else:
            self.roots.append(span)
        self._recorded += 1

    # -- export ------------------------------------------------------------
    def export(self) -> List[dict]:
        """Structured (nested) dump of every finished root span."""
        return [s.as_dict() for s in self.roots]

    def export_chrome(self) -> List[dict]:
        """Flat Chrome-trace complete events (``ph: "X"``, microseconds)."""
        events: List[dict] = []

        def walk(span: Span) -> None:
            events.append(dict(
                name=span.name, ph="X", pid=0, tid=0,
                ts=span.start_ns / 1e3,
                dur=(span.end_ns - span.start_ns) / 1e3,
                args=dict(span.attrs)))
            for c in span.children:
                walk(c)

        for root in self.roots:
            walk(root)
        return events

    def to_chrome_json(self) -> str:
        return json.dumps({"traceEvents": self.export_chrome()})

    def reset(self) -> None:
        self.roots.clear()
        self._stack.clear()
        self.dropped = 0
        self._recorded = 0
