"""The one switch every instrument checks (DESIGN.md §12.1).

Observability is off by default and every hot-path instrument call must
degrade to a single attribute check when it is — engines, kernels and the
scheduler are instrumented unconditionally, so the disabled path *is* the
production path.  The switch is a slotted singleton rather than a module
global so both :mod:`repro.obs.metrics` and :mod:`repro.obs.trace` share
one mutable flag without import-order games, and reading it
(``SWITCH.on``) allocates nothing.

``$REPRO_OBS=1`` arms the switch at import time (e.g. for a bench run or
a service deployment launched without code changes).
"""
from __future__ import annotations

import os


class _Switch:
    __slots__ = ("on",)

    def __init__(self, on: bool = False):
        self.on = on


SWITCH = _Switch(os.environ.get("REPRO_OBS", "") in ("1", "true", "yes"))


def enable() -> None:
    SWITCH.on = True


def disable() -> None:
    SWITCH.on = False


def enabled() -> bool:
    return SWITCH.on
