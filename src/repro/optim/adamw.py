"""Optimizers: AdamW (ZeRO-1-shardable moments) and Adafactor.

AdamW keeps f32 first/second moments; under ZeRO-1 the moments (and the
gradient reduction) are sharded across the `data`(+`pod`) mesh axes on top of
the params' TP sharding (see distributed/sharding.py — GSPMD realizes the
reduce-scatter).  Adafactor stores factored second moments (row/col sums) —
the choice for the 1T-param config, where full AdamW moments cannot fit 512
chips (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step: Array) -> Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_opt_state(cfg: OptConfig, params: Params) -> Dict[str, Any]:
    if cfg.kind == "adamw":
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}
    if cfg.kind == "adafactor":
        def facs(p):
            if p.ndim < 2:
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"fac": jax.tree.map(facs, params,
                                    is_leaf=lambda x: isinstance(x, jax.Array)),
                "step": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.kind)


def global_norm(tree: Params) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree: Params, max_norm: float) -> Tuple[Params, Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), norm


_CHUNK_THRESHOLD = 1 << 30     # elements; ~2 GB bf16 / 4 GB f32


def _maybe_chunked(fn, *leaves):
    """Apply an elementwise-leaf update; giant stacked leaves (e.g. the
    (layers, experts, d, ff) MoE stack — 1T params) are processed slice-by-
    slice over the leading dim so the f32 temporaries are per-layer-sized
    instead of a full f32 copy of the tensor (which alone would blow the HBM
    budget on the 1T config)."""
    p = leaves[0]
    if p.ndim >= 3 and p.size >= _CHUNK_THRESHOLD:
        return jax.lax.map(lambda args: fn(*args), leaves)
    return fn(*leaves)


def apply_updates(cfg: OptConfig, params: Params, grads: Params,
                  state: Dict[str, Any]) -> Tuple[Params, Dict[str, Any], Dict[str, Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    if cfg.kind == "adamw":
        bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g32 = g.astype(jnp.float32)
            mu2 = cfg.b1 * mu + (1 - cfg.b1) * g32
            nu2 = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g32)
            d = (mu2 / bc1) / (jnp.sqrt(nu2 / bc2) + cfg.eps)
            if p.ndim >= 2:
                d = d + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype), mu2, nu2

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_mu = tdef.flatten_up_to(state["mu"])
        flat_nu = tdef.flatten_up_to(state["nu"])
        out = [_maybe_chunked(upd, p, g, m, n) for p, g, m, n
               in zip(flat_p, flat_g, flat_mu, flat_nu)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_state = {"mu": tdef.unflatten([o[1] for o in out]),
                     "nu": tdef.unflatten([o[2] for o in out]),
                     "step": step}
        return new_p, new_state, {"grad_norm": gnorm, "lr": lr}

    # -- adafactor (beta1=0, factored second moment) --------------------------
    d2 = 1e-30

    def upd_fac(p, g, fac):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + d2
        if p.ndim < 2:
            v = cfg.b2 * fac["v"] + (1 - cfg.b2) * g2
            d = g32 * jax.lax.rsqrt(v + cfg.eps)
            new_fac = {"v": v}
        else:
            vr = cfg.b2 * fac["vr"] + (1 - cfg.b2) * g2.mean(axis=-1)
            vc = cfg.b2 * fac["vc"] + (1 - cfg.b2) * g2.mean(axis=-2)
            rfac = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), d2)
            d = g32 * jax.lax.rsqrt(rfac[..., None] * vc[..., None, :] + cfg.eps)
            new_fac = {"vr": vr, "vc": vc}
        # update clipping (adafactor RMS trick)
        rms = jnp.sqrt(jnp.mean(jnp.square(d)) + d2)
        d = d / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            d = d + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * d).astype(p.dtype), new_fac

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_f = tdef.flatten_up_to(state["fac"])
    out = [_maybe_chunked(upd_fac, p, g, f)
           for p, g, f in zip(flat_p, flat_g, flat_f)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_state = {"fac": tdef.unflatten([o[1] for o in out]), "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
