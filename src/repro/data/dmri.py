"""Synthetic dMRI / tractography generator (DS1/DS2 analogue).

The paper evaluates on the STN96 dataset (Ntheta=96, Nv ~ 1.4-2.6e5,
Nf = 5e4-5e5) with candidate connectomes from five MRtrix tractography
algorithms (Table 9).  That data is not redistributable, so this module
synthesizes connectomes with matching structure:

  * fibers are 3-D streamlines stepped through a voxel grid,
  * each traversed (voxel, orientation) pair quantizes the step direction to
    the nearest dictionary atom (the ENCODE construction),
  * Phi coefficients are (atom, voxel, fiber, value=segment length), deduped,
  * the measured signal is  y = M w_true + noise  with a sparse nonnegative
    ground-truth w_true (so pruning has signal to find).

The five named generators vary step curvature/length statistics the way the
MRtrix algorithms vary tract shapes; they exist so the Table-9 benchmark has
a faithful sweep axis, not to claim anatomical realism.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.std import PhiTensor, make_dictionary, _fibonacci_sphere
from repro.core import spmv

TRACTOGRAPHY = {
    # name: (curvature, mean_len, len_jitter)
    "DET": (0.05, 24, 4),
    "PROB": (0.35, 24, 8),
    "iFOD1": (0.50, 36, 12),
    "SD_STREAM": (0.20, 20, 6),
    "FACT": (0.00, 16, 4),
}


@dataclasses.dataclass
class LifeProblem:
    phi: PhiTensor
    dictionary: jax.Array        # (Na, Ntheta)
    b: jax.Array                 # (Nv, Ntheta) demeaned measured signal
    w_true: jax.Array            # (Nf,) ground truth weights
    stats: Dict[str, float]


def synth_connectome(
    *,
    n_fibers: int = 512,
    n_theta: int = 96,
    n_atoms: int = 96,
    grid: Tuple[int, int, int] = (24, 24, 24),
    algorithm: str = "PROB",
    noise: float = 0.01,
    active_frac: float = 0.35,
    seed: int = 0,
    dtype=jnp.float32,
) -> LifeProblem:
    if algorithm not in TRACTOGRAPHY:
        raise ValueError(f"unknown tractography {algorithm!r}")
    curvature, mean_len, jitter = TRACTOGRAPHY[algorithm]
    rng = np.random.default_rng(seed)
    gx, gy, gz = grid
    n_voxels = gx * gy * gz
    atom_dirs = _fibonacci_sphere(n_atoms)

    atoms, voxels, fibers, values = [], [], [], []
    step = 0.75
    for f in range(n_fibers):
        pos = rng.uniform([2, 2, 2], [gx - 2, gy - 2, gz - 2])
        d = rng.normal(size=3)
        d /= np.linalg.norm(d)
        n_steps = max(4, int(rng.normal(mean_len, jitter)))
        for _ in range(n_steps):
            if curvature > 0:
                d = d + curvature * rng.normal(size=3)
                d /= np.linalg.norm(d)
            elif algorithm == "FACT":
                # axis-aligned steps (fiber assignment by continuous tracking)
                ax = np.argmax(np.abs(d))
                d = np.zeros(3)
                d[ax] = 1.0
            pos = pos + step * d
            v = np.floor(pos).astype(np.int64)
            if np.any(v < 0) or v[0] >= gx or v[1] >= gy or v[2] >= gz:
                break
            vox = int(v[0] * gy * gz + v[1] * gz + v[2])
            atom = int(np.argmax(np.abs(atom_dirs @ d)))  # axial symmetry
            atoms.append(atom)
            voxels.append(vox)
            fibers.append(f)
            values.append(step)

    atoms_a = np.asarray(atoms, np.int64)
    voxels_a = np.asarray(voxels, np.int64)
    fibers_a = np.asarray(fibers, np.int64)
    values_a = np.asarray(values, np.float64)

    # dedupe repeated (atom, voxel, fiber) triples, summing values
    key = (atoms_a * n_voxels + voxels_a) * n_fibers + fibers_a
    uniq, inv = np.unique(key, return_inverse=True)
    val_sum = np.zeros(uniq.size, np.float64)
    np.add.at(val_sum, inv, values_a)
    atoms_u = (uniq // n_fibers) // n_voxels
    voxels_u = (uniq // n_fibers) % n_voxels
    fibers_u = uniq % n_fibers

    phi = PhiTensor(
        atoms=jnp.asarray(atoms_u, jnp.int32),
        voxels=jnp.asarray(voxels_u, jnp.int32),
        fibers=jnp.asarray(fibers_u, jnp.int32),
        values=jnp.asarray(val_sum, dtype),
        n_atoms=n_atoms, n_voxels=n_voxels, n_fibers=n_fibers,
    )
    dictionary = make_dictionary(n_atoms, n_theta, dtype=dtype)

    w_true = rng.uniform(0.0, 1.0, n_fibers)
    w_true[rng.uniform(size=n_fibers) > active_frac] = 0.0
    w_true_j = jnp.asarray(w_true, dtype)
    clean = spmv.dsc_naive(phi, dictionary, w_true_j)
    b = clean + noise * jnp.asarray(rng.normal(size=clean.shape), dtype)

    nc = phi.n_coeffs
    stats = dict(
        n_coeffs=float(nc),
        n_voxels_touched=float(np.unique(voxels_u).size),
        phi_mbytes=float(nc * (3 * 4 + 4)) / 1e6,
        nnz_per_fiber=float(nc) / max(1, n_fibers),
    )
    return LifeProblem(phi=phi, dictionary=dictionary, b=b,
                       w_true=w_true_j, stats=stats)


def synth_cohort(n_subjects: int, *, base_seed: int = 0,
                 algorithm: str = "PROB", **kwargs) -> List[LifeProblem]:
    """Cohort of subjects sharing the acquisition, varying the anatomy.

    All subjects share grid / n_fibers / n_theta / n_atoms — and therefore
    the *same* dictionary (make_dictionary is deterministic in the atom
    geometry, matching the real setting where canonical atoms depend on the
    gradient scheme, not the subject).  Per-subject seeds vary streamline
    geometry, so coefficient counts Nc differ across subjects — exactly the
    padding problem BatchedLifeEngine solves.
    """
    return [synth_connectome(seed=base_seed + s, algorithm=algorithm,
                             **kwargs) for s in range(n_subjects)]
