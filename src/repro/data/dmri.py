"""Synthetic dMRI / tractography generator (DS1/DS2 analogue).

The paper evaluates on the STN96 dataset (Ntheta=96, Nv ~ 1.4-2.6e5,
Nf = 5e4-5e5) with candidate connectomes from five MRtrix tractography
algorithms (Table 9).  That data is not redistributable, so this module
synthesizes connectomes with matching structure:

  * fibers are 3-D streamlines stepped through a voxel grid,
  * each traversed (voxel, orientation) pair quantizes the step direction to
    the nearest dictionary atom (the ENCODE construction),
  * Phi coefficients are (atom, voxel, fiber, value=segment length), deduped,
  * the measured signal is  y = M w_true + noise  with a sparse nonnegative
    ground-truth w_true (so pruning has signal to find).

The five named generators vary step curvature/length statistics the way the
MRtrix algorithms vary tract shapes; they exist so the Table-9 benchmark has
a faithful sweep axis, not to claim anatomical realism.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.std import PhiTensor, make_dictionary, _fibonacci_sphere
from repro.core import spmv

TRACTOGRAPHY = {
    # name: (curvature, mean_len, len_jitter)
    "DET": (0.05, 24, 4),
    "PROB": (0.35, 24, 8),
    "iFOD1": (0.50, 36, 12),
    "SD_STREAM": (0.20, 20, 6),
    "FACT": (0.00, 16, 4),
}


@dataclasses.dataclass
class LifeProblem:
    phi: PhiTensor
    dictionary: jax.Array        # (Na, Ntheta)
    b: jax.Array                 # (Nv, Ntheta) demeaned measured signal
    w_true: jax.Array            # (Nf,) ground truth weights
    stats: Dict[str, float]
    # (gx, gy, gz) voxel-grid shape when voxel ids are a row-major box
    # linearization (set by synth_connectome); None for problems whose
    # voxel axis has no spatial structure (e.g. crossval restrictions).
    # Required by coarsen_problem and used by fiber_bundles for 3-D
    # centroids.
    grid: Optional[Tuple[int, int, int]] = None


def synth_connectome(
    *,
    n_fibers: int = 512,
    n_theta: int = 96,
    n_atoms: int = 96,
    grid: Tuple[int, int, int] = (24, 24, 24),
    algorithm: str = "PROB",
    noise: float = 0.01,
    active_frac: float = 0.35,
    seed: int = 0,
    dtype=jnp.float32,
) -> LifeProblem:
    if algorithm not in TRACTOGRAPHY:
        raise ValueError(f"unknown tractography {algorithm!r}")
    curvature, mean_len, jitter = TRACTOGRAPHY[algorithm]
    rng = np.random.default_rng(seed)
    gx, gy, gz = grid
    n_voxels = gx * gy * gz
    atom_dirs = _fibonacci_sphere(n_atoms)

    atoms, voxels, fibers, values = [], [], [], []
    step = 0.75
    for f in range(n_fibers):
        pos = rng.uniform([2, 2, 2], [gx - 2, gy - 2, gz - 2])
        d = rng.normal(size=3)
        d /= np.linalg.norm(d)
        n_steps = max(4, int(rng.normal(mean_len, jitter)))
        for _ in range(n_steps):
            if curvature > 0:
                d = d + curvature * rng.normal(size=3)
                d /= np.linalg.norm(d)
            elif algorithm == "FACT":
                # axis-aligned steps (fiber assignment by continuous tracking)
                ax = np.argmax(np.abs(d))
                d = np.zeros(3)
                d[ax] = 1.0
            pos = pos + step * d
            v = np.floor(pos).astype(np.int64)
            if np.any(v < 0) or v[0] >= gx or v[1] >= gy or v[2] >= gz:
                break
            vox = int(v[0] * gy * gz + v[1] * gz + v[2])
            atom = int(np.argmax(np.abs(atom_dirs @ d)))  # axial symmetry
            atoms.append(atom)
            voxels.append(vox)
            fibers.append(f)
            values.append(step)

    atoms_a = np.asarray(atoms, np.int64)
    voxels_a = np.asarray(voxels, np.int64)
    fibers_a = np.asarray(fibers, np.int64)
    values_a = np.asarray(values, np.float64)

    # dedupe repeated (atom, voxel, fiber) triples, summing values
    key = (atoms_a * n_voxels + voxels_a) * n_fibers + fibers_a
    uniq, inv = np.unique(key, return_inverse=True)
    val_sum = np.zeros(uniq.size, np.float64)
    np.add.at(val_sum, inv, values_a)
    atoms_u = (uniq // n_fibers) // n_voxels
    voxels_u = (uniq // n_fibers) % n_voxels
    fibers_u = uniq % n_fibers

    phi = PhiTensor(
        atoms=jnp.asarray(atoms_u, jnp.int32),
        voxels=jnp.asarray(voxels_u, jnp.int32),
        fibers=jnp.asarray(fibers_u, jnp.int32),
        values=jnp.asarray(val_sum, dtype),
        n_atoms=n_atoms, n_voxels=n_voxels, n_fibers=n_fibers,
    )
    dictionary = make_dictionary(n_atoms, n_theta, dtype=dtype)

    w_true = rng.uniform(0.0, 1.0, n_fibers)
    w_true[rng.uniform(size=n_fibers) > active_frac] = 0.0
    w_true_j = jnp.asarray(w_true, dtype)
    clean = spmv.dsc_naive(phi, dictionary, w_true_j)
    b = clean + noise * jnp.asarray(rng.normal(size=clean.shape), dtype)

    nc = phi.n_coeffs
    stats = dict(
        n_coeffs=float(nc),
        n_voxels_touched=float(np.unique(voxels_u).size),
        phi_mbytes=float(nc * (3 * 4 + 4)) / 1e6,
        nnz_per_fiber=float(nc) / max(1, n_fibers),
    )
    return LifeProblem(phi=phi, dictionary=dictionary, b=b,
                       w_true=w_true_j, stats=stats, grid=grid)


def synth_cohort(n_subjects: int, *, base_seed: int = 0,
                 algorithm: str = "PROB", **kwargs) -> List[LifeProblem]:
    """Cohort of subjects sharing the acquisition, varying the anatomy.

    All subjects share grid / n_fibers / n_theta / n_atoms — and therefore
    the *same* dictionary (make_dictionary is deterministic in the atom
    geometry, matching the real setting where canonical atoms depend on the
    gradient scheme, not the subject).  Per-subject seeds vary streamline
    geometry, so coefficient counts Nc differ across subjects — exactly the
    padding problem BatchedLifeEngine solves.
    """
    return [synth_connectome(seed=base_seed + s, algorithm=algorithm,
                             **kwargs) for s in range(n_subjects)]


def coarsen_problem(problem: LifeProblem, factor: int, *,
                    grid: Optional[Tuple[int, int, int]] = None
                    ) -> LifeProblem:
    """Voxel-coarsened problem for coarse-to-fine multi-resolution solves.

    Merges every ``factor^3`` block of fine voxels into one coarse voxel:
    Phi coefficients are remapped and deduped (values summed, like the
    generator's own dedupe), and the signal rows of merged voxels are
    summed — so the coarse clean signal is exactly the sum of the fine
    clean signals and the fiber id space is untouched.  A coarse solve's
    weights therefore warm-start the fine solve directly
    (:func:`repro.science.incremental.multires_solve`).

    Args:
        problem: the fine problem; needs a voxel grid.
        factor: coarsening factor per axis; 1 returns the input.
        grid: grid override when ``problem.grid`` is unset.

    Returns:
        The coarsened :class:`LifeProblem` (its ``grid`` is the coarse
        box).

    Raises:
        ValueError: if ``factor < 1`` or no grid is available.
    """
    if factor < 1:
        raise ValueError(f"factor must be >= 1, got {factor}")
    if factor == 1:
        return problem
    g = grid if grid is not None else problem.grid
    if g is None:
        raise ValueError("coarsen_problem needs a voxel grid: the problem "
                         "has grid=None and no grid= was given")
    gx, gy, gz = g
    cgx, cgy, cgz = (-(-gx // factor), -(-gy // factor), -(-gz // factor))
    phi = problem.phi
    if gx * gy * gz != phi.n_voxels:
        raise ValueError(f"grid {g} does not linearize to "
                         f"n_voxels={phi.n_voxels}")

    def to_coarse(vox: np.ndarray) -> np.ndarray:
        x, rem = vox // (gy * gz), vox % (gy * gz)
        y, z = rem // gz, rem % gz
        return ((x // factor) * cgy + (y // factor)) * cgz + (z // factor)

    atoms = np.asarray(phi.atoms, np.int64)
    cvox = to_coarse(np.asarray(phi.voxels, np.int64))
    fibers = np.asarray(phi.fibers, np.int64)
    values = np.asarray(phi.values, np.float64)
    n_cvox = cgx * cgy * cgz
    key = (atoms * n_cvox + cvox) * phi.n_fibers + fibers
    uniq, inv = np.unique(key, return_inverse=True)
    val_sum = np.zeros(uniq.size, np.float64)
    np.add.at(val_sum, inv, values)
    sub = PhiTensor(
        atoms=jnp.asarray((uniq // phi.n_fibers) // n_cvox, jnp.int32),
        voxels=jnp.asarray((uniq // phi.n_fibers) % n_cvox, jnp.int32),
        fibers=jnp.asarray(uniq % phi.n_fibers, jnp.int32),
        values=jnp.asarray(val_sum, problem.phi.values.dtype),
        n_atoms=phi.n_atoms, n_voxels=n_cvox, n_fibers=phi.n_fibers)
    b_np = np.asarray(problem.b)
    b_coarse = np.zeros((n_cvox, b_np.shape[1]), np.float64)
    np.add.at(b_coarse, to_coarse(np.arange(gx * gy * gz, dtype=np.int64)),
              b_np)
    stats = dict(problem.stats)
    stats["n_coeffs"] = float(sub.n_coeffs)
    stats["n_voxels_touched"] = float(np.unique(np.asarray(sub.voxels)).size)
    return LifeProblem(phi=sub, dictionary=problem.dictionary,
                       b=jnp.asarray(b_coarse, b_np.dtype),
                       w_true=problem.w_true, stats=stats,
                       grid=(cgx, cgy, cgz))


def fiber_bundles(problem: LifeProblem, *, bundle_size: int,
                  n_bundles: int = 1, seed: int = 0
                  ) -> List[np.ndarray]:
    """Disjoint, spatially coherent fiber bundles (lesion candidates).

    Each bundle is a seed fiber plus its ``bundle_size - 1`` nearest
    neighbours by coefficient-centroid distance (3-D positions when the
    problem has a grid, linear voxel ids otherwise) — a synthetic stand-
    in for an anatomically grouped tract.  Only fibers with at least one
    Phi coefficient are eligible, and bundles never overlap.

    Args:
        problem: the problem to draw bundles from.
        bundle_size: fibers per bundle.
        n_bundles: number of disjoint bundles.
        seed: RNG seed for the bundle seed-fiber draw.

    Returns:
        ``n_bundles`` sorted int64 arrays of ``bundle_size`` fiber ids.

    Raises:
        ValueError: when fewer than ``n_bundles * bundle_size`` fibers
            have coefficients.
    """
    fib = np.asarray(problem.phi.fibers, np.int64)
    vox = np.asarray(problem.phi.voxels, np.int64)
    if problem.grid is not None:
        gx, gy, gz = problem.grid
        pos = np.stack([vox // (gy * gz), (vox // gz) % gy, vox % gz],
                       axis=1).astype(np.float64)
    else:
        pos = vox[:, None].astype(np.float64)
    counts = np.bincount(fib, minlength=problem.phi.n_fibers)
    sums = np.zeros((problem.phi.n_fibers, pos.shape[1]))
    np.add.at(sums, fib, pos)
    structural = np.nonzero(counts > 0)[0]
    if structural.size < n_bundles * bundle_size:
        raise ValueError(
            f"need {n_bundles * bundle_size} fibers with coefficients, "
            f"have {structural.size}")
    centroids = sums[structural] / counts[structural, None]
    rng = np.random.default_rng(seed)
    available = np.ones(structural.size, bool)
    bundles: List[np.ndarray] = []
    for _ in range(n_bundles):
        pool = np.nonzero(available)[0]
        anchor = rng.choice(pool)
        d = np.linalg.norm(centroids - centroids[anchor], axis=1)
        d[~available] = np.inf
        members = np.argsort(d, kind="stable")[:bundle_size]
        available[members] = False
        bundles.append(np.sort(structural[members]))
    return bundles

