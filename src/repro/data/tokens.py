"""Deterministic synthetic token pipeline — stateless, sharded, resumable.

Every batch is a pure function of (seed, step), so:

  * any host can materialize exactly its shard of the global batch (no
    inter-host data coordination),
  * restart/elastic-resize resumes from the checkpointed step with identical
    data order (the cursor IS the step),
  * stragglers can be re-issued the same batch deterministically.

Tokens follow a Zipf-ish marginal with short-range structure so losses move;
this is a load generator, not a corpus.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    seq_len: int = 1024
    global_batch: int = 8


def _keyed(seed: int, step: int) -> jax.Array:
    return jax.random.fold_in(jax.random.PRNGKey(seed), step)


def synth_tokens(cfg: DataConfig, vocab: int, step: int,
                 *, batch_slice: slice | None = None) -> Dict[str, jax.Array]:
    """Global (or host-sliced) batch for `step`.  labels[t] = tokens[t+1]."""
    key = _keyed(cfg.seed, step)
    b0, b1 = (0, cfg.global_batch) if batch_slice is None else (
        batch_slice.start, batch_slice.stop)
    rows = []
    for b in range(b0, b1):
        kb = jax.random.fold_in(key, b)
        # Zipf-ish marginal + local repetition structure
        base = jax.random.categorical(
            kb, -jnp.log1p(jnp.arange(vocab, dtype=jnp.float32)),
            shape=(cfg.seq_len + 1,))
        shift = jnp.roll(base, 3)
        mix = jax.random.bernoulli(jax.random.fold_in(kb, 1), 0.25,
                                   (cfg.seq_len + 1,))
        rows.append(jnp.where(mix, shift, base))
    seq = jnp.stack(rows).astype(jnp.int32)
    return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


def synth_batch_for(cfg: ArchConfig, data: DataConfig, step: int
                    ) -> Dict[str, jax.Array]:
    """Family-aware batch (matches configs.base.input_specs train layout)."""
    if cfg.family == "audio":
        key = _keyed(data.seed, step)
        emb = jax.random.normal(
            key, (data.global_batch, data.seq_len, cfg.d_model)
        ).astype(cfg.jnp_dtype)
        codes = jax.random.randint(
            jax.random.fold_in(key, 1),
            (data.global_batch, data.seq_len, cfg.n_codebooks),
            0, cfg.vocab_size, jnp.int32)
        return {"frame_embeds": emb, "codes": codes}
    if cfg.family == "vlm":
        vt = min(cfg.vision_tokens, data.seq_len // 2)
        base = synth_tokens(dataclasses.replace(data, seq_len=data.seq_len - vt),
                            cfg.vocab_size, step)
        key = _keyed(data.seed, step + 1)
        img = jax.random.normal(
            key, (data.global_batch, vt, cfg.d_model)).astype(cfg.jnp_dtype)
        pos = jnp.broadcast_to(jnp.arange(data.seq_len)[None, None],
                               (3, data.global_batch, data.seq_len)).astype(jnp.int32)
        labels = jnp.concatenate(
            [jnp.full((data.global_batch, vt), -1, jnp.int32),
             base["labels"]], axis=1)
        return {"tokens": base["tokens"], "image_embeds": img,
                "positions": pos, "labels": labels}
    return synth_tokens(data, cfg.vocab_size, step)
