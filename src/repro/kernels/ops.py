"""jit'd wrappers binding inspector TilePlans to the Pallas executors.

`make_dsc` / `make_wc` close over the *static* plan operands (padded index
tiles, host-computed once, amortized across SBBNNLS iterations and runs) and
return matvec/rmatvec callables whose only dynamic inputs are ``w`` / ``Y``.

Lane padding: Ntheta is padded to a 128-lane multiple (the paper pads Ntheta
to warp multiples; zero columns contribute zeros through both ops).

Compute dtype (DESIGN.md §10.3): ``compute_dtype="bf16"`` stores the static
operands — the dictionary and the Phi values — in bfloat16 while every
reduction accumulates in fp32 (the kernels' output dtype is pinned to the
original dictionary dtype, and contributions are cast up before the
reductions).  Dynamic operands (``w``, ``Y``) stay fp32, so products promote
to fp32 before any accumulation; only per-element storage rounding (~2^-8
relative) enters the result — the documented ``repro.tune.plan.BF16_RTOL``
contract.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.inspector import TilePlan
from repro.core.std import PhiTensor
from repro.kernels import dsc as dsc_kernel
from repro.kernels import wc as wc_kernel

LANES = 128


def pad_lanes(x: jax.Array, multiple: int = LANES) -> jax.Array:
    pad = (-x.shape[-1]) % multiple
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


def storage_cast(x: jax.Array, compute_dtype: str) -> jax.Array:
    """Cast a *static* operand to its storage dtype ("bf16" halves resident
    bytes; anything else is identity).  Never used on accumulators."""
    if compute_dtype == "bf16":
        return jnp.asarray(x).astype(jnp.bfloat16)
    return jnp.asarray(x)


def _padded_operands(phi: PhiTensor, plan: TilePlan):
    """Static executor operands from a plan (host-side, cached)."""
    sel = jnp.asarray(plan.sel)
    atoms_pad = jnp.concatenate([phi.atoms, jnp.zeros((1,), phi.atoms.dtype)])
    fibers_pad = jnp.concatenate([phi.fibers, jnp.zeros((1,), phi.fibers.dtype)])
    voxels_pad = jnp.concatenate([phi.voxels, jnp.zeros((1,), phi.voxels.dtype)])
    values_pad = jnp.concatenate([phi.values, jnp.zeros((1,), phi.values.dtype)])
    shape = (plan.n_tiles, plan.c_tile)
    return dict(
        atoms_p=jnp.take(atoms_pad, sel).reshape(shape),
        fibers_p=jnp.take(fibers_pad, sel).reshape(shape),
        voxels_p=jnp.take(voxels_pad, sel).reshape(shape),
        values_p=jnp.take(values_pad, sel).reshape(shape),
        local_row_p=jnp.asarray(plan.local_row).reshape(shape),
        row_block=jnp.asarray(plan.row_block),
        # padding slots got values 0 via values_pad, so they contribute 0.
    )


def _visited_mask(plan: TilePlan, n_rows: int) -> jax.Array:
    """Row mask zeroing row-blocks never visited by any tile (kernel leaves
    them uninitialized)."""
    visited = np.zeros(plan.n_rows_padded // plan.row_tile, bool)
    visited[np.asarray(plan.row_block)] = True
    mask = np.repeat(visited, plan.row_tile)[:n_rows]
    return jnp.asarray(mask, jnp.float32)


def make_dsc(phi_voxel_sorted: PhiTensor, dictionary: jax.Array,
             plan: TilePlan, *, interpret: bool = True,
             compute_dtype: str = "fp32") -> Callable:
    """Returns matvec(w) -> (Nv, Ntheta) running the DSC Pallas executor."""
    ops = _padded_operands(phi_voxel_sorted, plan)
    ops["values_p"] = storage_cast(ops["values_p"], compute_dtype)
    d_pad = pad_lanes(storage_cast(dictionary, compute_dtype))
    n_theta = dictionary.shape[1]
    n_voxels = phi_voxel_sorted.n_voxels
    n_row_blocks = plan.n_rows_padded // plan.row_tile
    mask = _visited_mask(plan, n_voxels)
    kernel = dsc_kernel.dsc_factory(row_tile=plan.row_tile,
                                    out_dtype=dictionary.dtype,
                                    interpret=interpret)

    @jax.jit
    def matvec(w: jax.Array) -> jax.Array:
        scaled_p = jnp.take(w, ops["fibers_p"].reshape(-1)).reshape(
            ops["fibers_p"].shape) * ops["values_p"]
        y = kernel(ops["row_block"], ops["atoms_p"], scaled_p,
                   ops["local_row_p"], d_pad, n_row_blocks=n_row_blocks)
        # where (not multiply): unvisited blocks are uninitialized memory
        return jnp.where(mask[:, None] > 0, y[:n_voxels, :n_theta], 0.0)

    return matvec


def make_dsc_sell(sell, dictionary: jax.Array, *, interpret: bool = True,
                  compute_dtype: str = "fp32") -> Callable:
    """matvec(w) -> (Nv, Ntheta) over a ``formats/sell.py:SellPhi`` (op="dsc").

    No TilePlan, no prefetch operands: the layout's static slot arrays are
    the whole plan (DESIGN.md §7)."""
    if sell.op != "dsc":
        raise ValueError(f"need a dsc-layout SellPhi, got op={sell.op!r}")
    atoms = jnp.asarray(sell.atoms)
    fibers = jnp.asarray(sell.others)
    values = storage_cast(sell.values, compute_dtype)
    d_pad = pad_lanes(storage_cast(dictionary, compute_dtype))
    n_theta = dictionary.shape[1]
    n_voxels = sell.n_voxels
    kernel = dsc_kernel.dsc_sell_factory(
        row_tile=sell.row_tile, slot_tile=sell.slot_tile,
        out_dtype=dictionary.dtype, interpret=interpret)

    @jax.jit
    def matvec(w: jax.Array) -> jax.Array:
        scaled = jnp.take(w, fibers) * values      # padding slots stay 0
        y = kernel(atoms, scaled, d_pad)
        return y[:n_voxels, :n_theta]

    return matvec


def make_wc_sell(sell, dictionary: jax.Array, *, interpret: bool = True,
                 compute_dtype: str = "fp32") -> Callable:
    """rmatvec(Y) -> (Nf,) over a ``formats/sell.py:SellPhi`` (op="wc")."""
    if sell.op != "wc":
        raise ValueError(f"need a wc-layout SellPhi, got op={sell.op!r}")
    atoms = jnp.asarray(sell.atoms)
    voxels = jnp.asarray(sell.others)
    values = storage_cast(sell.values, compute_dtype)
    d_pad = pad_lanes(storage_cast(dictionary, compute_dtype))
    n_fibers = sell.n_fibers
    kernel = wc_kernel.wc_sell_factory(
        row_tile=sell.row_tile, slot_tile=sell.slot_tile,
        out_dtype=dictionary.dtype, interpret=interpret)

    @jax.jit
    def rmatvec(y: jax.Array) -> jax.Array:
        y_pad = pad_lanes(y)
        # coalesced XLA pre-gather of Y rows, one (rows_padded, W, T) stream;
        # padding slots gather row 0 but carry value 0, so they are inert
        yg = jnp.take(y_pad, voxels, axis=0)
        w = kernel(atoms, yg, values, d_pad)
        return w.reshape(-1)[:n_fibers]

    return rmatvec


def make_fcoo_ops(fc, dictionary: jax.Array, *, interpret: bool = True,
                  compute_dtype: str = "fp32"):
    """(matvec, rmatvec) over ONE resident ``formats/fcoo.py:FcooPhi``.

    Both closures share the same device arrays — the stream is uploaded
    once; the WC view is a per-call in-jit gather through ``wc_perm``, not
    a second resident copy (the one-copy residency the 0.6x-of-SELL gate
    in benchmarks/check_regression.py holds).  The kernels emit per-chunk
    segment partials; the batched scatter-add over ``seg_rows_*`` here is
    the chunk-boundary combine (a run split across chunks lands twice on
    the same output row) and routes padding segments to the dummy row that
    the final trim drops."""
    from repro.kernels import fcoo as fcoo_kernel
    n_theta = dictionary.shape[1]
    n_voxels, n_fibers = fc.n_voxels, fc.n_fibers
    n_chunks, c_tile = fc.n_chunks, fc.c_tile
    if n_chunks == 0:                       # empty Phi: no kernel to launch
        zero_y = jnp.zeros((n_voxels, n_theta), dictionary.dtype)
        zero_w = jnp.zeros((n_fibers,), dictionary.dtype)
        return (jax.jit(lambda w: zero_y), jax.jit(lambda y: zero_w))

    shape = (n_chunks, c_tile)
    atoms = jnp.asarray(fc.atoms).reshape(shape)
    fibers = jnp.asarray(fc.fibers).reshape(shape)
    values = storage_cast(fc.values, compute_dtype).reshape(shape)
    dsc_ranks = jnp.asarray(fc.dsc_ranks).reshape(shape)
    wc_ranks = jnp.asarray(fc.wc_ranks).reshape(shape)
    seg_rows_dsc = jnp.asarray(fc.seg_rows_dsc)          # (T, Kd)
    seg_rows_wc = jnp.asarray(fc.seg_rows_wc)            # (T, Kw)
    wc_perm = jnp.asarray(fc.wc_perm)
    voxels = jnp.asarray(fc.voxels)
    d_pad = pad_lanes(storage_cast(dictionary, compute_dtype))
    out_dtype = dictionary.dtype
    dsc_k = fcoo_kernel.fcoo_dsc_factory(out_dtype=out_dtype,
                                         interpret=interpret)
    wc_k = fcoo_kernel.fcoo_wc_factory(out_dtype=out_dtype,
                                       interpret=interpret)

    @jax.jit
    def matvec(w: jax.Array) -> jax.Array:
        scaled = jnp.take(w, fibers.reshape(-1)).reshape(shape) * values
        parts = dsc_k(atoms, dsc_ranks, scaled, d_pad, seg_k=fc.k_dsc)
        y = jnp.zeros((n_voxels + 1, parts.shape[-1]), parts.dtype)
        return y.at[seg_rows_dsc].add(parts)[:n_voxels, :n_theta]

    @jax.jit
    def rmatvec(y: jax.Array) -> jax.Array:
        y_pad = pad_lanes(y)
        # per-call in-jit gathers materialize the fiber-major view without
        # keeping a second resident copy of the stream
        atoms_w = jnp.take(atoms.reshape(-1), wc_perm).reshape(shape)
        vals_w = jnp.take(values.reshape(-1), wc_perm).reshape(shape)
        yg = jnp.take(y_pad, jnp.take(voxels, wc_perm), axis=0).reshape(
            n_chunks, c_tile, y_pad.shape[1])
        parts = wc_k(atoms_w, wc_ranks, vals_w, yg, d_pad, seg_k=fc.k_wc)
        w = jnp.zeros((n_fibers + 1,), parts.dtype)
        return w.at[seg_rows_wc].add(parts)[:n_fibers]

    return matvec, rmatvec


def make_wc(phi_fiber_sorted: PhiTensor, dictionary: jax.Array,
            plan: TilePlan, *, interpret: bool = True,
            compute_dtype: str = "fp32") -> Callable:
    """Returns rmatvec(Y) -> (Nf,) running the WC Pallas executor."""
    ops = _padded_operands(phi_fiber_sorted, plan)
    ops["values_p"] = storage_cast(ops["values_p"], compute_dtype)
    d_pad = pad_lanes(storage_cast(dictionary, compute_dtype))
    n_fibers = phi_fiber_sorted.n_fibers
    n_fib_blocks = plan.n_rows_padded // plan.row_tile
    mask = _visited_mask(plan, n_fibers)
    kernel = wc_kernel.wc_factory(fib_tile=plan.row_tile,
                                  out_dtype=dictionary.dtype,
                                  interpret=interpret)

    @jax.jit
    def rmatvec(y: jax.Array) -> jax.Array:
        y_pad = pad_lanes(y)
        # coalesced XLA pre-gather of Y rows (beyond-paper: output-side sort
        # moves the irregularity to a streaming gather; see DESIGN.md §2)
        yg_p = jnp.take(
            jnp.concatenate([y_pad, jnp.zeros((1, y_pad.shape[1]), y_pad.dtype)]),
            ops["voxels_p"].reshape(-1), axis=0,
        ).reshape(*ops["voxels_p"].shape, y_pad.shape[1])
        w = kernel(ops["row_block"], ops["atoms_p"], yg_p, ops["values_p"],
                   ops["local_row_p"], d_pad, n_fib_blocks=n_fib_blocks)
        return jnp.where(mask > 0, w.reshape(-1)[:n_fibers], 0.0)

    return rmatvec
