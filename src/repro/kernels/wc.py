"""Pallas TPU kernel for WC (w = M^T y), the weight computation.

Executor for a ``TilePlan`` over **fiber-sorted** coefficients — the
beyond-paper TPU restructuring choice (the paper picks atom-sorted WC on
CPU/GPU for dictionary reuse; on TPU the scatter is the serial hazard, so we
sort by the output dimension and let XLA pre-gather ``Y`` rows as one
coalesced stream; see DESIGN.md §2).

Per grid step:

  * ``D`` stays VMEM-resident; atom rows are gathered in-VMEM,
  * the dot-product inner loop (paper: BLAS ``dot`` / warp ``SHFL``
    reduction) is a lane-dimension multiply + row reduction on the VPU:
    ``dots = sum(D[atoms_t] * Yg_t, axis=-1) * vals_t``,
  * the fiber scatter is the one-hot segment reduction into a
    (1, FIB_TILE) output block, accumulated across consecutive tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Frozen fallbacks only — see kernels/dsc.py; production binds launch
# parameters through the factories below.
DEFAULT_C_TILE = 256
DEFAULT_FIB_TILE = 128


def wc_factory(*, fib_tile: int = DEFAULT_FIB_TILE, out_dtype=None,
               interpret: bool = False):
    """Bind COO-WC launch parameters once (e.g. from a TunePlan)."""
    return functools.partial(wc_pallas, fib_tile=fib_tile,
                             out_dtype=out_dtype, interpret=interpret)


def wc_sell_factory(*, row_tile: int = 8, slot_tile: int = 32, out_dtype=None,
                    interpret: bool = False):
    """Bind SELL-WC launch parameters once (e.g. from a TunePlan)."""
    return functools.partial(wc_sell_pallas, row_tile=row_tile,
                             slot_tile=slot_tile, out_dtype=out_dtype,
                             interpret=interpret)


def _wc_kernel(row_block_ref,             # scalar prefetch: (T,) int32
               atoms_ref,                 # (1, C_TILE) int32
               yg_ref,                    # (1, C_TILE, Ntheta_p) fp
               vals_ref,                  # (1, C_TILE) fp
               local_row_ref,             # (1, C_TILE) int32
               d_ref,                     # (Na, Ntheta_p) fp, VMEM-resident
               w_ref):                    # (1, FIB_TILE) output block
    t = pl.program_id(0)
    prev = row_block_ref[jnp.maximum(t - 1, 0)]
    is_first_visit = jnp.logical_or(t == 0, row_block_ref[t] != prev)

    @pl.when(is_first_visit)
    def _():
        w_ref[...] = jnp.zeros_like(w_ref)

    atoms = atoms_ref[0]                                    # (C_TILE,)
    d_rows = d_ref[atoms]                                   # VMEM gather
    dots = jnp.sum(d_rows * yg_ref[0], axis=-1) * vals_ref[0]   # (C_TILE,)
    fib_tile = w_ref.shape[1]
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (fib_tile, dots.shape[0]), 0)
        == local_row_ref[0][None, :]
    ).astype(dots.dtype)
    w_ref[...] += jax.lax.dot_general(
        onehot, dots[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(w_ref.dtype).reshape(1, fib_tile)


def wc_pallas(row_block: jax.Array, atoms_p: jax.Array, yg_p: jax.Array,
              vals_p: jax.Array, local_row_p: jax.Array,
              dictionary_padded: jax.Array, *, fib_tile: int,
              n_fib_blocks: int, out_dtype=None,
              interpret: bool = False) -> jax.Array:
    """Run the WC executor.  Returns (n_fib_blocks, fib_tile) partial weights.

    ``out_dtype`` pins the accumulator/output dtype independently of the
    storage dtype (bf16 storage keeps fp32 accumulation)."""
    n_tiles, c_tile = atoms_p.shape
    n_theta_p = dictionary_padded.shape[1]
    out_dtype = dictionary_padded.dtype if out_dtype is None else out_dtype
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, c_tile), lambda t, rb: (t, 0)),
            pl.BlockSpec((1, c_tile, n_theta_p), lambda t, rb: (t, 0, 0)),
            pl.BlockSpec((1, c_tile), lambda t, rb: (t, 0)),
            pl.BlockSpec((1, c_tile), lambda t, rb: (t, 0)),
            pl.BlockSpec(dictionary_padded.shape, lambda t, rb: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, fib_tile), lambda t, rb: (rb[t], 0)),
    )
    return pl.pallas_call(
        _wc_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_fib_blocks, fib_tile), out_dtype),
        interpret=interpret,
    )(row_block, atoms_p, yg_p, vals_p, local_row_p, dictionary_padded)


# ----------------------------------------------------------------------------
# SELL fast path: direct fiber-block accumulation, no prefetch, no one-hot
# (DESIGN.md §7; layout from formats/sell.py with op="wc" — rows = fibers).
# ----------------------------------------------------------------------------

def _wc_sell_kernel(atoms_ref,            # (ROW_TILE, SLOT_TILE) int32
                    yg_ref,               # (ROW_TILE, SLOT_TILE, Ntheta_p) fp
                    vals_ref,             # (ROW_TILE, SLOT_TILE) fp
                    d_ref,                # (Na, Ntheta_p) fp, VMEM-resident
                    w_ref):               # (1, ROW_TILE) output block
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        w_ref[...] = jnp.zeros_like(w_ref)

    r, s = atoms_ref.shape
    d_rows = d_ref[atoms_ref[...].reshape(-1)]              # (R*S, Ntheta_p)
    # cast to the accumulator dtype BEFORE the reductions: bf16-stored
    # operands must still dot/accumulate in the output dtype (fp32)
    prods = (d_rows.reshape(r, s, -1) * yg_ref[...]).astype(w_ref.dtype)
    dots = jnp.sum(prods, axis=-1)
    # slot [r, s] belongs to fiber row r by layout: reduce the slot axis.
    w_ref[...] += (dots * vals_ref[...].astype(w_ref.dtype)
                   ).sum(axis=1)[None, :]


def wc_sell_pallas(atoms: jax.Array, yg: jax.Array, vals: jax.Array,
                   dictionary_padded: jax.Array, *, row_tile: int,
                   slot_tile: int, out_dtype=None,
                   interpret: bool = False) -> jax.Array:
    """WC over a fiber-row SELL layout.  ``yg`` is the pre-gathered
    ``(n_rows_padded, width, Ntheta_p)`` stream of Y rows (padding slots
    carry value 0 so their gathered rows are inert).  Returns
    ``(n_row_blocks, row_tile)`` partial weights (reshape + trim to Nf)."""
    n_rows_padded, width = atoms.shape
    n_theta_p = dictionary_padded.shape[1]
    out_dtype = dictionary_padded.dtype if out_dtype is None else out_dtype
    grid = (n_rows_padded // row_tile, width // slot_tile)
    return pl.pallas_call(
        _wc_sell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, slot_tile), lambda i, j: (i, j)),
            pl.BlockSpec((row_tile, slot_tile, n_theta_p),
                         lambda i, j: (i, j, 0)),
            pl.BlockSpec((row_tile, slot_tile), lambda i, j: (i, j)),
            pl.BlockSpec(dictionary_padded.shape, lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, row_tile), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (n_rows_padded // row_tile, row_tile), out_dtype),
        interpret=interpret,
    )(atoms, yg, vals, dictionary_padded)
