"""Pallas TPU grouped matmul for MoE expert FFNs (sorted dispatch executor).

The paper's technique re-applied to MoE: tokens are *restructured* (sorted by
expert id — the data restructuring of §4.1.2), groups are cut into tiles that
never cross an expert boundary (the sync-free partitioning of §4.2.1.2), and
the executor streams token tiles against the scalar-prefetch-selected expert
weight block:

    out[t] = x[t] @ W[expert_of_tile[t]]

Grid is (token_tiles, ff_tiles); the expert id indexes the weight BlockSpec —
an indirect *block* access, which is the TPU-legal form of the paper's
indirect array access.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_T_TILE = 128
DEFAULT_F_TILE = 128


def _gmm_kernel(expert_ref, x_ref, w_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        x_ref[...], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def moe_gmm(expert_of_tile: jax.Array, x_p: jax.Array, w_experts: jax.Array,
            *, t_tile: int = DEFAULT_T_TILE, f_tile: int = DEFAULT_F_TILE,
            interpret: bool = False) -> jax.Array:
    """x_p: (n_tiles*t_tile, d_model) expert-sorted/padded tokens;
    w_experts: (E, d_model, d_ff); returns (n_tiles*t_tile, d_ff)."""
    n_rows, d_model = x_p.shape
    n_exp, _, d_ff = w_experts.shape
    if n_rows % t_tile:
        raise ValueError("token rows must be a multiple of t_tile")
    n_tiles = n_rows // t_tile
    if expert_of_tile.shape[0] != n_tiles:
        raise ValueError("expert_of_tile must have one entry per token tile")
    f_tile = min(f_tile, d_ff)
    if d_ff % f_tile:
        raise ValueError("d_ff must be a multiple of f_tile")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles, d_ff // f_tile),
        in_specs=[
            pl.BlockSpec((t_tile, d_model), lambda t, f, e: (t, 0)),
            pl.BlockSpec((1, d_model, f_tile), lambda t, f, e: (e[t], 0, f)),
        ],
        out_specs=pl.BlockSpec((t_tile, f_tile), lambda t, f, e: (t, f)),
    )
    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, d_ff), x_p.dtype),
        interpret=interpret,
    )(expert_of_tile, x_p, w_experts)
