"""Pallas TPU kernel for DSC (y = M w), the diffusion-signal computation.

Executor for an inspector ``TilePlan`` over voxel-sorted coefficients
(DESIGN.md §2).  Geometry per grid step ``t``:

  * ``D`` (dictionary) is VMEM-resident for the whole grid — the TPU analogue
    of the paper keeping D rows in GPU shared memory.
  * a coefficient tile contributes ``contrib = D[atoms_t] * scaled_t[:,None]``
    of shape (C_TILE, Ntheta): the daxpy/BLAS inner loop, vectorized across
    the 128-lane dimension (Ntheta padded to a lane multiple, mirroring the
    paper's pad-to-warp-multiple trick).
  * the voxel scatter becomes a one-hot MXU matmul
    ``(ROW_TILE x C_TILE) @ (C_TILE x Ntheta)`` into the output row-block —
    the synchronization-free reduction: the tile plan guarantees a tile
    touches exactly one row-block, and the sequential TPU grid makes
    consecutive-tile accumulation race-free (no atomics exist or are needed).

Scalar-prefetched ``row_block`` drives the output BlockSpec index_map, which
is exactly the inspector/executor split of the paper: the host-side sort +
tile plan is the inspector, this kernel is the executor.

``dsc_sell_pallas`` is the SELL fast path (DESIGN.md §7): over the blocked
ELL layout of ``formats/sell.py`` the tile -> output-block mapping is the
identity on grid axis 0, so there is **no** scalar prefetch and no one-hot
matmul — slot ``[r, s]`` belongs to output row ``r`` by construction, and
the kernel reduces over the slot axis straight into the resident output
block.  The irregularity the TilePlan machinery handles at run time is paid
once, as padding, at encode time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Frozen fallbacks only: production paths bind launch parameters through the
# factories below (fed by a repro.tune TunePlan); these constants are what
# tune="off" and the pre-tune call sites get.
DEFAULT_C_TILE = 256
DEFAULT_ROW_TILE = 8


def dsc_factory(*, row_tile: int = DEFAULT_ROW_TILE, out_dtype=None,
                interpret: bool = False):
    """Bind COO-DSC launch parameters once (e.g. from a TunePlan).

    Returns a callable with the :func:`dsc_pallas` signature minus the bound
    keywords — the parameterized replacement for reading module constants."""
    return functools.partial(dsc_pallas, row_tile=row_tile,
                             out_dtype=out_dtype, interpret=interpret)


def dsc_sell_factory(*, row_tile: int = DEFAULT_ROW_TILE,
                     slot_tile: int = 32, out_dtype=None,
                     interpret: bool = False):
    """Bind SELL-DSC launch parameters once (e.g. from a TunePlan)."""
    return functools.partial(dsc_sell_pallas, row_tile=row_tile,
                             slot_tile=slot_tile, out_dtype=out_dtype,
                             interpret=interpret)


def _dsc_kernel(row_block_ref,            # scalar prefetch: (T,) int32
                atoms_ref,                # (1, C_TILE) int32
                scaled_ref,               # (1, C_TILE) fp
                local_row_ref,            # (1, C_TILE) int32
                d_ref,                    # (Na, Ntheta_p) fp, VMEM-resident
                y_ref):                   # (ROW_TILE, Ntheta_p) output block
    t = pl.program_id(0)
    prev = row_block_ref[jnp.maximum(t - 1, 0)]
    is_first_visit = jnp.logical_or(t == 0, row_block_ref[t] != prev)

    @pl.when(is_first_visit)
    def _():
        y_ref[...] = jnp.zeros_like(y_ref)

    atoms = atoms_ref[0]                                    # (C_TILE,)
    d_rows = d_ref[atoms]                                   # VMEM gather
    contrib = d_rows * scaled_ref[0][:, None]               # daxpy tile
    row_tile = y_ref.shape[0]
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (row_tile, atoms.shape[0]), 0)
        == local_row_ref[0][None, :]
    ).astype(contrib.dtype)
    # segment reduction on the MXU (replaces atomicAdd / warp shuffle)
    y_ref[...] += jax.lax.dot_general(
        onehot, contrib, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(y_ref.dtype)


def dsc_pallas(row_block: jax.Array, atoms_p: jax.Array, scaled_p: jax.Array,
               local_row_p: jax.Array, dictionary_padded: jax.Array,
               *, row_tile: int, n_row_blocks: int, out_dtype=None,
               interpret: bool = False) -> jax.Array:
    """Run the DSC executor.  Returns (n_row_blocks*row_tile, Ntheta_padded).

    All operands are pre-padded by :mod:`repro.kernels.ops` from a TilePlan.
    ``out_dtype`` pins the accumulator/output dtype independently of the
    storage dtype of ``dictionary_padded`` (bf16 storage keeps fp32
    accumulation: pass out_dtype=float32).
    """
    n_tiles, c_tile = atoms_p.shape
    n_theta_p = dictionary_padded.shape[1]
    out_dtype = dictionary_padded.dtype if out_dtype is None else out_dtype
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, c_tile), lambda t, rb: (t, 0)),
            pl.BlockSpec((1, c_tile), lambda t, rb: (t, 0)),
            pl.BlockSpec((1, c_tile), lambda t, rb: (t, 0)),
            pl.BlockSpec(dictionary_padded.shape, lambda t, rb: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, n_theta_p), lambda t, rb: (rb[t], 0)),
    )
    return pl.pallas_call(
        _dsc_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n_row_blocks * row_tile, n_theta_p), out_dtype),
        interpret=interpret,
    )(row_block, atoms_p, scaled_p, local_row_p, dictionary_padded)


# ----------------------------------------------------------------------------
# SELL fast path: direct row-block accumulation, no prefetch, no one-hot.
# ----------------------------------------------------------------------------

def _dsc_sell_kernel(atoms_ref,           # (ROW_TILE, SLOT_TILE) int32
                     scaled_ref,          # (ROW_TILE, SLOT_TILE) fp
                     d_ref,               # (Na, Ntheta_p) fp, VMEM-resident
                     y_ref):              # (ROW_TILE, Ntheta_p) output block
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        y_ref[...] = jnp.zeros_like(y_ref)

    r, s = atoms_ref.shape
    d_rows = d_ref[atoms_ref[...].reshape(-1)]              # (R*S, Ntheta_p)
    contrib = d_rows * scaled_ref[...].reshape(-1)[:, None]  # daxpy slots
    # slot [r, s] belongs to output row r by layout: reduce the slot axis,
    # accumulate directly — the one-hot matmul of _dsc_kernel is gone.
    # cast BEFORE the reduction: with bf16-stored operands the slot-axis sum
    # must still accumulate in the output dtype (fp32).
    y_ref[...] += contrib.reshape(r, s, -1).astype(y_ref.dtype).sum(axis=1)


def dsc_sell_pallas(atoms: jax.Array, scaled: jax.Array,
                    dictionary_padded: jax.Array, *, row_tile: int,
                    slot_tile: int, out_dtype=None,
                    interpret: bool = False) -> jax.Array:
    """DSC over a SELL layout.  ``atoms``/``scaled`` are the dense
    ``(n_rows_padded, width)`` slot arrays of ``formats/sell.py:SellPhi``
    (``scaled = w[fibers] * values``, padding slots 0).  Returns
    ``(n_rows_padded, Ntheta_padded)``; grid axis 0 IS the output block
    index, axis 1 sweeps slot chunks into the resident block."""
    n_rows_padded, width = atoms.shape
    n_theta_p = dictionary_padded.shape[1]
    out_dtype = dictionary_padded.dtype if out_dtype is None else out_dtype
    grid = (n_rows_padded // row_tile, width // slot_tile)
    return pl.pallas_call(
        _dsc_sell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, slot_tile), lambda i, j: (i, j)),
            pl.BlockSpec((row_tile, slot_tile), lambda i, j: (i, j)),
            pl.BlockSpec(dictionary_padded.shape, lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, n_theta_p), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows_padded, n_theta_p), out_dtype),
        interpret=interpret,
    )(atoms, scaled, dictionary_padded)
