"""Pure-jnp oracles for the Pallas kernels (same operands, same padding).

These mirror the executor math exactly — including tile padding and the
one-hot segment reduction — so kernel tests can assert elementwise equality,
while `repro.core.spmv` provides the independent mathematical oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dsc_ref(row_block, atoms_p, scaled_p, local_row_p, dictionary_padded,
            *, row_tile: int, n_row_blocks: int) -> jax.Array:
    n_tiles, c_tile = atoms_p.shape
    n_theta_p = dictionary_padded.shape[1]
    out = jnp.zeros((n_row_blocks * row_tile, n_theta_p), dictionary_padded.dtype)
    d_rows = dictionary_padded[atoms_p]                      # (T, C, Np)
    contrib = d_rows * scaled_p[..., None]                   # (T, C, Np)
    rows = row_block[:, None] * row_tile + local_row_p       # (T, C) global rows
    return out.at[rows.reshape(-1)].add(contrib.reshape(-1, n_theta_p))


def wc_ref(row_block, atoms_p, yg_p, vals_p, local_row_p, dictionary_padded,
           *, fib_tile: int, n_fib_blocks: int) -> jax.Array:
    d_rows = dictionary_padded[atoms_p]                      # (T, C, Np)
    dots = jnp.sum(d_rows * yg_p, axis=-1) * vals_p          # (T, C)
    rows = row_block[:, None] * fib_tile + local_row_p       # (T, C)
    out = jnp.zeros((n_fib_blocks * fib_tile,), dictionary_padded.dtype)
    out = out.at[rows.reshape(-1)].add(dots.reshape(-1))
    return out.reshape(n_fib_blocks, fib_tile)


def moe_gmm_ref(x_p, w_experts, expert_of_tile) -> jax.Array:
    """Grouped matmul oracle: x_p (T, TT, d), w (E, d, f) -> (T, TT, f)."""
    return jnp.einsum("gcd,gdf->gcf", x_p, w_experts[expert_of_tile])
