"""Pallas TPU kernels for F-COO: both SpMV ops off ONE resident layout.

The SELL kernels (kernels/dsc.py / wc.py) buy direct row-block accumulation
with a per-op padded copy; the F-COO pair (Liu et al., arXiv:1705.09905)
spends segment metadata instead of bytes.  Geometry per grid step ``t``:

  * one fixed ``c_tile`` chunk of the linearized coefficient stream
    (formats/fcoo.py) is loaded; ``D`` stays VMEM-resident as everywhere,
  * the chunk's precomputed segment ranks turn the within-chunk segment
    reduction into a one-hot ``(K, c_tile)`` MXU matmul — the same
    synchronization-free trick as the COO kernels, but against *chunk-local*
    segments instead of a planned output row-block,
  * each step writes its own ``(1, K, .)`` partials block — no cross-step
    accumulation, no scalar prefetch, no ``@pl.when`` zero-init hazard; the
    caller (kernels/ops.py) folds chunk-boundary segments with one batched
    scatter-add over the format's ``seg_rows_*`` map.

bf16 storage keeps fp32 accumulation: products are cast to the output dtype
before any reduction and the one-hot matmuls pin
``preferred_element_type=float32`` (DESIGN.md §10.3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def fcoo_dsc_factory(*, out_dtype=None, interpret: bool = False):
    """Bind F-COO DSC launch parameters once (e.g. from a TunePlan); the
    tile geometry itself (c_tile, K) is carried by the operand shapes."""
    return functools.partial(dsc_fcoo_pallas, out_dtype=out_dtype,
                             interpret=interpret)


def fcoo_wc_factory(*, out_dtype=None, interpret: bool = False):
    """Bind F-COO WC launch parameters once (e.g. from a TunePlan)."""
    return functools.partial(wc_fcoo_pallas, out_dtype=out_dtype,
                             interpret=interpret)


def _dsc_fcoo_kernel(atoms_ref,           # (1, C_TILE) int32
                     ranks_ref,           # (1, C_TILE) int32, chunk-local
                     scaled_ref,          # (1, C_TILE) fp (w[fiber] * value)
                     d_ref,               # (Na, Ntheta_p) fp, VMEM-resident
                     out_ref):            # (1, K, Ntheta_p) segment partials
    atoms = atoms_ref[0]                                    # (C_TILE,)
    d_rows = d_ref[atoms]                                   # VMEM gather
    contrib = d_rows * scaled_ref[0][:, None]               # daxpy chunk
    k = out_ref.shape[1]
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (k, atoms.shape[0]), 0)
        == ranks_ref[0][None, :]
    ).astype(contrib.dtype)
    # within-chunk segment reduction on the MXU; the block is exclusively
    # this grid step's, so plain assignment (no accumulation) is race-free
    out_ref[...] = jax.lax.dot_general(
        onehot, contrib, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)[None]


def dsc_fcoo_pallas(atoms: jax.Array, ranks: jax.Array, scaled: jax.Array,
                    dictionary_padded: jax.Array, *, seg_k: int,
                    out_dtype=None, interpret: bool = False) -> jax.Array:
    """DSC segment partials over the F-COO stream.

    ``atoms``/``ranks``/``scaled`` are the ``(n_chunks, c_tile)`` chunked
    views of the resident stream (``scaled = w[fibers] * values``; padding
    slots carry value 0).  Returns ``(n_chunks, seg_k, Ntheta_padded)``
    partials — the caller scatter-adds them over ``seg_rows_dsc``."""
    n_chunks, c_tile = atoms.shape
    n_theta_p = dictionary_padded.shape[1]
    out_dtype = dictionary_padded.dtype if out_dtype is None else out_dtype
    return pl.pallas_call(
        _dsc_fcoo_kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((1, c_tile), lambda t: (t, 0)),
            pl.BlockSpec((1, c_tile), lambda t: (t, 0)),
            pl.BlockSpec((1, c_tile), lambda t: (t, 0)),
            pl.BlockSpec(dictionary_padded.shape, lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, seg_k, n_theta_p), lambda t: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, seg_k, n_theta_p),
                                       out_dtype),
        interpret=interpret,
    )(atoms, ranks, scaled, dictionary_padded)


def _wc_fcoo_kernel(atoms_ref,            # (1, C_TILE) int32 (WC order)
                    ranks_ref,            # (1, C_TILE) int32, chunk-local
                    vals_ref,             # (1, C_TILE) fp
                    yg_ref,               # (1, C_TILE, Ntheta_p) fp
                    d_ref,                # (Na, Ntheta_p) fp, VMEM-resident
                    out_ref):             # (1, K) segment partials
    atoms = atoms_ref[0]                                    # (C_TILE,)
    d_rows = d_ref[atoms]                                   # VMEM gather
    # cast BEFORE the reductions: bf16-stored operands must still
    # dot/accumulate in the output dtype (fp32)
    prods = (d_rows * yg_ref[0]).astype(out_ref.dtype)
    dots = prods.sum(axis=-1) * vals_ref[0].astype(out_ref.dtype)
    k = out_ref.shape[1]
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (k, dots.shape[0]), 0)
        == ranks_ref[0][None, :]
    ).astype(dots.dtype)
    out_ref[...] = jax.lax.dot_general(
        onehot, dots[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype).reshape(1, k)


def wc_fcoo_pallas(atoms: jax.Array, ranks: jax.Array, vals: jax.Array,
                   yg: jax.Array, dictionary_padded: jax.Array, *,
                   seg_k: int, out_dtype=None,
                   interpret: bool = False) -> jax.Array:
    """WC segment partials over the fiber-major view of the same stream.

    ``atoms``/``vals`` are the ``wc_perm``-gathered chunked views, ``yg``
    the pre-gathered ``(n_chunks, c_tile, Ntheta_p)`` Y rows (padding slots
    gather a real row but carry value 0, so they are inert).  Returns
    ``(n_chunks, seg_k)`` partials for the ``seg_rows_wc`` scatter-add."""
    n_chunks, c_tile = atoms.shape
    n_theta_p = dictionary_padded.shape[1]
    out_dtype = dictionary_padded.dtype if out_dtype is None else out_dtype
    return pl.pallas_call(
        _wc_fcoo_kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((1, c_tile), lambda t: (t, 0)),
            pl.BlockSpec((1, c_tile), lambda t: (t, 0)),
            pl.BlockSpec((1, c_tile), lambda t: (t, 0)),
            pl.BlockSpec((1, c_tile, n_theta_p), lambda t: (t, 0, 0)),
            pl.BlockSpec(dictionary_padded.shape, lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, seg_k), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n_chunks, seg_k), out_dtype),
        interpret=interpret,
    )(atoms, ranks, vals, yg, dictionary_padded)
