"""TunePlan: one executor's measured launch-parameter choice (DESIGN.md §10).

The paper's target-dependent optimizations (its Table 9 platform sweep) are a
search over launch parameters — tile sizes, partitioning granularity, data
layout knobs — whose winner depends on both the dataset and the hardware
(Chen et al. arXiv:1805.11938 for SpMV formats, Laukemann et al.
arXiv:2403.06348 for linearized tensor layouts).  A :class:`TunePlan` is the
serialized outcome of that search for one (dataset, executor, backend)
triple: the winning tile parameters plus the resolved compute dtype, cached
through :mod:`repro.core.plan_cache` so a warm engine rebuild replays the
choice instead of re-measuring.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

#: documented accuracy contract for ``compute_dtype="bf16"`` (bf16 storage of
#: the static operands — dictionary + Phi values — with fp32 accumulation):
#: matvec/rmatvec outputs stay within this relative tolerance of the pure-fp32
#: executor across the whole executor x format conformance matrix
#: (regression-tested in tests/test_tune.py).  bf16 keeps an 8-bit mantissa,
#: so each stored operand carries ~0.4% rounding; the fp32 accumulation keeps
#: the reduction from amplifying it beyond the per-term bound.
BF16_RTOL = 2e-2
BF16_ATOL = 2e-2

#: the compute-dtype axis of the search space ("auto" resolves to one of
#: these; storage dtype only — accumulation stays fp32 either way)
COMPUTE_DTYPES = ("fp32", "bf16")

#: LifeConfig.tune modes: "off" = frozen config constants (pre-tune
#: behaviour), "cached" = replay a persisted plan if one exists but never
#: measure, "full" = search on miss and persist the winner.
TUNE_MODES = ("off", "cached", "full")


@dataclasses.dataclass
class TunePlan:
    """Winning launch parameters for one executor on one dataset/backend.

    ``params`` holds only the tile axes the executor actually exposes
    (``c_tile``/``row_tile`` for the COO Pallas pair, ``row_tile``/
    ``slot_tile`` for the SELL kernels and their per-cell shard variants);
    ``compute_dtype`` is always resolved ("fp32" or "bf16", never "auto").
    ``reason`` records how the plan came to be: "search" (measured),
    "default" (nothing to search — no tile axes and a fixed dtype),
    "predicted" (tune="cached" miss answered by the learn subsystem's
    predictor — zero measurements; upgraded in place to "search" by
    background refinement), or "untuned" (tune="cached" miss with no
    predictor: config constants, never persisted).
    ``measurements`` keeps the per-candidate costs (label -> seconds) so
    benchmarks and audits can explain the choice without re-measuring.
    ``stats`` carries the ``phi_stats`` feature dict the plan was decided
    under — the learn subsystem's training pairs are harvested from it.
    """

    executor: str
    backend: str                   # jax.default_backend() at tune time
    n_devices: int
    params: Dict[str, int]
    compute_dtype: str
    reason: str = "search"
    measurements: Dict[str, float] = dataclasses.field(default_factory=dict)
    stats: Dict[str, float] = dataclasses.field(default_factory=dict)

    def apply(self, config):
        """Return ``config`` with the tuned launch parameters substituted.

        Only fields the config dataclass actually declares are replaced, so
        the same plan can parameterize engine configs and the slimmer
        benchmark configs alike.
        """
        fields = {f.name for f in dataclasses.fields(config)}
        updates = {k: int(v) for k, v in self.params.items() if k in fields}
        if "compute_dtype" in fields:
            updates["compute_dtype"] = self.compute_dtype
        return dataclasses.replace(config, **updates) if updates else config

    def describe(self) -> str:
        ps = ",".join(f"{k}={v}" for k, v in sorted(self.params.items()))
        return (f"tune[{self.executor}@{self.backend}x{self.n_devices}]: "
                f"{ps or 'no tile axes'}, {self.compute_dtype} "
                f"({self.reason})")
