"""Search-space enumeration for the kernel autotuner (DESIGN.md §10.1).

One table, :data:`TUNABLE_TILES`, names the launch-parameter axes each Pallas
executor exposes — the analogue of the paper's per-platform sweep columns.
Executors without an entry (the pure-jnp scatter/segment paths) have no tile
axes; their search space degenerates to the compute-dtype axis.

Candidate enumeration always includes the *current* config values, so the
measured winner can never be worse than the frozen defaults on the tuner's
own objective — the invariant ``benchmarks/table15_tuning.py`` reports on.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Tuple

from repro.tune.plan import COMPUTE_DTYPES

#: executor registry name -> the launch-parameter axes its kernels take.
#: The COO Pallas pair tiles coefficients (c_tile) into row blocks
#: (row_tile); the SELL kernels and their per-cell shard variants walk
#: (row_tile, slot_tile) blocks of the slot layout; the F-COO pair chunks
#: the linearized stream (c_tile) with seg_tile-quantized segment blocks.
TUNABLE_TILES: Dict[str, Tuple[str, ...]] = {
    "kernel": ("c_tile", "row_tile"),
    "kernel-sell": ("row_tile", "slot_tile"),
    "kernel-fcoo": ("c_tile", "seg_tile"),
    "shard-sell": ("row_tile", "slot_tile"),
}

#: per-axis candidate values (the current config value is always added).
#: row_tile stays a multiple of the fp32 sublane (8); slot_tile and c_tile
#: sweep the padding-vs-occupancy trade-off the paper's Table 9 measures.
AXIS_CANDIDATES: Dict[str, Tuple[int, ...]] = {
    "c_tile": (128, 256, 512),
    "row_tile": (8, 16),
    "slot_tile": (16, 32, 64),
    "seg_tile": (8, 16, 32),
}


def tile_axes(executor: str) -> Tuple[str, ...]:
    """Launch-parameter axes executor ``executor`` exposes (may be empty)."""
    return TUNABLE_TILES.get(executor, ())


def current_params(executor: str, config) -> Dict[str, int]:
    """The config's own values for the executor's tile axes."""
    return {ax: int(getattr(config, ax)) for ax in tile_axes(executor)}


def search_space(executor: str, config, *,
                 budget: int | None = None) -> List[dict]:
    """Candidate list: ``{"params": {axis: value}, "compute_dtype": str}``.

    The first candidate is always the current config under its requested (or
    fp32-first, when "auto") dtype — truncating to ``budget`` can therefore
    never drop the default configuration, only exotic corners of the grid.
    """
    axes = tile_axes(executor)
    cur = current_params(executor, config)
    requested = getattr(config, "compute_dtype", "fp32")
    dtypes = COMPUTE_DTYPES if requested == "auto" else (requested,)

    per_axis = [sorted(set(AXIS_CANDIDATES[ax]) | {cur[ax]}) for ax in axes]
    tiles = [dict(zip(axes, combo))
             for combo in itertools.product(*per_axis)] if axes else [{}]
    # current-config-first ordering so budget truncation keeps the default
    tiles.sort(key=lambda t: (t != cur, tuple(sorted(t.items()))))

    out: List[dict] = []
    for dt in dtypes:              # default tiles under every dtype first
        out.append(dict(params=dict(cur), compute_dtype=dt))
    for t in tiles:
        for dt in dtypes:
            cand = dict(params=dict(t), compute_dtype=dt)
            if cand not in out:
                out.append(cand)
    if budget is not None and budget > 0:
        out = out[:max(budget, len(dtypes))]
    return out
