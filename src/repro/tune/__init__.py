"""Kernel autotuning subsystem (DESIGN.md §10).

Searches the launch-parameter space of the Pallas executors — tile shapes
plus the compute-dtype axis — per (dataset, backend, device count), and
persists each winner as a :class:`~repro.tune.plan.TunePlan` through the
content-addressed plan cache.  ``LifeConfig(tune="cached"|"full")`` switches
it on; ``core/registry.ExecutorRegistry.create`` resolves and applies the
plan beneath every engine.
"""
from repro.tune.plan import (BF16_ATOL, BF16_RTOL, COMPUTE_DTYPES,
                             TUNE_MODES, TunePlan)
from repro.tune.space import (AXIS_CANDIDATES, TUNABLE_TILES, current_params,
                              search_space, tile_axes)
from repro.tune.tuner import (backend_name, resolve_plan, tunable_executors,
                              validate_config)

__all__ = [
    "BF16_ATOL", "BF16_RTOL", "COMPUTE_DTYPES", "TUNE_MODES", "TunePlan",
    "AXIS_CANDIDATES", "TUNABLE_TILES", "current_params", "search_space",
    "tile_axes", "backend_name", "resolve_plan", "tunable_executors",
    "validate_config",
]
