"""The measurement loop every runtime search in the repo shares.

The paper selects its restructuring at runtime from "the average execution
time for three runs"; this module is that loop factored out once, so the
three searches that exist today — restructuring choice
(``core/restructure.autotune_plan``), format choice (``formats/select``,
via ``autotune_plan``), and kernel launch parameters (``tune/tuner``) —
measure with identical warmup/blocking/repeat semantics and their outcomes
stay comparable.

Deliberately dependency-light: jax only, so it can be imported from the
bottom of the stack (``core/restructure``) without cycles.
"""
from __future__ import annotations

import time
import warnings
from typing import Callable, Sequence, Tuple

import jax

#: measurement defaults, mirroring the paper's "three runs" protocol
DEFAULT_WARMUP = 1
DEFAULT_REPEATS = 3

#: process-lifetime count of :func:`time_call` invocations.  Every runtime
#: search in the repo times through this one function, so the counter is a
#: complete audit of measurement work — the zero-measurement contract of
#: the predicted cold-start path is asserted against it (tests and the
#: table16 benchmark snapshot it before/after a build).
_N_MEASURED = 0


def measurement_count() -> int:
    """Total ``time_call`` invocations in this process."""
    return _N_MEASURED


def block(out):
    """Block until every array leaf of ``out`` is ready (timing barrier)."""
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return out


def time_call(fn: Callable, *args, warmup: int = DEFAULT_WARMUP,
              repeats: int = DEFAULT_REPEATS) -> float:
    """Mean seconds per blocking call after ``warmup`` compile/warm calls."""
    global _N_MEASURED
    _N_MEASURED += 1
    for _ in range(warmup):
        block(fn(*args))
    t0 = time.perf_counter()
    for _ in range(repeats):
        block(fn(*args))
    return (time.perf_counter() - t0) / max(1, repeats)


def measure_candidates(candidates: Sequence, run: Callable[[object], float],
                       ) -> Tuple[int, dict]:
    """Run ``run(candidate) -> cost_seconds`` for every candidate.

    Returns (index of the cheapest candidate, {str(candidate): cost}).
    ``run`` owns preparation *and* timing (usually via :func:`time_call`)
    so callers decide what "cost" means — a single op, a weighted pair,
    a whole iteration.

    Duplicate labels are disambiguated with a ``#<index>`` suffix instead
    of silently overwriting: persisted measurement dicts must account for
    every candidate actually timed, or audits under-count the search.
    """
    if not candidates:
        raise ValueError("need at least one candidate")
    costs = {}
    best_i, best_cost = 0, None
    for i, cand in enumerate(candidates):
        cost = float(run(cand))
        label = _label(cand)
        if label in costs:
            warnings.warn(f"duplicate search candidate label {label!r}; "
                          f"keying repeat as {label}#{i}", stacklevel=2)
            label = f"{label}#{i}"
        costs[label] = cost
        if best_cost is None or cost < best_cost:
            best_i, best_cost = i, cost
    return best_i, costs


def _label(cand) -> str:
    if isinstance(cand, dict):
        parts = []
        for k in sorted(cand):
            v = cand[k]
            parts.append(f"{k}={_label(v) if isinstance(v, dict) else v}")
        return ",".join(parts)
    return str(cand)
