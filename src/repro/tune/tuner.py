"""Kernel autotuner: search the launch-parameter space, persist the winner.

Entry point is :func:`resolve_plan`, called by
``core/registry.ExecutorRegistry.create`` whenever the engine config asks
for tuning (``LifeConfig.tune != "off"``):

  * ``tune="cached"`` — replay a persisted :class:`~repro.tune.plan.TunePlan`
    if the cache holds one for this (dataset, geometry, executor, backend,
    device count, requested dtype) key; on a miss, consult the learn
    subsystem's trained predictor (DESIGN.md §14) for a zero-measurement
    ``reason="predicted"`` plan — persisted, and queued for background
    refinement — and only when no predictor answers fall back to the
    config's frozen constants (intake paths must never stall on a search).
  * ``tune="full"`` — same warm-hit fast path (a rebuild on tuned data pays
    zero measurements, regression-tested), except a cached *predicted* plan
    counts as a miss (that is what refinement runs: the full mode measures
    and overwrites it in place); on a miss, measure every candidate from
    :func:`repro.tune.space.search_space` through the shared loop in
    :mod:`repro.tune.search` and persist the winner.

Each candidate is measured as a *bound executor* — the same factory path
production uses — with the cost weighted ``2 x DSC + 1.5 x WC``: the
per-iteration op mix of SBBNNLS (two matvecs every iteration, a rmatvec on
~three of four), matching the weighting ``formats/select`` uses when it
arbitrates layouts.  Format choice and tile choice thereby share one search
currency; see DESIGN.md §10.2.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.tune import search
from repro.tune.plan import COMPUTE_DTYPES, TUNE_MODES, TunePlan
from repro.tune.space import current_params, search_space, tile_axes

#: SBBNNLS per-iteration op mix: DSC runs every iteration plus the
#: line-search probe, WC on odd/even alternation — the same dominant-op
#: weighting formats/select.py measures under.
DSC_WEIGHT = 2.0
WC_WEIGHT = 1.5


def backend_name() -> str:
    """The platform tag tune keys are scoped by (cpu / gpu / tpu)."""
    return jax.default_backend()


def _resolved_dtype(config) -> str:
    dt = getattr(config, "compute_dtype", "fp32")
    return "fp32" if dt == "auto" else dt


def validate_config(config) -> None:
    """Shared engine-side validation of the tuning knobs."""
    mode = getattr(config, "tune", "off")
    if mode not in TUNE_MODES:
        raise ValueError(f"tune must be one of {TUNE_MODES}, got {mode!r}")
    dt = getattr(config, "compute_dtype", "fp32")
    if dt not in COMPUTE_DTYPES + ("auto",):
        raise ValueError(
            f"compute_dtype must be one of {COMPUTE_DTYPES + ('auto',)}, "
            f"got {dt!r}")
    if dt == "auto" and mode == "off":
        raise ValueError(
            'compute_dtype="auto" is a searched axis; it needs '
            'tune="cached" or tune="full"')
    predict = getattr(config, "predict", "auto")
    if predict not in ("auto", "off"):
        raise ValueError(
            f'predict must be "auto" or "off", got {predict!r}')


def _untuned(name: str, config) -> TunePlan:
    return TunePlan(executor=name, backend=backend_name(),
                    n_devices=len(jax.devices()),
                    params=current_params(name, config),
                    compute_dtype=_resolved_dtype(config), reason="untuned")


def _phi_stats_for(phi, config) -> dict:
    from repro.core.inspector import phi_stats
    return phi_stats(phi, row_tile=int(getattr(config, "row_tile", 8)),
                     slot_tile=int(getattr(config, "slot_tile", 32)))


def _predicted(name: str, key: str, phi, problem, config,
               cache) -> Optional[TunePlan]:
    """Zero-measurement rung of the ladder for a tune="cached" miss.

    Replays the nearest trained dataset's winning params for this
    (executor, backend) — sanitized to the axes the executor actually
    exposes, with any axis the example lacks filled from the config (a
    predicted plan must always be a legal configuration).  Returns None
    (caller falls back to frozen constants) when prediction is disabled,
    no predictor is trained, or there is nothing to predict — an executor
    without tile axes under a fixed dtype is fully determined already.
    """
    if getattr(config, "predict", "auto") == "off" or not cache.enabled:
        return None
    axes = tile_axes(name)
    requested = getattr(config, "compute_dtype", "fp32")
    if not axes and requested != "auto":
        return None
    from repro.learn import load_predictor
    predictor = load_predictor(cache.directory)
    if predictor is None:
        return None
    stats = _phi_stats_for(phi, config)
    payload = predictor.predict_tune(stats, executor=name,
                                     backend=backend_name())
    if payload is None:
        obs.counter("learn.predict", kind="tune", outcome="fallback").inc()
        return None
    obs.counter("learn.predict", kind="tune", outcome="hit").inc()
    params = current_params(name, config)
    params.update({ax: int(payload[ax]) for ax in axes if ax in payload})
    dtype = _resolved_dtype(config)
    if requested == "auto" and payload.get("compute_dtype") in COMPUTE_DTYPES:
        dtype = payload["compute_dtype"]
    plan = TunePlan(executor=name, backend=backend_name(),
                    n_devices=len(jax.devices()), params=params,
                    compute_dtype=dtype, reason="predicted", stats=stats)
    cache.put_tune_plan(key, plan)
    _enqueue_refinement(name, key, phi, problem, config, cache)
    return plan


def _enqueue_refinement(name: str, key: str, phi, problem, config,
                        cache) -> None:
    """Queue a measured tune="full" re-resolve to upgrade a predicted plan
    (the full mode treats the cached predicted entry as a miss and
    overwrites it with the searched winner)."""
    from repro.learn import refine

    def _task() -> None:
        resolve_plan(name, phi, problem, replace(config, tune="full"), cache)

    refine.QUEUE.push("tune", key, _task)


def resolve_plan(name: str, phi, problem, config, cache) -> Optional[TunePlan]:
    """TunePlan for executor ``name`` on ``phi`` per ``config.tune`` mode.

    Returns None when tuning is off.  Never measures under "cached"; under
    "full" a warm cache hit also skips every measurement.
    """
    validate_config(config)
    mode = getattr(config, "tune", "off")
    if mode == "off":
        return None

    from repro.core.plan_cache import tune_plan_key
    from repro.core.registry import REGISTRY

    import numpy as np
    d = problem.dictionary
    key = tune_plan_key(
        np.asarray(phi.atoms), np.asarray(phi.voxels), np.asarray(phi.fibers),
        sizes=(phi.n_atoms, phi.n_voxels, phi.n_fibers),
        n_theta=int(d.shape[1]), executor=name,
        fmt=REGISTRY.consumes(name), backend=backend_name(),
        n_devices=len(jax.devices()),
        compute_dtype=getattr(config, "compute_dtype", "fp32"),
        budget=int(getattr(config, "tune_budget", 0)),
        mesh=(int(getattr(config, "shard_rows", 1)),
              int(getattr(config, "shard_cols", 1))))
    plan = cache.get_tune_plan(key)
    if plan is not None:
        if plan.reason == "predicted":
            if mode == "full":
                plan = None       # refinement path: measure and overwrite
            else:
                # still serving a prediction: make sure refinement is (re)
                # queued — a process restart drops the in-memory queue
                _enqueue_refinement(name, key, phi, problem, config, cache)
        if plan is not None:
            return plan
    if mode == "cached":
        plan = _predicted(name, key, phi, problem, config, cache)
        if plan is not None:
            return plan
        # miss: frozen constants, no measurement, nothing persisted (a later
        # tune="full" run must still be able to search and fill this key)
        return _untuned(name, config)

    candidates = search_space(name, config,
                              budget=getattr(config, "tune_budget", None))
    if len(candidates) == 1:
        # no tile axes and a fixed dtype: nothing to measure — persist the
        # degenerate plan so tune="cached" rebuilds hit instead of missing
        cand = candidates[0]
        plan = TunePlan(executor=name, backend=backend_name(),
                        n_devices=len(jax.devices()), params=cand["params"],
                        compute_dtype=cand["compute_dtype"], reason="default")
        cache.put_tune_plan(key, plan)
        return plan

    w_probe = jnp.ones((phi.n_fibers,), d.dtype)
    y_probe = jnp.ones((phi.n_voxels, d.shape[1]), d.dtype)

    def run(cand) -> float:
        cfg = replace(config, tune="off", compute_dtype=cand["compute_dtype"])
        if cand["params"]:
            cfg = replace(cfg, **cand["params"])
        ex = REGISTRY.create(name, phi, problem, cfg, cache)
        return (DSC_WEIGHT * search.time_call(ex.matvec, w_probe)
                + WC_WEIGHT * search.time_call(ex.rmatvec, y_probe))

    with obs.span("tune.search", {"executor": name,
                                  "candidates": len(candidates)}):
        best_i, costs = search.measure_candidates(candidates, run)
    # cold path (a search compiles + times every candidate), so per-call
    # instrument fetch is fine here — no need to hold references
    obs.counter("tune.searches", executor=name).inc()
    obs.counter("tune.measurements").inc(float(len(candidates)))
    obs.histogram("tune.measurements.per_search").observe(
        float(len(candidates)))
    winner = candidates[best_i]
    # the phi_stats the search was decided under ride along as the learn
    # subsystem's training features (harvested by repro.learn.harvest)
    plan = TunePlan(executor=name, backend=backend_name(),
                    n_devices=len(jax.devices()), params=winner["params"],
                    compute_dtype=winner["compute_dtype"], reason="search",
                    measurements=costs, stats=_phi_stats_for(phi, config))
    cache.put_tune_plan(key, plan)
    return plan


def tunable_executors() -> tuple:
    """Executor names with at least one tile axis (introspection helper)."""
    from repro.tune.space import TUNABLE_TILES
    return tuple(sorted(TUNABLE_TILES))


__all__ = ["resolve_plan", "validate_config", "backend_name",
           "tunable_executors", "tile_axes", "DSC_WEIGHT", "WC_WEIGHT"]
