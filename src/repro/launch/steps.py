"""train_step / serve_step builders shared by the launcher and the dry-run.

`make_train_step(cfg, opt)` returns the canonical fused step:
    (params, opt_state, batch) -> (params, opt_state, metrics)
with value_and_grad over models.transformer.loss_fn and the optimizer update
inline (so the compiled artifact contains the full iteration the roofline
measures — forward, backward, reduction, update).

`make_serve_step(cfg)` returns the one-token decode step; `make_prefill(cfg)`
the prefill.  All are pure and jit-able with explicit shardings.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.optim.adamw import OptConfig, apply_updates, init_opt_state


def make_train_step(cfg: ArchConfig, opt: OptConfig,
                    grad_specs: Any = None) -> Callable:
    """grad_specs: optional PartitionSpec tree (usually the param specs) —
    constrains gradients so GSPMD computes each dW shard locally and reduces
    over the data axes only, instead of replicating dW and all-reducing over
    the whole mesh."""
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, batch), has_aux=True)(params)
        if grad_specs is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_specs)
        params, opt_state, opt_metrics = apply_updates(
            opt, params, grads, opt_state)
        return params, opt_state, {**metrics, **opt_metrics,
                                   "total_loss": loss}
    return train_step


def make_eval_step(cfg: ArchConfig) -> Callable:
    def eval_step(params, batch):
        loss, metrics = T.loss_fn(cfg, params, batch)
        return metrics
    return eval_step


def make_prefill(cfg: ArchConfig) -> Callable:
    def prefill_step(params, batch):
        return T.prefill(cfg, params, batch)
    return prefill_step


def make_serve_step(cfg: ArchConfig) -> Callable:
    def serve_step(params, batch):
        return T.decode_step(cfg, params, batch)
    return serve_step


def init_all(cfg: ArchConfig, opt: OptConfig, key) -> Tuple[Any, Any]:
    params = T.init_params(cfg, key)
    return params, init_opt_state(opt, params)


def abstract_state(cfg: ArchConfig, opt: OptConfig):
    """(params, opt_state) as ShapeDtypeStructs — no allocation."""
    return jax.eval_shape(
        lambda k: init_all(cfg, opt, k), jax.random.PRNGKey(0))
