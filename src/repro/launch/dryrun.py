import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST stay the first two lines — jax locks the device count on first init,
#   and the production meshes need 512 placeholder devices.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Do not import this module from tests/benchmarks (they want 1 device); it is
a CLI:

  PYTHONPATH=src python -m repro.launch.dryrun --mesh pod --arch deepseek-7b \
      --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all          # full sweep

Per cell it records into results/dryrun/<mesh>/<arch>__<shape>.json:
  memory_analysis (bytes per device), cost_analysis (flops/bytes),
  per-collective bytes from the post-SPMD HLO, the three roofline terms and
  the dominant bottleneck.  Failures (sharding mismatch, OOM-at-compile,
  unsupported collective) are bugs — the sweep fails loudly.
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ARCH_IDS, SHAPES, ArchConfig, get_config,
                                input_specs)
from repro.distributed import hints
from repro.distributed import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as ST
from repro.optim.adamw import OptConfig
from repro.roofline import analysis as RL
from repro.roofline import hlo_cost as HC

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def opt_for(cfg: ArchConfig) -> OptConfig:
    # the 1T MoE trains with factored moments (DESIGN.md §5)
    kind = "adafactor" if cfg.param_count() > SH.FSDP_PARAM_THRESHOLD else "adamw"
    return OptConfig(kind=kind)


def lower_cell(arch: str, shape: str, mesh, *,
               variant: str = "base") -> Dict[str, Any]:
    """Lower+compile one cell; returns the record dict."""
    if arch.startswith("life-stn96"):
        return _lower_life(mesh, shape,
                           variant="1d" if arch.endswith("-1d") else "2d")
    cfg = get_config(arch)
    if not cfg.supports(shape):
        return {"status": "skipped",
                "reason": "full-attention arch at 500k context "
                          "(DESIGN.md §4)"}
    seq, batch, kind = SHAPES[shape]
    opt = opt_for(cfg)
    n_chips = mesh.devices.size
    hints.activate(mesh)

    t0 = time.time()
    state_sds = ST.abstract_state(cfg, opt)
    params_sds, opt_sds = state_sds
    pspecs = SH.param_specs(cfg, mesh, params_sds)
    ospecs = SH.opt_state_specs(cfg, mesh, opt_sds)
    bspecs = SH.batch_specs(cfg, mesh, shape)
    psh = SH.logical_to_shardings(mesh, pspecs)
    osh = SH.logical_to_shardings(mesh, ospecs)
    bsh = SH.logical_to_shardings(mesh, bspecs)
    batch_sds = input_specs(cfg, shape)

    with_sh = lambda sds, sh: jax.tree.map(
        lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h),
        sds, sh)

    if kind == "train":
        fn = ST.make_train_step(cfg, opt, grad_specs=psh)
        args = (with_sh(params_sds, psh), with_sh(opt_sds, osh),
                with_sh(batch_sds, bsh))
        jitted = jax.jit(fn, out_shardings=(psh, osh, None))
    elif kind == "prefill":
        fn = ST.make_prefill(cfg)
        args = (with_sh(params_sds, psh), with_sh(batch_sds, bsh))
        jitted = jax.jit(fn)
    else:  # decode
        fn = ST.make_serve_step(cfg)
        cache_sh = bsh["cache"]
        out_cache_sh = dict(cache_sh)
        out_cache_sh["index"] = SH.logical_to_shardings(
            mesh, jax.sharding.PartitionSpec())
        args = (with_sh(params_sds, psh), with_sh(batch_sds, bsh))
        jitted = jax.jit(fn, out_shardings=(None, out_cache_sh))

    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = HC.xla_cost_analysis(compiled)
    hlo = compiled.as_text()
    # trip-count-corrected cost model (cost_analysis counts while bodies once)
    hc = HC.analyze(hlo, n_chips)
    mf = RL.model_flops(cfg, shape, seq, batch, kind)
    r = RL.roofline(hc.flops, hc.bytes_accessed, hc.collective_total,
                    n_chips, mf)

    return {
        "status": "ok",
        "arch": arch, "shape": shape, "variant": variant,
        "mesh": dict(shape=dict(mesh.shape), n_chips=int(n_chips)),
        "kind": kind,
        "compile_seconds": round(t_compile, 1),
        "memory": _mem_dict(mem),
        "xla_cost_raw": {k: cost[k] for k in ("flops", "bytes accessed")
                         if k in cost},
        "collectives": dict(hc.collective, total=hc.collective_total),
        "loop_multipliers": {k: v for k, v in sorted(
            hc.loops.items(), key=lambda kv: -kv[1])[:8]},
        "roofline": r.as_dict(),
        "mfu_upper_bound": RL.mfu_fraction(r, n_chips, kind),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }


def _lower_life(mesh, shape: str, variant: str = "2d") -> Dict[str, Any]:
    """The paper's own workload: distributed SBBNNLS iteration at Table-9
    scale.  `shape` selects the connectome size; `variant` selects the 2-D
    (voxel x fiber) partition vs the paper-faithful 1-D coefficient
    partition (MPI-LiFE analogue) used as the §Perf baseline."""
    from repro.distributed import life_shard as LS
    scales = {
        "train_4k": dict(n_fibers=500_000, nnz=400_000_000),   # iFOD1 500k
        "prefill_32k": dict(n_fibers=250_000, nnz=190_000_000),
        "decode_32k": dict(n_fibers=100_000, nnz=100_000_000),
        "long_500k": dict(n_fibers=50_000, nnz=50_000_000),
    }
    sc = scales[shape]
    n_chips = mesh.devices.size
    t0 = time.time()
    if variant == "1d":
        specs = LS.life_input_specs_1d(mesh, **sc)
        meta = specs.pop("meta")
        step = LS.make_sharded_step_1d(mesh, meta)
        jitted = jax.jit(step)
        with mesh:
            lowered = jitted.lower(
                specs["a"], specs["v"], specs["fi"], specs["vals"],
                specs["d"], specs["b"], specs["w"], specs["it"])
            compiled = lowered.compile()
    else:
        specs = LS.life_input_specs(mesh, **sc)
        meta = specs.pop("meta")
        step = LS.make_sharded_step(mesh, meta)
        jitted = jax.jit(step)
        with mesh:
            lowered = jitted.lower(
                specs["da"], specs["dv"], specs["df"], specs["dw"],
                specs["wa"], specs["wv"], specs["wf"], specs["ww"],
                specs["d"], specs["b"], specs["w"], specs["it"])
            compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = HC.xla_cost_analysis(compiled)
    hc = HC.analyze(compiled.as_text(), n_chips)
    # useful flops: 2 ops/nnz/theta x (2 DSC + 1.5 WC avg -> here 3 spmv + dots)
    n_theta = meta["n_theta"]
    mf = 3.5 * 2.0 * sc["nnz"] * n_theta
    r = RL.roofline(hc.flops, hc.bytes_accessed, hc.collective_total,
                    n_chips, mf)
    return {
        "status": "ok", "arch": "life-stn96" + ("-1d" if variant == "1d" else ""),
        "shape": shape, "variant": variant,
        "mesh": dict(shape=dict(mesh.shape), n_chips=int(n_chips)),
        "kind": "sbbnnls", "compile_seconds": round(t_compile, 1),
        "memory": _mem_dict(mem),
        "xla_cost_raw": {k: cost[k] for k in ("flops", "bytes accessed")
                         if k in cost},
        "collectives": dict(hc.collective, total=hc.collective_total),
        "roofline": r.as_dict(),
        "scale": sc,
    }


def _mem_dict(mem) -> Dict[str, float]:
    keys = ("temp_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = float(v)
    if out:
        out["total_bytes_per_device"] = (
            out.get("temp_size_in_bytes", 0)
            + out.get("argument_size_in_bytes", 0))
    else:
        out["repr"] = str(mem)
    return out


def run_cell(arch: str, shape: str, mesh_kind: str,
             out_dir: Optional[str] = None) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    try:
        rec = lower_cell(arch, shape, mesh)
    except Exception as e:  # noqa: BLE001 — recorded, sweep continues
        rec = {"status": "error", "arch": arch, "shape": shape,
               "error": repr(e), "traceback": traceback.format_exc()}
    rec["mesh_kind"] = mesh_kind
    out_dir = out_dir or RESULTS_DIR
    d = os.path.join(out_dir, mesh_kind)
    os.makedirs(d, exist_ok=True)
    fname = os.path.join(d, f"{arch}__{shape}.json")
    with open(fname, "w") as f:
        json.dump(rec, f, indent=2, default=float)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or args.arch is None) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or args.shape is None) else (args.shape,)
    meshes = ("pod", "multipod") if args.all else (args.mesh,)
    for mk in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mk))

    failures = 0
    for a, s, mk in cells:
        t0 = time.time()
        rec = run_cell(a, s, mk, args.out)
        dt = time.time() - t0
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec.get("roofline", {})
            extra = (f" dominant={r.get('dominant')}"
                     f" bound={r.get('bound_s', 0):.4f}s"
                     f" mem={rec['memory'].get('total_bytes_per_device', 0)/1e9:.2f}GB")
        elif status == "error":
            failures += 1
            extra = " " + rec["error"][:120]
        print(f"[{mk}] {a:24s} {s:12s} {status:7s} {dt:6.1f}s{extra}",
              flush=True)
        if status == "ok":
            ma = rec["memory"]
            r = rec["roofline"]
            print(f"    memory_analysis: {json.dumps(ma)}", flush=True)
            print(f"    corrected cost: flops/chip={r['flops_per_chip']:.3e}"
                  f" bytes/chip={r['bytes_per_chip']:.3e}"
                  f" coll_bytes/chip={r['coll_bytes_per_chip']:.3e}"
                  f" useful={r['useful_ratio']:.2f}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
