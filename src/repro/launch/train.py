"""Training driver: config -> mesh -> restore-or-init -> step loop.

Fault tolerance per DESIGN.md §5: atomic checkpoints every --ckpt-every
steps, automatic resume from the latest checkpoint (the data pipeline cursor
IS the step, so restart reproduces the exact batch order), straggler watchdog
(per-step wall-time report vs the running median), elastic restart (the mesh
is rebuilt from whatever devices exist; checkpoints reshard on load).

CPU-smoke default: reduced config on the host mesh.  On a real cluster the
same driver runs under jax.distributed with the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --steps 50 \
      --reduced --ckpt-dir /tmp/ck
"""
import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as CK
from repro.configs.base import get_config, reduced as reduce_cfg
from repro.data.tokens import DataConfig, synth_batch_for
from repro.distributed import hints, sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import OptConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
        cfg = dataclasses.replace(cfg, remat=False)
    opt = OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                    decay_steps=args.steps)
    data = DataConfig(seed=0, seq_len=args.seq_len,
                      global_batch=args.global_batch)
    mesh = make_host_mesh(model=args.model_axis)
    hints.activate(mesh)

    params, opt_state = ST.init_all(cfg, opt, jax.random.PRNGKey(0))
    start = 0
    if args.ckpt_dir and CK.latest_step(args.ckpt_dir) is not None:
        start, flat, _ = CK.restore(args.ckpt_dir)
        tree = CK.unflatten_like(
            jax.eval_shape(lambda: {"params": params, "opt": opt_state}),
            flat)
        params = jax.tree.map(jnp.asarray, tree["params"])
        opt_state = jax.tree.map(jnp.asarray, tree["opt"])
        print(f"resumed from step {start}")

    psh = SH.logical_to_shardings(mesh, SH.param_specs(cfg, mesh, params))
    params = CK.place(params, psh)
    step_fn = jax.jit(ST.make_train_step(cfg, opt))

    durations = []
    with mesh:
        for step in range(start, args.steps):
            t0 = time.perf_counter()
            batch = synth_batch_for(cfg, data, step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])       # blocks
            dt = time.perf_counter() - t0
            durations.append(dt)
            med = float(np.median(durations[-20:]))
            if dt > 3.0 * med and len(durations) > 5:
                print(f"[watchdog] step {step} straggled: {dt:.2f}s "
                      f"vs median {med:.2f}s")
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms",
                      flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                CK.save(args.ckpt_dir, step + 1,
                        {"params": params, "opt": opt_state},
                        meta={"arch": cfg.name})
    if args.ckpt_dir:
        CK.save(args.ckpt_dir, args.steps,
                {"params": params, "opt": opt_state}, meta={"arch": cfg.name})
    print("done")


if __name__ == "__main__":
    main()
