"""Production mesh construction.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init)."""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    assert n % model == 0
    return compat.make_mesh((n // model, model), ("data", "model"))
