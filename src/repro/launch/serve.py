"""Serving driver: batched prefill + greedy decode with a static KV budget.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-12b --reduced \
      --batch 4 --prompt-len 16 --gen 16
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced as reduce_cfg
from repro.distributed import hints
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T


def pad_cache(cache, s_max):
    for kn in ("k", "v"):
        if kn in cache:
            kv = cache[kn]
            cache[kn] = jnp.pad(
                kv, ((0, 0), (0, 0), (0, s_max - kv.shape[2]),
                     (0, 0), (0, 0)))
    return cache


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    mesh = make_host_mesh(model=args.model_axis)
    hints.activate(mesh)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    s_max = args.prompt_len + args.gen

    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    prefill = jax.jit(lambda p, b: T.prefill(cfg, p, b))
    decode = jax.jit(lambda p, b: T.decode_step(cfg, p, b))

    with mesh:
        t0 = time.perf_counter()
        logits, cache = prefill(params, {"tokens": prompts})
        cache = pad_cache(cache, s_max)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        jax.block_until_ready(tok)
        t_prefill = time.perf_counter() - t0

        out = [np.asarray(tok)]
        idx = jnp.asarray(args.prompt_len, jnp.int32)
        t0 = time.perf_counter()
        for _ in range(args.gen - 1):
            logits, cache = decode(params, dict(tokens=tok, cache=cache,
                                                cache_index=idx))
            cache.pop("index")
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            out.append(np.asarray(tok))
            idx = idx + 1
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0

    gen = np.concatenate(out, axis=1)
    tput = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.1f}ms")
    print(f"decode: {t_decode*1e3:.1f}ms total, {tput:.1f} tok/s")
    print("generated tokens (first row):", gen[0].tolist())


if __name__ == "__main__":
    main()
