"""Inspector layer: host-side tile & shard planning (paper §4.1.3 + §4.2.1.2).

Two plans are produced from a *sorted* output-index vector:

1. ``TilePlan`` — the Pallas executor plan.  Coefficients are cut into tiles
   of at most ``c_tile`` entries such that every tile touches output rows in
   exactly **one** row-block of ``row_tile`` rows.  On TPU the kernel grid
   walks tiles sequentially; consecutive tiles that share a row-block
   accumulate into the same VMEM-resident output block, and a block is
   flushed before the grid moves to the next one — the synchronization-free
   thread mapping of the paper, expressed as block scheduling instead of
   thread scheduling.

2. ``shard_boundaries`` — the mesh partition plan.  Coefficient ranges per
   device are chosen with equal-nnz targets and then snapped to sub-vector
   boundaries so no output row is ever owned by two devices (Figure 5b:
   schedule the whole sub-vector to the thread that minimizes imbalance).

Inspector cost is O(Nc) on the host and is amortized across the several
hundred SBBNNLS iterations (and across runs via caching), exactly as the
paper argues for its restructuring overhead (3-5% of total runtime).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Executor plan for one SpMV op over sorted coefficients.

    sel:        int32[n_tiles * c_tile]  gather map into the padded coefficient
                arrays; padding entries point at index Nc (a zero dummy).
    row_block:  int32[n_tiles]           output row-block index per grid step.
    local_row:  int32[n_tiles * c_tile]  output row within the row-block.
    n_tiles, c_tile, row_tile, n_rows_padded: static geometry.
    n_coeffs:   the real (unpadded) coefficient count Nc — also the dummy
                index that padding slots in ``sel`` point at.
    """

    sel: np.ndarray
    row_block: np.ndarray
    local_row: np.ndarray
    n_tiles: int
    c_tile: int
    row_tile: int
    n_rows_padded: int
    n_coeffs: int

    @property
    def n_padded(self) -> int:
        return self.n_tiles * self.c_tile

    def occupancy(self) -> float:
        """Fraction of tile slots holding real coefficients (waste metric).

        Padding is exactly the slots pointing at the dummy index Nc —
        comparing against ``sel.max()`` instead would miscount the largest
        real coefficient as padding whenever a plan is exactly full.
        """
        return float((self.sel < self.n_coeffs).mean()) if self.sel.size else 1.0


def auto_tile(sorted_ids: np.ndarray, n_rows: int, *, row_tile: int = 8,
              min_c: int = 32, max_c: int = 512) -> Tuple[int, int]:
    """Pick (c_tile, row_tile) from the data's density so tile slots stay
    occupied: c_tile ~ row_tile x mean nnz-per-touched-row, rounded to a
    power of two.  (The inspector choosing its own geometry from runtime
    statistics is the same move as the paper's runtime restructuring
    selection, applied to tiling.)"""
    sorted_ids = np.asarray(sorted_ids)
    touched = max(1, np.unique(sorted_ids).size)
    per_row = sorted_ids.size / touched
    target = row_tile * per_row
    c = min_c
    while c < target and c < max_c:
        c *= 2
    return int(c), int(row_tile)


def plan_tiles(sorted_ids: np.ndarray, n_rows: int, *, c_tile: int,
               row_tile: int) -> TilePlan:
    """Cut sorted coefficients into (<=c_tile, single row-block) tiles."""
    sorted_ids = np.asarray(sorted_ids, np.int64)
    nc = sorted_ids.size
    if nc and (sorted_ids.min() < 0 or sorted_ids.max() >= n_rows):
        raise ValueError("row id out of range")
    if np.any(np.diff(sorted_ids) < 0):
        raise ValueError("ids must be sorted (run the restructuring first)")

    blocks = sorted_ids // row_tile
    # tile boundaries: every c_tile coefficients, plus every row-block change
    starts = [0]
    i = 0
    while i < nc:
        b = blocks[i]
        # end of this row-block run
        j = int(np.searchsorted(blocks, b, side="right"))
        # cut the run into c_tile chunks
        while i + c_tile < j:
            i += c_tile
            starts.append(i)
        i = j
        if i < nc:
            starts.append(i)
    starts_arr = np.asarray(starts, np.int64) if nc else np.zeros(0, np.int64)
    ends = np.append(starts_arr[1:], nc) if nc else starts_arr
    n_tiles = max(1, starts_arr.size)

    sel = np.full(n_tiles * c_tile, nc, np.int32)          # default: dummy pad
    local_row = np.zeros(n_tiles * c_tile, np.int32)
    row_block = np.zeros(n_tiles, np.int32)
    for t in range(starts_arr.size):
        s, e = int(starts_arr[t]), int(ends[t])
        row_block[t] = blocks[s]
        sel[t * c_tile: t * c_tile + (e - s)] = np.arange(s, e, dtype=np.int32)
        local_row[t * c_tile: t * c_tile + (e - s)] = (
            sorted_ids[s:e] - blocks[s] * row_tile)
    n_rows_padded = -(-n_rows // row_tile) * row_tile
    return TilePlan(sel=sel, row_block=row_block, local_row=local_row,
                    n_tiles=n_tiles, c_tile=c_tile, row_tile=row_tile,
                    n_rows_padded=n_rows_padded, n_coeffs=int(nc))


def run_lengths(ids: np.ndarray) -> np.ndarray:
    """Length of each run of equal output index once sorted — i.e. the
    nnz-per-touched-row distribution (sub-vector lengths, paper §4.1.2),
    in ascending row-id order."""
    ids = np.asarray(ids, np.int64)
    if ids.size == 0:
        return np.zeros(0, np.int64)
    return np.unique(ids, return_counts=True)[1]


def sell_geometry(max_nnz: int, n_rows: int, *, row_tile: int,
                  slot_tile: int) -> Tuple[int, int]:
    """(width, n_rows_padded) a SELL layout allocates for this shape.

    Single source of truth shared by the layout itself
    (``formats/sell.py:SellPhi.encode``) and the selector's overhead
    prediction below — the accept/reject heuristic is only sound if the
    predicted slots equal the allocated slots."""
    width = max(slot_tile, -(-max_nnz // slot_tile) * slot_tile)
    n_rows_padded = -(-n_rows // row_tile) * row_tile
    return width, n_rows_padded


def phi_stats(phi, *, row_tile: int = 8, slot_tile: int = 32) -> dict:
    """Format-selection statistics (consumed by formats/select.py).

    Per op (dsc: voxel rows, wc: fiber rows): run-length histogram moments
    of the output dimension plus the padding overhead a SELL layout with
    this (row_tile, slot_tile) geometry would pay — computed from counts
    alone, without materializing the layout.  Global Nc/Nv/Nf ratios ride
    along for the density heuristics.
    """
    out = dict(
        n_coeffs=float(phi.n_coeffs),
        nc_per_voxel=phi.n_coeffs / max(1, phi.n_voxels),
        nc_per_fiber=phi.n_coeffs / max(1, phi.n_fibers),
        nc_per_atom=phi.n_coeffs / max(1, phi.n_atoms),
    )
    for op, ids, n_rows in (("dsc", phi.voxels, phi.n_voxels),
                            ("wc", phi.fibers, phi.n_fibers)):
        touched = run_lengths(ids)
        max_nnz = int(touched.max()) if touched.size else 0
        width, n_rows_padded = sell_geometry(max_nnz, n_rows,
                                             row_tile=row_tile,
                                             slot_tile=slot_tile)
        slots = n_rows_padded * width
        out[f"{op}.rows_touched"] = float(touched.size) / max(1, n_rows)
        out[f"{op}.run_mean"] = float(touched.mean()) if touched.size else 0.0
        out[f"{op}.run_p99"] = (float(np.percentile(touched, 99))
                                if touched.size else 0.0)
        out[f"{op}.run_max"] = float(max_nnz)
        out[f"{op}.sell_width"] = float(width)
        out[f"{op}.sell_overhead"] = slots / max(1, phi.n_coeffs) - 1.0
    return out


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """2-D mesh partition plan: equal-nnz (voxel-range x fiber-range) cells.

    ``voxel_cuts``/``fiber_cuts`` are *id-space* boundaries (int64[R+1] /
    int64[C+1]): mesh row ``r`` owns voxels ``[voxel_cuts[r], voxel_cuts[r+1])``
    and mesh column ``c`` owns fibers ``[fiber_cuts[c], fiber_cuts[c+1])``.
    Produced by :func:`repro.formats.shard.partition_cuts` from
    :func:`shard_boundaries` per dimension, and serialized through the
    persistent plan cache under a key that includes the mesh shape and the
    device count (a plan built for one topology must miss on another).
    """

    R: int
    C: int
    voxel_cuts: np.ndarray        # int64 (R+1,)
    fiber_cuts: np.ndarray        # int64 (C+1,)

    @property
    def nv_local(self) -> int:
        """Common per-row voxel count (max range length; rows pad up to it)."""
        return int(np.max(np.diff(self.voxel_cuts)))

    @property
    def nf_local(self) -> int:
        return int(np.max(np.diff(self.fiber_cuts)))


def shard_boundaries(sorted_ids: np.ndarray, n_shards: int) -> np.ndarray:
    """Equal-nnz shard cuts snapped to sub-vector boundaries.

    Returns int64[n_shards + 1] coefficient offsets.  Snapping direction is
    chosen per cut to minimize the induced imbalance (paper Figure 5b, case 2:
    give the straddling sub-vector to whichever side adds less work).
    """
    sorted_ids = np.asarray(sorted_ids, np.int64)
    nc = sorted_ids.size
    cuts = [0]
    for s in range(1, n_shards):
        target = (nc * s) // n_shards
        if target <= cuts[-1]:
            cuts.append(cuts[-1])
            continue
        v = sorted_ids[min(target, nc - 1)]
        lo = int(np.searchsorted(sorted_ids, v, side="left"))
        hi = int(np.searchsorted(sorted_ids, v, side="right"))
        # snap to whichever sub-vector boundary is closer to the target
        snap = lo if (target - lo) <= (hi - target) else hi
        snap = max(snap, cuts[-1])
        cuts.append(snap)
    cuts.append(nc)
    return np.asarray(cuts, np.int64)


def pad_shards_equal(cuts: np.ndarray, pad_to: int | None = None) -> Tuple[np.ndarray, int]:
    """Per-shard (start, length) padded to a common length for stacking."""
    lens = np.diff(cuts)
    width = int(lens.max()) if pad_to is None else pad_to
    return np.stack([cuts[:-1], lens], axis=1), width
