"""The two LiFE SpMV operations in pure JAX (executor layer).

Implements the paper's Figure-3 ops with the optimization ladder as separate,
benchmarkable code versions (mirroring §6 "code versions"):

  * ``*_naive``      — direct translation (per-coefficient scatter/gather via
                       XLA scatter-add). The CPU-naive analogue.
  * ``dsc`` / ``wc`` — restructured executors: contributions computed as a
                       dense (Nc, Ntheta) tile stream + segment reduction over
                       the sorted output dimension.  The CPU/GPU-opt analogue
                       and the building block that shard_map distributes.

All functions treat the *index* arrays as static-shaped operands, so they jit
cleanly and lower to the same HLO the dry-run mesh sees.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.std import PhiTensor

Array = jax.Array


# ----------------------------------------------------------------------------
# Naive code versions (paper Figure 3): per-coefficient indirect ops.
# ----------------------------------------------------------------------------

@partial(jax.jit, static_argnames=())
def dsc_naive(phi: PhiTensor, dictionary: Array, w: Array) -> Array:
    """y = M w via scatter-add, no restructuring assumed. (Nv, Ntheta)."""
    scaled = w[phi.fibers] * phi.values                       # hoisted w*val
    contrib = dictionary[phi.atoms] * scaled[:, None]          # (Nc, Ntheta)
    out = jnp.zeros((phi.n_voxels, dictionary.shape[1]), contrib.dtype)
    return out.at[phi.voxels].add(contrib)


@partial(jax.jit, static_argnames=())
def wc_naive(phi: PhiTensor, dictionary: Array, y: Array) -> Array:
    """w = M^T y via gather-dot-scatter, no restructuring assumed. (Nf,)."""
    dots = jnp.einsum("ct,ct->c", dictionary[phi.atoms], y[phi.voxels])
    vals = dots * phi.values
    out = jnp.zeros((phi.n_fibers,), vals.dtype)
    return out.at[phi.fibers].add(vals)


# ----------------------------------------------------------------------------
# Restructured executors (paper §4.1.2 + §4.1.3): sorted segment reduction.
# On TPU these lower to efficient sorted-segment sums; they are also exactly
# what each device runs inside the shard_map 2-D partition.
# ----------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_segments",))
def segment_sum_sorted(data: Array, segment_ids: Array, num_segments: int) -> Array:
    return jax.ops.segment_sum(
        data, segment_ids, num_segments=num_segments,
        indices_are_sorted=True, unique_indices=False,
    )


@partial(jax.jit, static_argnames=())
def dsc(phi_sorted: PhiTensor, dictionary: Array, w: Array) -> Array:
    """y = M w assuming coefficients sorted by voxel (restructured).

    contributions stream as a (Nc, Ntheta) dense tile; the voxel scatter is a
    *sorted* segment sum — the sync-free reduction of DESIGN.md §2.
    """
    scaled = jnp.take(w, phi_sorted.fibers) * phi_sorted.values
    contrib = jnp.take(dictionary, phi_sorted.atoms, axis=0) * scaled[:, None]
    return segment_sum_sorted(contrib, phi_sorted.voxels, phi_sorted.n_voxels)


@partial(jax.jit, static_argnames=())
def wc(phi_sorted: PhiTensor, dictionary: Array, y: Array) -> Array:
    """w = M^T y assuming coefficients sorted by fiber (TPU-optimized sort).

    Gathers are coalesced XLA takes; the fiber scatter is a sorted segment
    sum.  The paper's atom-sorted CPU/GPU variant is `wc_atom_sorted`.
    """
    dots = jnp.einsum(
        "ct,ct->c",
        jnp.take(dictionary, phi_sorted.atoms, axis=0),
        jnp.take(y, phi_sorted.voxels, axis=0),
    )
    vals = dots * phi_sorted.values
    return segment_sum_sorted(vals, phi_sorted.fibers, phi_sorted.n_fibers)


@partial(jax.jit, static_argnames=())
def wc_atom_sorted(phi_sorted: PhiTensor, dictionary: Array, y: Array) -> Array:
    """Paper-faithful WC: atom-sorted (D reuse), unsorted fiber scatter."""
    dots = jnp.einsum(
        "ct,ct->c",
        jnp.take(dictionary, phi_sorted.atoms, axis=0),
        jnp.take(y, phi_sorted.voxels, axis=0),
    )
    vals = dots * phi_sorted.values
    out = jnp.zeros((phi_sorted.n_fibers,), vals.dtype)
    return out.at[phi_sorted.fibers].add(vals)


@partial(jax.jit, static_argnames=())
def dsc_atom_sorted(phi_sorted: PhiTensor, dictionary: Array, w: Array) -> Array:
    """Paper Table-2 variant: DSC with atom-sorted data (D reuse, unsorted Y)."""
    scaled = jnp.take(w, phi_sorted.fibers) * phi_sorted.values
    contrib = jnp.take(dictionary, phi_sorted.atoms, axis=0) * scaled[:, None]
    out = jnp.zeros((phi_sorted.n_voxels, dictionary.shape[1]), contrib.dtype)
    return out.at[phi_sorted.voxels].add(contrib)


def matvec_dense_oracle(m: Array, w: Array) -> Array:
    return m @ w


def rmatvec_dense_oracle(m: Array, y: Array) -> Array:
    return m.T @ y
