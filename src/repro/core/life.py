"""LiFE end-to-end engine: connectome pruning with pluggable SpMV executors.

Executor dispatch goes through :mod:`repro.core.registry` — the code-version
ladder (paper §6.3.1/§6.4.1), selectable via ``executor=``:

  naive        CPU-naive        : Figure-3 translation, scatter/gather adds
  opt-paper    CPU/GPU-opt      : per-op restructuring as the paper ships it
                                  (DSC voxel-sorted, WC atom-sorted)
  opt          TPU-opt (ours)   : output-side sorts for both ops
                                  (DSC voxel-sorted, WC fiber-sorted)
  kernel       TPU Pallas       : inspector-planned tiled kernels
                                  (interpret=True off-TPU)
  auto         runtime autotune : measured selection (paper's hybrid/runtime
                                  choice, §4.1.2)
  shard        mesh partition   : 2-D shard_map SpMVs over inner sorted-COO
                                  cells (distributed/life_shard, DESIGN.md §9)
  shard-sell   mesh + SELL      : per-cell SELL tiles feeding the Pallas SELL
                                  kernels under shard_map

Inspector products (tile plans, autotune choices) are memoized through the
persistent :class:`~repro.core.plan_cache.PlanCache`, so a second engine
construction on the same dataset pays ~zero ``inspector_seconds``
(amortization across runs, DESIGN.md §6.3).

Weight compaction (``compact_every > 0``) periodically drops coefficients
whose fiber weight reached zero — the paper's "evaded BLAS call" effect,
realized as an inspector re-run whose cost is amortized over the following
iterations.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.plan_cache import PlanCache
from repro.core.registry import REGISTRY, Executor, create_for_format
from repro.core.restructure import compact_by_weight
from repro.core.sbbnnls import (SbbnnlsState, nnls_loss, sbbnnls_init,
                                sbbnnls_steps)
from repro.core.std import PhiTensor
from repro.data.dmri import LifeProblem

EXECUTORS = REGISTRY.names()          # public alias; registry is the truth


@dataclasses.dataclass
class LifeConfig:
    """Engine configuration: executor choice plus every tuning knob.

    The fields form four groups — code version (``executor``, ``format``,
    mesh geometry), kernel launch parameters (``c_tile``, ``row_tile``,
    ``slot_tile``, ``seg_tile``), plan-selection policy (``tune``,
    ``predict``, the SELL thresholds, ``compute_dtype``), and the solver
    driver (``n_iters``, compaction).  Instances are plain data: hashable
    config digests and serving batch-compatibility classes are derived
    from them, so two equal configs must mean identical execution.
    """

    executor: str = "opt"
    n_iters: int = 100
    compact_every: int = 0          # 0 disables weight compaction
    compact_threshold: float = 0.0
    c_tile: int = 256               # kernel coefficient-tile size
    row_tile: int = 8               # kernel output row-block size
    kernel_interpret: bool = True   # CPU container: validate via interpret
    # mesh geometry (R, C) for the sharded executors; with R*C > 1 the
    # format="auto" candidate set and executor mapping become mesh-aware
    # (formats/select.py picks among formats with a registered mesh executor)
    shard_rows: int = 1
    shard_cols: int = 1
    # Phi layout: "coo" (canonical; executor= picks the code version),
    # "sell" / "alto" (force that format's executor), or "auto" (pick per
    # dataset via formats/select.py, FormatPlan-cached).  DESIGN.md §7.
    format: str = "coo"
    slot_tile: int = 32             # SELL slots consumed per kernel grid step
    seg_tile: int = 16              # F-COO segments-per-chunk rounding (the
                                    # one-hot K dim of kernels/fcoo.py)
    # Kernel autotuning (DESIGN.md §10): "off" runs the frozen constants
    # above; "cached" replays a persisted TunePlan when one exists (never
    # measures); "full" searches the launch-parameter space on a cache miss
    # and persists the winner per (dataset, executor, backend, devices).
    tune: str = "off"
    # Learned cold-start selection (DESIGN.md §14): "auto" lets a trained
    # predictor beside the plan cache answer format/tune cache misses with
    # zero-measurement reason="predicted" plans (measured refinement runs
    # in the background); "off" disables the predict rung of the ladder.
    predict: str = "auto"
    # Storage dtype of the static operands (dictionary + Phi values):
    # "fp32", "bf16" (bf16 storage / fp32 accumulate — halves resident
    # bytes, accuracy contract repro.tune.plan.BF16_RTOL), or "auto" (a
    # searched axis; requires tune != "off").
    compute_dtype: str = "fp32"
    # cap on measured candidates per search (the default-config candidate
    # is never truncated away, so "tuned" can't regress the frozen config
    # on the tuner's own objective)
    tune_budget: int = 12
    # format="auto" SELL thresholds: padding overhead (extra slots/coeff)
    # below sell_accept takes SELL outright, above sell_reject strikes it
    sell_accept: float = 1.0
    sell_reject: float = 4.0
    # None -> default cache dir ($REPRO_PLAN_CACHE or ~/.cache/repro-life);
    # "" -> plan caching disabled.
    plan_cache_dir: Optional[str] = None
    # cap on the on-disk plan cache (oldest entries pruned past it);
    # None -> $REPRO_PLAN_CACHE_MAX_BYTES or unbounded.
    plan_cache_max_bytes: Optional[int] = None


class LifeEngine:
    """Binds a LifeProblem to an executor; runs SBBNNLS; reports pruning."""

    def __init__(self, problem: LifeProblem, config: LifeConfig,
                 cache: Optional[PlanCache] = None):
        if config.executor not in REGISTRY:
            raise ValueError(f"executor must be one of {REGISTRY.names()}")
        from repro.tune.tuner import validate_config as _validate_tune
        _validate_tune(config)
        self.problem = problem
        self.config = config
        self.cache = cache if cache is not None else PlanCache(
            config.plan_cache_dir, config.plan_cache_max_bytes)
        self.inspector_seconds = 0.0
        self._build(problem.phi)

    # -- inspector ----------------------------------------------------------
    def _build(self, phi: PhiTensor) -> None:
        t0 = time.perf_counter()
        self.phi = phi
        if self.config.format == "coo":
            name = self.config.executor
            if self.config.shard_rows * self.config.shard_cols > 1:
                # a multi-cell mesh request is the strongest signal: route
                # through the mesh-aware mapping (-> "shard") instead of
                # silently running the configured executor on one device
                from repro.formats import select as fsel
                name = fsel.executor_for("coo", self.config)
            self.executor: Executor = REGISTRY.create(
                name, phi, self.problem, self.config, self.cache)
        else:
            # format-parameterized path: "sell"/"alto" force that layout's
            # executor; "auto" selects per dataset (FormatPlan-cached)
            self.executor = create_for_format(
                phi, self.problem, self.config, self.cache)
        self.matvec = self.executor.matvec
        self.rmatvec = self.executor.rmatvec
        dt = time.perf_counter() - t0
        self.inspector_seconds += dt
        obs.histogram("engine.build.seconds").observe(dt)
        # held instruments for the hot step loop (no-ops while disabled);
        # HLO byte counts are invalidated here because compaction rebinds
        # the SpMV closures over a smaller Phi
        self._op_bytes: Optional[dict] = None
        self._h_step = obs.histogram("engine.step.seconds",
                                     executor=self.executor.name)
        self._g_frac = obs.gauge("engine.roofline.fraction",
                                 executor=self.executor.name,
                                 format=self.config.format)
        self._g_bw = obs.gauge("engine.achieved_bandwidth.gbps",
                               executor=self.executor.name,
                               format=self.config.format)

    @property
    def dsc_plan(self):
        """Autotuned DSC SpmvPlan (auto executor only)."""
        return self.executor.plans.get("dsc")

    @property
    def format_plan(self):
        """Chosen FormatPlan (format != "coo" only)."""
        return self.executor.plans.get("format")

    @property
    def tune_plan(self):
        """Resolved TunePlan (tune != "off" only; DESIGN.md §10)."""
        return self.executor.plans.get("tune")

    @property
    def resolved_compute_dtype(self) -> str:
        """The storage dtype this engine actually runs under — the tune
        plan's winner when a search resolved ``compute_dtype="auto"``,
        the config value otherwise.  Serving pins checkpoints (and bucket
        rebuilds) to this, never to the unresolved request."""
        plan = self.tune_plan
        if plan is not None:
            return plan.compute_dtype
        cd = getattr(self.config, "compute_dtype", "fp32")
        return "fp32" if cd == "auto" else cd

    @property
    def wc_plan(self):
        """Autotuned WC SpmvPlan (auto executor only; None otherwise)."""
        return self.executor.plans.get("wc")

    @property
    def cache_stats(self):
        """Hit/miss counters of the bound plan cache (CacheStats)."""
        return self.cache.stats

    # -- driver --------------------------------------------------------------
    def init_state(self, w0: Optional[jax.Array] = None) -> SbbnnlsState:
        """Fresh solver state (all-ones start unless ``w0`` is given)."""
        nf = self.problem.phi.n_fibers
        w = jnp.ones((nf,), self.problem.dictionary.dtype) if w0 is None else w0
        return sbbnnls_init(w)

    def step(self, state: SbbnnlsState, k: int
             ) -> Tuple[SbbnnlsState, np.ndarray]:
        """Advance ``state`` by ``k`` SBBNNLS iterations (stepped API).

        State in -> k iters -> state out; the iteration counter rides in the
        state, so chained calls reproduce one uninterrupted run exactly.
        The serving scheduler time-slices long solves through this."""
        if not obs.SWITCH.on:
            new, ls = sbbnnls_steps(self.matvec, self.rmatvec,
                                    self.problem.b, state, k)
            return new, np.asarray(ls)
        with obs.span("engine.step", {"executor": self.executor.name,
                                      "format": self.config.format,
                                      "k": k}) as sp:
            t0 = time.perf_counter()
            new, ls = sbbnnls_steps(self.matvec, self.rmatvec,
                                    self.problem.b, state, k)
            ls = np.asarray(ls)     # host transfer blocks on the computation
            dt = time.perf_counter() - t0
            self._h_step.observe(dt)
            self._annotate_roofline(sp, k, dt)
        return new, ls

    def _annotate_roofline(self, sp, k: int, dt: float) -> None:
        """Set achieved-bandwidth gauges from HLO byte counts (obs-on only).

        Bytes per SBBNNLS iteration follow the tuner's dominant-op mix
        (DSC every iteration + line-search probe, WC on alternation):
        ``DSC_WEIGHT * dsc_bytes + WC_WEIGHT * wc_bytes``.  Fraction is
        against the roofline model's HBM bandwidth (analysis.HW)."""
        bytes_per_iter = self._op_bytes_per_iter()
        if bytes_per_iter is None or dt <= 0.0:
            return
        from repro.roofline.analysis import HW
        achieved = bytes_per_iter * k / dt
        frac = achieved / HW["hbm_bw"]
        self._g_bw.set(achieved / 1e9)
        self._g_frac.set(frac)
        sp.set_attr("bytes_accessed", bytes_per_iter * k)
        sp.set_attr("achieved_gbps", achieved / 1e9)
        sp.set_attr("roofline_fraction", frac)

    def _op_bytes_per_iter(self) -> Optional[float]:
        """Weighted HBM bytes of one SBBNNLS iteration, from the compiled
        HLO of the bound SpMV pair (lazy, memoized until the next _build;
        None when either op can't be lowered/costed)."""
        if self._op_bytes is None:
            from repro.roofline import hlo_cost
            from repro.tune.tuner import DSC_WEIGHT, WC_WEIGHT
            d = self.problem.dictionary
            probes = ((self.matvec, jnp.ones((self.phi.n_fibers,), d.dtype)),
                      (self.rmatvec,
                       jnp.ones((self.phi.n_voxels, d.shape[1]), d.dtype)))
            try:
                dsc_b, wc_b = (
                    hlo_cost.analyze(
                        jax.jit(fn).lower(probe).compile().as_text(),
                        n_chips=1).bytes_accessed
                    for fn, probe in probes)
                self._op_bytes = dict(
                    per_iter=DSC_WEIGHT * dsc_b + WC_WEIGHT * wc_b)
            except Exception:
                # interpret-mode kernels / exotic layouts may not lower to
                # costable HLO — roofline annotation is best-effort
                self._op_bytes = dict(per_iter=None)
        return self._op_bytes["per_iter"]

    def run(self, n_iters: Optional[int] = None,
            w0: Optional[jax.Array] = None) -> Tuple[jax.Array, np.ndarray]:
        """Run SBBNNLS with optional periodic weight compaction."""
        cfg = self.config
        n_iters = cfg.n_iters if n_iters is None else n_iters
        state = self.init_state(w0)
        losses: List[np.ndarray] = []
        chunk = cfg.compact_every if cfg.compact_every > 0 else n_iters
        done = 0
        while done < n_iters:
            k = min(chunk, n_iters - done)
            state, ls = self.step(state, k)
            losses.append(ls)
            done += k
            if cfg.compact_every > 0 and done < n_iters:
                t0 = time.perf_counter()
                compacted = compact_by_weight(self.phi, state.w,
                                              cfg.compact_threshold)
                if compacted.n_coeffs < self.phi.n_coeffs:
                    self._build(compacted)
                self.inspector_seconds += time.perf_counter() - t0
        return state.w, np.concatenate(losses)

    def loss(self, w: jax.Array) -> float:
        """NNLS objective ``0.5 * ||Phi w - b||^2`` under this engine's
        bound SpMV (so a compacted engine scores against its own Phi)."""
        return float(nnls_loss(self.matvec, self.problem.b, w))

    def prune_stats(self, w: jax.Array, threshold: float = 1e-6) -> dict:
        """Support recovery vs the synthetic ground truth.

        Args:
            w: converged fiber weights.
            threshold: weights at or below this count as pruned.

        Returns:
            dict with ``kept``/``total`` counts and ``precision``/
            ``recall`` of the recovered support against ``w_true > 0``.
            Only meaningful on synthetic problems that carry ``w_true``;
            for ground-truth-free pruning use
            :func:`repro.science.prune_connectome`.
        """
        w_np = np.asarray(w)
        true = np.asarray(self.problem.w_true) > 0
        kept = w_np > threshold
        tp = float(np.sum(kept & true))
        return dict(
            kept=float(kept.sum()),
            total=float(kept.size),
            precision=tp / max(1.0, float(kept.sum())),
            recall=tp / max(1.0, float(true.sum())),
        )
