"""LiFE end-to-end engine: connectome pruning with pluggable SpMV executors.

Code-version ladder (paper §6.3.1/§6.4.1), selectable via ``executor=``:

  naive        CPU-naive        : Figure-3 translation, scatter/gather adds
  opt-paper    CPU/GPU-opt      : per-op restructuring as the paper ships it
                                  (DSC voxel-sorted, WC atom-sorted)
  opt          TPU-opt (ours)   : output-side sorts for both ops
                                  (DSC voxel-sorted, WC fiber-sorted)
  kernel       TPU Pallas       : inspector-planned tiled kernels
                                  (interpret=True off-TPU)
  auto         runtime autotune : measured selection (paper's hybrid/runtime
                                  choice, §4.1.2)

Weight compaction (``compact_every > 0``) periodically drops coefficients
whose fiber weight reached zero — the paper's "evaded BLAS call" effect,
realized as an inspector re-run whose cost is amortized over the following
iterations.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spmv
from repro.core.inspector import plan_tiles
from repro.core.restructure import (SpmvPlan, autotune_plan, compact_by_weight,
                                    sort_by_host)
from repro.core.sbbnnls import SbbnnlsState, sbbnnls_run, nnls_loss
from repro.core.std import PhiTensor
from repro.data.dmri import LifeProblem

EXECUTORS = ("naive", "opt-paper", "opt", "kernel", "auto")


@dataclasses.dataclass
class LifeConfig:
    executor: str = "opt"
    n_iters: int = 100
    compact_every: int = 0          # 0 disables weight compaction
    compact_threshold: float = 0.0
    c_tile: int = 256               # kernel coefficient-tile size
    row_tile: int = 8               # kernel output row-block size
    kernel_interpret: bool = True   # CPU container: validate via interpret


class LifeEngine:
    """Binds a LifeProblem to an executor; runs SBBNNLS; reports pruning."""

    def __init__(self, problem: LifeProblem, config: LifeConfig):
        if config.executor not in EXECUTORS:
            raise ValueError(f"executor must be one of {EXECUTORS}")
        self.problem = problem
        self.config = config
        self.inspector_seconds = 0.0
        self._build(problem.phi)

    # -- inspector ----------------------------------------------------------
    def _build(self, phi: PhiTensor) -> None:
        cfg = self.config
        t0 = time.perf_counter()
        self.phi = phi
        if cfg.executor == "naive":
            self.matvec = lambda w: spmv.dsc_naive(phi, self.problem.dictionary, w)
            self.rmatvec = lambda y: spmv.wc_naive(phi, self.problem.dictionary, y)
        elif cfg.executor in ("opt", "opt-paper", "kernel"):
            phi_v, _ = sort_by_host(phi, "voxel")
            wc_dim = "atom" if cfg.executor == "opt-paper" else "fiber"
            phi_w, _ = sort_by_host(phi, wc_dim)
            if cfg.executor == "kernel":
                from repro.kernels import ops as kops
                dsc_plan = plan_tiles(np.asarray(phi_v.voxels), phi.n_voxels,
                                      c_tile=cfg.c_tile, row_tile=cfg.row_tile)
                wc_plan = plan_tiles(np.asarray(phi_w.fibers), phi.n_fibers,
                                     c_tile=cfg.c_tile, row_tile=cfg.row_tile)
                self.matvec = kops.make_dsc(phi_v, self.problem.dictionary,
                                            dsc_plan, interpret=cfg.kernel_interpret)
                self.rmatvec = kops.make_wc(phi_w, self.problem.dictionary,
                                            wc_plan, interpret=cfg.kernel_interpret)
            else:
                wc_fn = spmv.wc_atom_sorted if cfg.executor == "opt-paper" else spmv.wc
                self.matvec = lambda w: spmv.dsc(phi_v, self.problem.dictionary, w)
                self.rmatvec = lambda y: wc_fn(phi_w, self.problem.dictionary, y)
        elif cfg.executor == "auto":
            self._autotune(phi)
        self.inspector_seconds += time.perf_counter() - t0

    def _autotune(self, phi: PhiTensor) -> None:
        d = self.problem.dictionary
        w_probe = jnp.ones((phi.n_fibers,), d.dtype)
        y_probe = jnp.ones((phi.n_voxels, d.shape[1]), d.dtype)
        # per sort-dim executors: output-side sorts get segment-sum paths,
        # input-side sorts keep the scatter (paper Table 2/3 combinations)
        dsc_fns = {"atom": spmv.dsc_atom_sorted, "voxel": spmv.dsc,
                   "fiber": spmv.dsc_atom_sorted}   # fiber-sort: unsorted Y path
        wc_fns = {"atom": spmv.wc_atom_sorted, "voxel": spmv.wc_atom_sorted,
                  "fiber": spmv.wc}
        self.dsc_plan = autotune_plan(
            "dsc", phi, lambda p, dim: dsc_fns[dim](p, d, w_probe))
        self.wc_plan = autotune_plan(
            "wc", phi, lambda p, dim: wc_fns[dim](p, d, y_probe))
        phi_v = phi.take(jnp.asarray(self.dsc_plan.order))
        phi_w = phi.take(jnp.asarray(self.wc_plan.order))
        dsc_fn = dsc_fns[self.dsc_plan.restructure]
        wc_fn = wc_fns[self.wc_plan.restructure]
        self.matvec = lambda w: dsc_fn(phi_v, d, w)
        self.rmatvec = lambda y: wc_fn(phi_w, d, y)

    # -- driver --------------------------------------------------------------
    def run(self, n_iters: Optional[int] = None,
            w0: Optional[jax.Array] = None) -> Tuple[jax.Array, np.ndarray]:
        """Run SBBNNLS with optional periodic weight compaction."""
        cfg = self.config
        n_iters = cfg.n_iters if n_iters is None else n_iters
        nf = self.problem.phi.n_fibers
        w = jnp.ones((nf,), self.problem.dictionary.dtype) if w0 is None else w0
        losses: List[np.ndarray] = []
        chunk = cfg.compact_every if cfg.compact_every > 0 else n_iters
        done = 0
        while done < n_iters:
            k = min(chunk, n_iters - done)
            state, ls = sbbnnls_run(self.matvec, self.rmatvec,
                                    self.problem.b, w, k)
            w = state.w
            losses.append(np.asarray(ls))
            done += k
            if cfg.compact_every > 0 and done < n_iters:
                t0 = time.perf_counter()
                compacted = compact_by_weight(self.phi, w, cfg.compact_threshold)
                if compacted.n_coeffs < self.phi.n_coeffs:
                    self._build(compacted)
                self.inspector_seconds += time.perf_counter() - t0
        return w, np.concatenate(losses)

    def loss(self, w: jax.Array) -> float:
        return float(nnls_loss(self.matvec, self.problem.b, w))

    def prune_stats(self, w: jax.Array, threshold: float = 1e-6) -> dict:
        w_np = np.asarray(w)
        true = np.asarray(self.problem.w_true) > 0
        kept = w_np > threshold
        tp = float(np.sum(kept & true))
        return dict(
            kept=float(kept.sum()),
            total=float(kept.size),
            precision=tp / max(1.0, float(kept.sum())),
            recall=tp / max(1.0, float(true.sum())),
        )


