"""Persistent, content-addressed inspector-plan cache.

The paper's whole argument for inspector-style restructuring is that its
host-side cost is amortized over the several hundred SBBNNLS iterations of
one run.  This module extends the amortization *across runs and processes*:
a ``TilePlan`` (Pallas tile geometry) or ``SpmvPlan`` (autotuned sort /
partition choice) is keyed by a content hash of the sorted index arrays plus
the tile geometry, and serialized to disk.  Re-constructing an engine on the
same dataset then pays ~zero ``inspector_seconds``: the O(Nc) python tiling
loop and the autotune measurements are replaced by one ``np.load``.

Keying is content-addressed, never identity-addressed: two subjects with
byte-identical sorted index vectors share a cache entry, while any change to
the data (compaction, different tractography seed) changes the digest and
misses cleanly.  Entries are written atomically (tmp file + rename) so
concurrent engines on the same cache directory never observe torn plans.

Layout: ``<cache_dir>/<digest>.npz`` holding the plan arrays + geometry.
Default directory is ``$REPRO_PLAN_CACHE`` or ``~/.cache/repro-life/plans``;
``LifeConfig.plan_cache_dir`` overrides per engine, and ``plan_cache_dir=""``
disables caching entirely.

See DESIGN.md §6.3 for the design discussion.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import tempfile
from typing import Optional

import numpy as np

from repro import obs
from repro.core.inspector import ShardPlan, TilePlan
from repro.core.restructure import SpmvPlan
from repro.formats.base import FORMAT_VERSION as _PHI_FORMAT_VERSION
from repro.formats.base import FormatPlan

_ENV_VAR = "REPRO_PLAN_CACHE"
_MAX_BYTES_ENV_VAR = "REPRO_PLAN_CACHE_MAX_BYTES"
_FORMAT_VERSION = 2      # bump on any incompatible serialization change
# v2: TilePlan geometry grew n_coeffs (occupancy fix); TunePlan carries the
# phi_stats it was searched under (the learn subsystem's training features)


def default_cache_dir() -> str:
    env = os.environ.get(_ENV_VAR)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-life",
                        "plans")


def default_max_bytes() -> Optional[int]:
    """Size cap from ``$REPRO_PLAN_CACHE_MAX_BYTES``; None = unbounded."""
    env = os.environ.get(_MAX_BYTES_ENV_VAR)
    if not env:
        return None
    try:
        return int(env)
    except ValueError:
        return None


def tile_plan_key(sorted_ids: np.ndarray, n_rows: int, *, c_tile: int,
                  row_tile: int) -> str:
    """Digest of the exact inspector inputs: sorted output-index content +
    row count + tile geometry.  Any input that would change plan_tiles'
    output changes the key."""
    h = hashlib.sha256()
    h.update(b"tile-plan-v%d" % _FORMAT_VERSION)
    h.update(np.int64([n_rows, c_tile, row_tile]).tobytes())
    h.update(np.ascontiguousarray(sorted_ids, np.int64).tobytes())
    return h.hexdigest()


def spmv_plan_key(op: str, atoms: np.ndarray, voxels: np.ndarray,
                  fibers: np.ndarray) -> str:
    """Digest for an autotuned SpmvPlan: the op plus the full index content
    (the measurement outcome depends on all three indirection vectors)."""
    h = hashlib.sha256()
    h.update(b"spmv-plan-v%d:" % _FORMAT_VERSION + op.encode())
    for arr in (atoms, voxels, fibers):
        h.update(np.ascontiguousarray(arr, np.int64).tobytes())
    return h.hexdigest()


def format_plan_key(atoms: np.ndarray, voxels: np.ndarray, fibers: np.ndarray,
                    *, sizes, row_tile: int, slot_tile: int, allowed,
                    sell_accept: float = 0.0,
                    sell_reject: float = 0.0) -> str:
    """Digest for a FormatPlan: the full index content + mode sizes + layout
    geometry + the candidate set and heuristic thresholds the selector
    decided under (different thresholds may legitimately choose a different
    format for the same data).  Versioned by formats.base.FORMAT_VERSION so
    any incompatible layout change invalidates every cached choice."""
    h = hashlib.sha256()
    h.update(b"format-plan-v%d.%d:" % (_FORMAT_VERSION, _PHI_FORMAT_VERSION))
    h.update(",".join(sorted(allowed)).encode())
    h.update(np.float64([sell_accept, sell_reject]).tobytes())
    h.update(np.int64(list(sizes) + [row_tile, slot_tile]).tobytes())
    for arr in (atoms, voxels, fibers):
        h.update(np.ascontiguousarray(arr, np.int64).tobytes())
    return h.hexdigest()


def tune_plan_key(atoms: np.ndarray, voxels: np.ndarray, fibers: np.ndarray,
                  *, sizes, n_theta: int, executor: str, fmt: str,
                  backend: str, n_devices: int, compute_dtype: str,
                  budget: int = 0, mesh=(1, 1)) -> str:
    """Digest for a TunePlan: full index content + problem geometry + the
    executor/format pair the search bound + the *platform* (backend name,
    device count, and the ``(R, C)`` mesh shape) + the requested
    compute-dtype mode and search budget.

    Scoping by platform is the point of the whole subsystem (the paper's
    Table 9: the best launch configuration shifts with the hardware): a plan
    tuned on one backend must miss cleanly on another instead of replaying
    tiles measured for different silicon.  The mesh shape matters for the
    same reason — a ``shard-sell`` plan measured on a (4, 2) partition saw
    different per-cell geometry than a (2, 4) one on the same device count.
    The requested dtype is in the key — not the resolved winner — so
    ``compute_dtype="auto"`` and an explicit "fp32" request never share an
    entry even when "auto" resolves to fp32.
    """
    h = hashlib.sha256()
    h.update(b"tune-plan-v%d:" % _FORMAT_VERSION)
    h.update(("%s|%s|%s|%s" % (executor, fmt, backend, compute_dtype))
             .encode())
    h.update(np.int64(list(sizes) + [n_theta, n_devices, budget]
                      + list(mesh)).tobytes())
    for arr in (atoms, voxels, fibers):
        h.update(np.ascontiguousarray(arr, np.int64).tobytes())
    return h.hexdigest()


def shard_plan_key(atoms: np.ndarray, voxels: np.ndarray, fibers: np.ndarray,
                   *, sizes, R: int, C: int, cell_format: str,
                   n_devices: int) -> str:
    """Digest for a ShardPlan: full index content + mode sizes + the mesh
    geometry (R x C), the per-cell layout the partition will be materialized
    in, and the device count the mesh is built over.  Including the topology
    is the point: a plan written on 8 virtual devices must miss cleanly when
    the same dataset is opened on 1 (or on a different R x C), instead of
    silently rebuilding a layout the mesh cannot place."""
    h = hashlib.sha256()
    h.update(b"shard-plan-v%d.%d:" % (_FORMAT_VERSION, _PHI_FORMAT_VERSION))
    h.update(cell_format.encode())
    h.update(np.int64(list(sizes) + [R, C, n_devices]).tobytes())
    for arr in (atoms, voxels, fibers):
        h.update(np.ascontiguousarray(arr, np.int64).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0

    def record(self, hit: bool, kind: str = "plan") -> None:
        if hit:
            self.hits += 1
        else:
            self.misses += 1
        # export the lookup to the obs registry, labeled by plan kind
        # (DESIGN.md §12.2); the local fields above stay authoritative —
        # they count lookups made while observability was disabled too
        if obs.SWITCH.on:
            obs.counter("plan_cache.lookups", kind=kind,
                        outcome="hit" if hit else "miss").inc()

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        """hits / lookups; 0.0 before the first lookup."""
        n = self.lookups
        return self.hits / n if n else 0.0


class PlanCache:
    """On-disk plan store.  ``directory=None`` -> default location;
    ``directory=""`` -> disabled (every lookup misses, nothing is written).

    ``max_bytes`` caps the directory's total ``.npz`` footprint: after each
    write, oldest entries (by mtime; a hit refreshes it) are pruned until the
    cache fits — so long-running services never fill the disk with plans for
    datasets they'll never see again.  ``None`` defers to
    ``$REPRO_PLAN_CACHE_MAX_BYTES``; unset means unbounded.
    """

    def __init__(self, directory: Optional[str] = None,
                 max_bytes: Optional[int] = None):
        self.directory = default_cache_dir() if directory is None else directory
        self.max_bytes = default_max_bytes() if max_bytes is None else max_bytes
        self.stats = CacheStats()

    @property
    def enabled(self) -> bool:
        return bool(self.directory)

    def _path(self, key: str) -> str:
        return os.path.join(self.directory, key + ".npz")

    def _write(self, key: str, payload: dict) -> None:
        if not self.enabled:
            return
        tmp = None
        try:
            os.makedirs(self.directory, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".tmp")
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **payload)
            os.replace(tmp, self._path(key))
            self._prune(keep=self._path(key))
        except OSError:
            # fail-open: an unwritable cache (read-only volume, quota) must
            # never take down the engine — the plan is simply not persisted
            if tmp is not None and os.path.exists(tmp):
                os.unlink(tmp)

    def _prune(self, keep: str) -> None:
        """Evict oldest entries until the directory fits ``max_bytes``.
        ``keep`` (the just-written path) is never evicted — not even on
        mtime ties with concurrently touched entries, and not when it alone
        exceeds the cap (evicting it would silently disable the cache)."""
        if self.max_bytes is None:
            return
        entries = []
        try:
            with os.scandir(self.directory) as it:
                for e in it:
                    if e.name.endswith(".npz") and e.path != keep:
                        st = e.stat()
                        entries.append((st.st_mtime, st.st_size, e.path))
            total = sum(size for _, size, _ in entries) \
                + os.stat(keep).st_size
        except OSError:
            return
        for _, size, path in sorted(entries):          # oldest first
            if total <= self.max_bytes:
                break
            try:
                os.unlink(path)
                total -= size
            except OSError:
                pass                                   # raced with another engine

    def _touch(self, key: str) -> None:
        """Refresh an entry's mtime on hit so pruning is LRU-ish."""
        try:
            os.utime(self._path(key))
        except OSError:
            pass

    def _read(self, key: str) -> Optional[dict]:
        if not self.enabled:
            return None
        path = self._path(key)
        try:
            with np.load(path, allow_pickle=False) as z:
                raw = {k: z[k] for k in z.files}
            self._touch(key)
            return raw
        except (FileNotFoundError, OSError, ValueError, KeyError):
            return None     # corrupt/foreign entries degrade to a miss

    # -- TilePlan -------------------------------------------------------------
    def get_tile_plan(self, key: str) -> Optional[TilePlan]:
        raw = self._read(key)
        self.stats.record(raw is not None, "tile")
        if raw is None:
            return None
        try:
            geom = raw["geometry"]
            return TilePlan(
                sel=raw["sel"].astype(np.int32),
                row_block=raw["row_block"].astype(np.int32),
                local_row=raw["local_row"].astype(np.int32),
                n_tiles=int(geom[0]), c_tile=int(geom[1]),
                row_tile=int(geom[2]), n_rows_padded=int(geom[3]),
                n_coeffs=int(geom[4]))
        except (KeyError, IndexError, ValueError):
            return None

    def put_tile_plan(self, key: str, plan: TilePlan) -> None:
        self._write(key, dict(
            sel=plan.sel, row_block=plan.row_block, local_row=plan.local_row,
            geometry=np.int64([plan.n_tiles, plan.c_tile, plan.row_tile,
                               plan.n_rows_padded, plan.n_coeffs])))

    # -- SpmvPlan -------------------------------------------------------------
    def get_spmv_plan(self, key: str) -> Optional[SpmvPlan]:
        raw = self._read(key)
        self.stats.record(raw is not None, "spmv")
        if raw is None:
            return None
        try:
            return SpmvPlan(
                op=str(raw["op"]), restructure=str(raw["restructure"]),
                partition=str(raw["partition"]),
                order=raw["order"] if "order" in raw else None)
        except (KeyError, ValueError):
            return None

    def put_spmv_plan(self, key: str, plan: SpmvPlan) -> None:
        payload = dict(op=np.str_(plan.op), restructure=np.str_(plan.restructure),
                       partition=np.str_(plan.partition))
        if plan.order is not None:
            payload["order"] = np.asarray(plan.order, np.int64)
        self._write(key, payload)

    # -- ShardPlan ------------------------------------------------------------
    def get_shard_plan(self, key: str) -> Optional[ShardPlan]:
        raw = self._read(key)
        self.stats.record(raw is not None, "shard")
        if raw is None:
            return None
        try:
            geom = raw["geometry"]
            return ShardPlan(R=int(geom[0]), C=int(geom[1]),
                             voxel_cuts=raw["voxel_cuts"].astype(np.int64),
                             fiber_cuts=raw["fiber_cuts"].astype(np.int64))
        except (KeyError, IndexError, ValueError):
            return None

    def put_shard_plan(self, key: str, plan: ShardPlan) -> None:
        self._write(key, dict(
            geometry=np.int64([plan.R, plan.C]),
            voxel_cuts=np.asarray(plan.voxel_cuts, np.int64),
            fiber_cuts=np.asarray(plan.fiber_cuts, np.int64)))

    # -- TunePlan -------------------------------------------------------------
    def get_tune_plan(self, key: str):
        raw = self._read(key)
        self.stats.record(raw is not None, "tune")
        if raw is None:
            return None
        return _parse_tune_plan(raw)

    def put_tune_plan(self, key: str, plan) -> None:
        pk = sorted(plan.params)
        mk = sorted(plan.measurements)
        sk = sorted(plan.stats)
        self._write(key, dict(
            executor=np.str_(plan.executor), backend=np.str_(plan.backend),
            n_devices=np.int64(plan.n_devices),
            compute_dtype=np.str_(plan.compute_dtype),
            reason=np.str_(plan.reason),
            params_keys=np.asarray(pk, np.str_),
            params_vals=np.asarray([plan.params[k] for k in pk], np.int64),
            meas_keys=np.asarray(mk, np.str_),
            meas_vals=np.asarray([plan.measurements[k] for k in mk],
                                 np.float64),
            stats_keys=np.asarray(sk, np.str_),
            stats_vals=np.asarray([plan.stats[k] for k in sk], np.float64)))

    # -- FormatPlan -----------------------------------------------------------
    def get_format_plan(self, key: str) -> Optional[FormatPlan]:
        raw = self._read(key)
        self.stats.record(raw is not None, "format")
        if raw is None:
            return None
        return _parse_format_plan(raw)

    def put_format_plan(self, key: str, plan: FormatPlan) -> None:
        pk = sorted(plan.params)
        sk = sorted(plan.stats)
        self._write(key, dict(
            format=np.str_(plan.format), reason=np.str_(plan.reason),
            params_keys=np.asarray(pk, np.str_),
            params_vals=np.asarray([plan.params[k] for k in pk], np.int64),
            stats_keys=np.asarray(sk, np.str_),
            stats_vals=np.asarray([plan.stats[k] for k in sk], np.float64)))

    # -- harvest iteration ----------------------------------------------------
    def iter_plans(self):
        """Yield every decodable (kind, plan) in the cache directory, kind
        in {"format", "tune"} — the learn subsystem's harvest source.

        Classification is structural, not key-based (digests are opaque):
        a FormatPlan payload carries a ``format`` entry, a TunePlan payload
        an ``executor`` entry.  Other plan kinds (tile/spmv/shard) and
        corrupt or foreign files are skipped silently; harvesting must
        degrade, never raise.  Lookup counters are deliberately *not*
        recorded — a training sweep is not a cache workload and must not
        distort the warm-path hit-rate gauge CI gates on.
        """
        if not self.enabled:
            return
        try:
            names = sorted(os.listdir(self.directory))
        except OSError:
            return
        for name in names:
            if not name.endswith(".npz"):
                continue
            try:
                with np.load(os.path.join(self.directory, name),
                             allow_pickle=False) as z:
                    raw = {k: z[k] for k in z.files}
            except (OSError, ValueError, KeyError):
                continue
            if "format" in raw:
                plan = _parse_format_plan(raw)
                if plan is not None:
                    yield "format", plan
            elif "executor" in raw:
                plan = _parse_tune_plan(raw)
                if plan is not None:
                    yield "tune", plan


def _parse_tune_plan(raw: dict):
    """Raw npz dict -> TunePlan, or None on a malformed payload.  ``stats``
    may be absent (plans written before v2 carried none)."""
    from repro.tune.plan import TunePlan
    try:
        params = {str(k): int(v) for k, v in
                  zip(raw["params_keys"], raw["params_vals"])}
        meas = {str(k): float(v) for k, v in
                zip(raw["meas_keys"], raw["meas_vals"])}
        stats = {str(k): float(v) for k, v in
                 zip(raw.get("stats_keys", ()), raw.get("stats_vals", ()))}
        return TunePlan(
            executor=str(raw["executor"]), backend=str(raw["backend"]),
            n_devices=int(raw["n_devices"]), params=params,
            compute_dtype=str(raw["compute_dtype"]),
            reason=str(raw["reason"]), measurements=meas, stats=stats)
    except (KeyError, ValueError):
        return None


def _parse_format_plan(raw: dict) -> Optional[FormatPlan]:
    """Raw npz dict -> FormatPlan, or None on a malformed payload."""
    try:
        params = {str(k): int(v) for k, v in
                  zip(raw["params_keys"], raw["params_vals"])}
        stats = {str(k): float(v) for k, v in
                 zip(raw["stats_keys"], raw["stats_vals"])}
        return FormatPlan(format=str(raw["format"]),
                          reason=str(raw["reason"]),
                          params=params, stats=stats)
    except (KeyError, ValueError):
        return None
