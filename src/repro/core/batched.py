"""Batched multi-subject LiFE: one vmapped SBBNNLS over a subject cohort.

Production LiFE serves many subjects against one shared diffusion dictionary
(the canonical atoms depend on the gradient scheme, not the subject).  Per
subject the workload is identical in *structure* — same Nv voxel grid, same
Nf candidate fibers, same Ntheta directions — but each Phi tensor has its own
coefficient count Nc_s.  This engine:

  1. restructures every subject's Phi per the chosen executor (the same
     per-op sorts :mod:`repro.core.registry` applies for one subject),
  2. pads each subject's coefficient arrays to the cohort max Nc with inert
     dummy slots — value 0 so padding contributes nothing through either
     SpMV, and sort-key index = (dim size - 1) so the padded tail preserves
     the sortedness the segment-sum executors rely on (the same dummy-slot
     idiom as ``kernels/ops.py:_padded_operands``),
  3. stacks the cohort into (S, Nc_max) operands and runs SBBNNLS for all
     subjects at once: one ``lax.scan`` whose body is the vmapped solver
     step, so the per-iteration Barzilai-Borwein step size stays
     *per-subject* while every SpMV becomes one batched device computation.

Batching composes with the plan cache: the "auto" path autotunes once (on
the first subject, through the persistent cache) and applies the measured
sort choice cohort-wide.  Executors whose operands are per-subject static
shapes (``kernel`` tile plans, ``shard`` mesh layouts) are rejected —
:class:`~repro.core.registry.Executor.vmappable` records which factories
admit stacking.  See DESIGN.md §6.2.

Mesh placement (DESIGN.md §9): with ``shard_rows * shard_cols > 1`` the
stacked cohort is laid out over the same (``data``, ``model``) mesh the
sharded executors use — *subjects* shard over the batch (``data``) axis and
the stacked Phi coefficient slots over ``model`` — by ``device_put``-ing
the operands under NamedShardings and letting GSPMD partition the vmapped
solve.  An axis whose size does not divide its mesh axis stays replicated
(jax requires even chunks for explicit placement); results are unchanged
either way, only the partitioning differs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import spmv
from repro.core.plan_cache import PlanCache
from repro.core.registry import _DSC_FNS, _WC_FNS, REGISTRY
from repro.core.restructure import sort_by_host
from repro.core.sbbnnls import SbbnnlsState, sbbnnls_step
from repro.core.std import PhiTensor
from repro.data.dmri import LifeProblem

# executor name -> (dsc sort dim or None, wc sort dim or None, dsc fn, wc fn)
_BATCH_RECIPES = {
    "naive": (None, None, spmv.dsc_naive, spmv.wc_naive),
    "opt": ("voxel", "fiber", spmv.dsc, spmv.wc),
    "opt-paper": ("voxel", "atom", spmv.dsc, spmv.wc_atom_sorted),
}

# dims whose executor consumes a *sorted* segment reduction; padding must
# extend the sort key monotonically for these
_SEGMENT_SORTED = {(spmv.dsc, "voxel"), (spmv.wc, "fiber")}


def _pad_sorted(phi: PhiTensor, nc_max: int, sort_dim: Optional[str],
                keep_sorted: bool) -> PhiTensor:
    """Pad a (possibly sorted) PhiTensor to nc_max inert dummy coefficients."""
    pad = nc_max - phi.n_coeffs
    if pad == 0:
        return phi
    dim_last = {"atom": phi.n_atoms - 1, "voxel": phi.n_voxels - 1,
                "fiber": phi.n_fibers - 1}

    def pad_idx(arr, dim):
        fill = dim_last[dim] if (keep_sorted and dim == sort_dim) else 0
        return jnp.concatenate(
            [arr, jnp.full((pad,), fill, arr.dtype)])

    return dataclasses.replace(
        phi,
        atoms=pad_idx(phi.atoms, "atom"),
        voxels=pad_idx(phi.voxels, "voxel"),
        fibers=pad_idx(phi.fibers, "fiber"),
        values=jnp.concatenate(
            [phi.values, jnp.zeros((pad,), phi.values.dtype)]))


def _stack_phis(phis: Sequence[PhiTensor]) -> PhiTensor:
    return dataclasses.replace(
        phis[0],
        atoms=jnp.stack([p.atoms for p in phis]),
        voxels=jnp.stack([p.voxels for p in phis]),
        fibers=jnp.stack([p.fibers for p in phis]),
        values=jnp.stack([p.values for p in phis]))


class BatchedLifeEngine:
    """Runs SBBNNLS for a cohort of subjects in one vmapped computation.

    All subjects must share the dictionary shape and the (Nv, Nf) problem
    geometry; coefficient counts may differ (padded to the cohort max).
    """

    def __init__(self, problems: Sequence[LifeProblem], config,
                 cache: Optional[PlanCache] = None):
        if not problems:
            raise ValueError("need at least one subject")
        self.problems = list(problems)
        self.config = config
        self.cache = cache if cache is not None else PlanCache(
            getattr(config, "plan_cache_dir", None),
            getattr(config, "plan_cache_max_bytes", None))
        self.format_plan = None       # set when config.format != "coo"
        self.tune_plan = None         # set when config.tune != "off"
        from repro.tune.tuner import validate_config as _validate_tune
        _validate_tune(config)
        if getattr(config, "compact_every", 0) > 0:
            raise ValueError(
                "weight compaction is per-subject (changes Nc mid-run) and "
                "is not supported by the batched engine; use LifeEngine")
        p0 = self.problems[0]
        for p in self.problems[1:]:
            if (p.phi.n_voxels, p.phi.n_fibers) != (p0.phi.n_voxels,
                                                    p0.phi.n_fibers):
                raise ValueError("subjects must share (Nv, Nf) geometry")
            if not np.array_equal(np.asarray(p.dictionary),
                                  np.asarray(p0.dictionary)):
                raise ValueError("subjects must share the dictionary "
                                 "(same gradient scheme and atoms)")
        self.dictionary = p0.dictionary
        self.n_subjects = len(self.problems)
        self.inspector_seconds = 0.0
        self.mesh = self._make_mesh()
        self._build()

    def _make_mesh(self):
        """(data, model) mesh when the config asks for a multi-cell layout."""
        R = getattr(self.config, "shard_rows", 1)
        C = getattr(self.config, "shard_cols", 1)
        if R * C <= 1:
            return None
        if R * C > len(jax.devices()):
            raise ValueError(
                f"batched mesh needs {R * C} devices, "
                f"have {len(jax.devices())}")
        from repro import compat
        return compat.make_mesh((R, C), ("data", "model"))

    def _place_on_mesh(self) -> None:
        """Subjects over the batch (`data`) axis, Phi slots over `model`.

        Axes that don't divide their mesh axis stay replicated (jax needs
        even chunks for device_put); GSPMD keeps results identical."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        subj = ("data" if self.n_subjects % self.mesh.shape["data"] == 0
                else None)
        slot = ("model" if self.nc_padded % self.mesh.shape["model"] == 0
                else None)
        phi_sh = NamedSharding(self.mesh, P(subj, slot))
        b_sh = NamedSharding(self.mesh, P(subj, None, None))
        self.phi_dsc = jax.device_put(self.phi_dsc, phi_sh)
        self.phi_wc = jax.device_put(self.phi_wc, phi_sh)
        self.b = jax.device_put(self.b, b_sh)

    # -- inspector ----------------------------------------------------------
    def _resolve_recipe(self):
        name = self.config.executor
        fmt = getattr(self.config, "format", "coo")
        self._alto_order = False
        if fmt != "coo":
            # Format selection across the vmappable subset: SELL widths are
            # per-subject static shapes, so only COO and ALTO stack; "auto"
            # picks between them on the first subject (FormatPlan-cached),
            # and an explicit format="sell" is rejected by resolve_format.
            from repro.formats import select as fsel
            # mesh_aware=False: shard_rows/cols are placement-only here
            # (device_put of the stacked operands), so alto stays eligible
            self.format_plan = fsel.resolve_format(
                self.problems[0].phi, self.problems[0], self.config,
                self.cache, allowed=("coo", "alto"), mesh_aware=False)
            if self.format_plan.format == "alto":
                self._alto_order = True
                return None, None, spmv.dsc_naive, spmv.wc_naive
        if name in _BATCH_RECIPES:
            return _BATCH_RECIPES[name]
        if name == "auto":
            # tune once on the first subject (persistent-cache-backed),
            # apply the measured choice cohort-wide
            ex = REGISTRY.create("auto", self.problems[0].phi,
                                 self.problems[0], self.config, self.cache)
            dsc_dim = ex.plans["dsc"].restructure
            wc_dim = ex.plans["wc"].restructure
            return dsc_dim, wc_dim, _DSC_FNS[dsc_dim], _WC_FNS[wc_dim]
        raise ValueError(
            f"executor {name!r} is not vmappable across subjects "
            f"(supported: {sorted(_BATCH_RECIPES) + ['auto']})")

    def _resolve_tuning(self) -> str:
        """Resolve the tune plan on the first subject (persistent-cached);
        returns the storage dtype the stacked operands are built under.

        The batched recipes are pure-jnp (no Pallas tile axes), so the
        searched axis that reaches this engine is the compute dtype; tile
        winners in the plan simply don't apply.  Routing through the same
        resolver keeps the plan-cache entry shared with single-subject
        engines on the same dataset/backend."""
        cfg = self.config
        if getattr(cfg, "tune", "off") == "off":
            cd = getattr(cfg, "compute_dtype", "fp32")
            return "fp32" if cd == "auto" else cd
        from repro.tune.tuner import resolve_plan
        self.tune_plan = resolve_plan(cfg.executor, self.problems[0].phi,
                                      self.problems[0], cfg, self.cache)
        return self.tune_plan.compute_dtype

    def _build(self) -> None:
        t0 = time.perf_counter()
        self._compute_dtype = self._resolve_tuning()
        dsc_dim, wc_dim, self._dsc_fn, self._wc_fn = self._resolve_recipe()
        nc_max = max(p.phi.n_coeffs for p in self.problems)
        self.nc_padded = nc_max

        def prep(phi: PhiTensor, dim: Optional[str], fn) -> PhiTensor:
            sorted_phi = sort_by_host(phi, dim)[0] if dim else phi
            keep_sorted = (fn, dim) in _SEGMENT_SORTED
            return _pad_sorted(sorted_phi, nc_max, dim, keep_sorted)

        phis = [p.phi for p in self.problems]
        if self._alto_order:
            # one ALTO-linearized ordering per subject serves both ops
            # (locality in every mode at once; scatter executors above)
            from repro.formats.alto import AltoPhi
            phis = [AltoPhi.encode(phi).sort()[0].decode() for phi in phis]

        self.phi_dsc = _stack_phis(
            [prep(phi, dsc_dim, self._dsc_fn) for phi in phis])
        self.phi_wc = _stack_phis(
            [prep(phi, wc_dim, self._wc_fn) for phi in phis])
        self.b = jnp.stack([p.b for p in self.problems])
        self._d_op = self.dictionary
        if self._compute_dtype == "bf16":
            # bf16 storage of the static operands (stacked Phi values + the
            # shared dictionary); w/Y/b stay fp32 so every product promotes
            # to fp32 before the segment reductions (DESIGN.md §10.3)
            store = jnp.bfloat16
            self.phi_dsc = dataclasses.replace(
                self.phi_dsc, values=self.phi_dsc.values.astype(store))
            self.phi_wc = dataclasses.replace(
                self.phi_wc, values=self.phi_wc.values.astype(store))
            self._d_op = jnp.asarray(self.dictionary).astype(store)
        if self.mesh is not None:
            self._place_on_mesh()
        self._runner = jax.jit(self._make_runner(),
                               static_argnames=("n_iters",))
        self.inspector_seconds += time.perf_counter() - t0

    @property
    def resolved_compute_dtype(self) -> str:
        """Storage dtype the stacked operands were built under (the tune
        plan's winner when ``compute_dtype="auto"`` was searched)."""
        return self._compute_dtype

    def _make_runner(self):
        d = self._d_op
        dsc_fn, wc_fn = self._dsc_fn, self._wc_fn

        def run_batch(phi_dsc, phi_wc, b, states, *, n_iters: int):
            def one_step(phi_v, phi_w, b_s, state):
                return sbbnnls_step(lambda w: dsc_fn(phi_v, d, w),
                                    lambda y: wc_fn(phi_w, d, y), b_s, state)

            def body(ss, _):
                new = jax.vmap(one_step)(phi_dsc, phi_wc, b, ss)
                return new, new.loss

            final, losses = jax.lax.scan(body, states, xs=None,
                                         length=n_iters)
            return final, losses.T            # states, (S, n_iters)

        return run_batch

    # -- driver --------------------------------------------------------------
    def init_states(self, w0: Optional[jax.Array] = None) -> SbbnnlsState:
        """Fresh per-subject solver states stacked along axis 0 (S, ...)."""
        nf = self.problems[0].phi.n_fibers
        if w0 is None:
            w0 = jnp.ones((self.n_subjects, nf), self.dictionary.dtype)
        s = w0.shape[0]
        return SbbnnlsState(w=w0, it=jnp.zeros((s,), jnp.int32),
                            loss=jnp.zeros((s,), w0.dtype))

    def step(self, states: SbbnnlsState, k: int
             ) -> Tuple[SbbnnlsState, np.ndarray]:
        """Advance every subject's state by ``k`` iterations (stepped API).

        Per-subject iteration counters ride in the stacked state, so subjects
        admitted mid-flight (continuous batching) or restored from a
        checkpoint keep their own Barzilai-Borwein parity — chained calls
        match one uninterrupted run exactly.  Returns (states, (S, k) loss
        trace)."""
        if not obs.SWITCH.on:
            new, losses = self._runner(self.phi_dsc, self.phi_wc, self.b,
                                       states, n_iters=k)
            return new, np.asarray(losses)
        with obs.span("engine.step", {"executor": self.config.executor,
                                      "batched": self.n_subjects, "k": k}):
            t0 = time.perf_counter()
            new, losses = self._runner(self.phi_dsc, self.phi_wc, self.b,
                                       states, n_iters=k)
            losses = np.asarray(losses)   # host transfer blocks on the scan
            obs.histogram("engine.step.seconds",
                          executor=self.config.executor).observe(
                time.perf_counter() - t0)
        return new, losses

    def run(self, n_iters: Optional[int] = None,
            w0: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, np.ndarray]:
        """Solve all subjects; returns (W (S, Nf), losses (S, n_iters))."""
        n_iters = self.config.n_iters if n_iters is None else n_iters
        final, losses = self._runner(self.phi_dsc, self.phi_wc, self.b,
                                     self.init_states(w0), n_iters=n_iters)
        return final.w, np.asarray(losses)

    def prune_stats(self, w_batch: jax.Array,
                    threshold: float = 1e-6) -> List[dict]:
        out = []
        for p, w in zip(self.problems, np.asarray(w_batch)):
            true = np.asarray(p.w_true) > 0
            kept = w > threshold
            tp = float(np.sum(kept & true))
            out.append(dict(
                kept=float(kept.sum()), total=float(kept.size),
                precision=tp / max(1.0, float(kept.sum())),
                recall=tp / max(1.0, float(true.sum()))))
        return out
