"""Data restructuring & runtime autotuning (paper §4.1.2, §4.2).

The paper's central target-independent optimization: sort the Phi tensor by
one of its indirection dimensions so indirect accesses become contiguous
*sub-vectors* (runs of equal index).  The winning dimension is chosen at
runtime by measuring each candidate a few times, and the (host-side,
inspector) cost is amortized across the several hundred SBBNNLS iterations —
and across runs, via plan caching.

TPU adaptation: we sort by the *output* dimension of each op (voxel for DSC,
fiber for WC) so the scatter becomes a segment reduction; the paper's CPU/GPU
choice (voxel for DSC, atom for WC) is kept available for comparison.  See
DESIGN.md §2.

Weight compaction (paper §4.2.1.3 "the BLAS call is evaded when the scalar is
zero"): SBBNNLS projects w to the nonnegative orthant so w gets sparser every
iteration; `compact_by_weight` drops coefficients whose fiber weight is zero
— an inspector re-run amortized over the following iterations.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.std import PhiTensor

SORT_DIMS = ("atom", "voxel", "fiber")


def sort_by(phi: PhiTensor, dim: str) -> Tuple[PhiTensor, jax.Array]:
    """Stable sort of the coefficients along one indirection dimension.

    Returns (restructured phi, permutation) — the permutation is kept so
    plans can be cached/replayed (amortization across runs).
    """
    key = {"atom": phi.atoms, "voxel": phi.voxels, "fiber": phi.fibers}[dim]
    order = jnp.argsort(key, stable=True)
    return phi.take(order), order


def sort_by_host(phi: PhiTensor, dim: str) -> Tuple[PhiTensor, np.ndarray]:
    """Host (numpy) variant used by inspectors — no device round-trips."""
    key = {"atom": phi.atoms, "voxel": phi.voxels, "fiber": phi.fibers}[dim]
    order = np.argsort(np.asarray(key), kind="stable")
    return phi.take(jnp.asarray(order)), order


def segment_starts(sorted_ids: np.ndarray) -> np.ndarray:
    """Start offsets of each sub-vector (run of equal ids) in a sorted vector."""
    if sorted_ids.size == 0:
        return np.zeros(0, np.int64)
    change = np.nonzero(np.diff(sorted_ids))[0] + 1
    return np.concatenate([[0], change])


def compact_by_weight(phi: PhiTensor, w, threshold: float = 0.0) -> PhiTensor:
    """Drop coefficients whose fiber weight is (near-)zero.

    Host-side inspector; returns a smaller PhiTensor.  Matches the paper's
    skip-zero-daxpy optimization but at the data-structure level, which is the
    TPU-friendly formulation (no per-element branches on device).
    """
    w = np.asarray(w)
    keep = np.nonzero(w[np.asarray(phi.fibers)] > threshold)[0]
    return phi.take(jnp.asarray(keep, jnp.int32))


@dataclasses.dataclass
class SpmvPlan:
    """Declarative restructuring + partitioning choice for one SpMV op.

    This is the framework's analogue of the paper's PolyMage-DSL layer: the
    user states the op; the autotuner fills in `restructure` (sort dimension)
    and `partition` (coefficient/voxel/atom split), and the executor honours
    it.  Cached in-process so repeated runs skip the measurement.
    """

    op: str                      # "dsc" | "wc"
    restructure: str             # member of SORT_DIMS (or a format name when
                                 # the candidates are formats, see formats/select.py)
    partition: str               # "coeff" | "voxel" | "atom" | "fiber"
    order: Optional[np.ndarray] = None   # cached permutation

    def describe(self) -> str:
        return f"{self.op}: sort-by-{self.restructure}, {self.partition}-partition"


# In-process memo for autotune_plan.  Keys include phi.n_coeffs so a
# compact_by_weight shrink (same logical dataset, fewer coefficients) misses
# cleanly instead of replaying a stale choice; clear_plan_cache() gives
# long-running services an explicit bound.  Persistent, content-addressed
# caching lives in core/plan_cache.py — prefer routing through that.
_PLAN_CACHE: Dict[Tuple, SpmvPlan] = {}


def clear_plan_cache() -> None:
    """Drop every in-process memoized plan (the dict is otherwise unbounded)."""
    _PLAN_CACHE.clear()


def autotune_plan(
    op: str,
    phi: PhiTensor,
    run: Callable[[PhiTensor, str], jax.Array],
    candidates: Tuple[str, ...] = ("atom", "voxel", "fiber"),
    repeats: int = 3,
    cache_key: Optional[Tuple] = None,
    sorter: Callable[[PhiTensor, str], Tuple] = sort_by_host,
) -> SpmvPlan:
    """Measure each restructuring candidate `repeats` times, pick the best.

    Mirrors the paper's runtime selection ("average execution time for three
    runs") — timed through the one shared measurement loop in
    :mod:`repro.tune.search`, the same loop the kernel autotuner uses, so
    restructuring choice, format choice, and tile choice are measured with
    identical semantics.  ``run(prepared, candidate)`` executes the op for
    the candidate's prepared data and blocks until ready.  ``sorter(phi,
    candidate)`` builds that prepared data plus an optional permutation; the
    default sorts along an indirection dimension, and formats/select.py
    substitutes format encoders so the same measurement loop arbitrates
    between layouts.
    """
    from repro.tune import search as tsearch
    full_key = None
    if cache_key is not None:
        full_key = ("plan", op, phi.n_coeffs) + cache_key
        if full_key in _PLAN_CACHE:
            return _PLAN_CACHE[full_key]
    prepared_orders = {}

    def measure(dim: str) -> float:
        prepared, order = sorter(phi, dim)
        prepared_orders[dim] = order
        return tsearch.time_call(lambda: run(prepared, dim),
                                 warmup=1, repeats=repeats)

    best_i, _ = tsearch.measure_candidates(tuple(candidates), measure)
    best_dim = tuple(candidates)[best_i]
    best = (None, best_dim, prepared_orders[best_dim])
    # Output-side sorts admit segment (sync-free) partitioning; input-side
    # sorts fall back to coefficient partitioning (paper Table 3/4 combos).
    out_dim = "voxel" if op == "dsc" else "fiber"
    partition = out_dim if best[1] == out_dim else "coeff"
    plan = SpmvPlan(op=op, restructure=best[1], partition=partition, order=best[2])
    if full_key is not None:
        _PLAN_CACHE[full_key] = plan
    return plan
