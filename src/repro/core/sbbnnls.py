"""SBBNNLS — Subspace Barzilai-Borwein non-negative least squares.

Algorithm 1 of the paper (Kim, Sra & Dhillon 2013), the optimizer that LiFE
runs for 500+ iterations and whose two SpMV ops (DSC: ``M w``; WC: ``M^T y``)
this framework optimizes.  The solver is written against abstract
``matvec``/``rmatvec`` closures so the same loop runs on:

  * the naive executors               (CPU-naive analogue)
  * the restructured executors        (CPU/GPU-opt analogue)
  * Pallas kernel executors           (TPU target)
  * shard_map 2-D mesh executors      (multi-pod)

Per average iteration the loop issues 2 x matvec and 1.5 x rmatvec, matching
the paper's accounting (§2.2).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
MatVec = Callable[[Array], Array]


class SbbnnlsState(NamedTuple):
    w: Array          # current weights (Nf,), nonnegative
    it: Array         # iteration counter (int32)
    loss: Array       # 0.5 * ||Mw - b||^2 at last step


def projected_gradient(w: Array, g: Array) -> Array:
    """Subspace projection: zero the gradient on the active set.

    Components with w == 0 and g > 0 would push w negative; they are frozen
    (the paper's "gradient projected to the positive space").
    """
    return jnp.where((w > 0) | (g < 0), g, 0.0)


def sbbnnls_step(matvec: MatVec, rmatvec: MatVec, b: Array,
                 state: SbbnnlsState) -> SbbnnlsState:
    """One SBBNNLS iteration (Algorithm 1)."""
    w, it = state.w, state.it
    y = matvec(w) - b                       # DSC (+ residual)
    g = rmatvec(y)                          # WC
    gt = projected_gradient(w, g)
    v = matvec(gt)                          # DSC

    def odd_alpha(_):
        return _safe_div(_dot(gt, gt), _dot(v, v))

    def even_alpha(_):
        vv = rmatvec(v)                     # WC (every other iteration)
        vv = projected_gradient(w, vv)
        return _safe_div(_dot(v, v), _dot(vv, vv))

    alpha = jax.lax.cond(it % 2 == 1, odd_alpha, even_alpha, operand=None)
    w_new = jnp.maximum(w - alpha * gt, 0.0)
    loss = 0.5 * _dot(y, y)
    return SbbnnlsState(w=w_new, it=it + 1, loss=loss)


def _dot(a: Array, b: Array) -> Array:
    return jnp.vdot(a.reshape(-1), b.reshape(-1))


def _safe_div(num: Array, den: Array) -> Array:
    return jnp.where(den > 0, num / jnp.where(den > 0, den, 1.0), 0.0)


def sbbnnls_init(w0: Array) -> SbbnnlsState:
    """Fresh solver state at iteration 0 (the stepped-API entry point)."""
    return SbbnnlsState(w=w0, it=jnp.asarray(0, jnp.int32),
                        loss=jnp.asarray(0.0, w0.dtype))


@partial(jax.jit, static_argnames=("matvec", "rmatvec", "n_iters"))
def sbbnnls_steps(matvec: MatVec, rmatvec: MatVec, b: Array,
                  state: SbbnnlsState, n_iters: int
                  ) -> Tuple[SbbnnlsState, Array]:
    """Advance an existing state by n_iters iterations (state in -> k iters
    -> state out).  Because ``state.it`` rides along, the Barzilai-Borwein
    odd/even alternation continues where it left off: composing
    ``k x (n/k)`` calls is exactly one ``n``-iteration run, which is what
    makes time-sliced and checkpoint-resumed solves bit-compatible with
    uninterrupted ones (serve/ relies on this)."""
    def body(s, _):
        new = sbbnnls_step(matvec, rmatvec, b, s)
        return new, new.loss

    final, losses = jax.lax.scan(body, state, xs=None, length=n_iters)
    return final, losses


def sbbnnls_run(matvec: MatVec, rmatvec: MatVec, b: Array, w0: Array,
                n_iters: int) -> Tuple[SbbnnlsState, Array]:
    """Run n_iters iterations under lax.scan; returns (final state, losses)."""
    return sbbnnls_steps(matvec, rmatvec, b, sbbnnls_init(w0), n_iters)


def nnls_loss(matvec: MatVec, b: Array, w: Array) -> Array:
    r = matvec(w) - b
    return 0.5 * _dot(r, r)
