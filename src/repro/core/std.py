"""Sparse Tucker Decomposition (STD) encoding of the LiFE matrix M.

The ENCODE representation (Caiafa & Pestilli 2017) stores the connectome
matrix ``M in R^{Ntheta*Nv x Nf}`` as:

  * a dictionary ``D in R^{Na x Ntheta}`` of canonical diffusion atoms, and
  * a sparse third-order tensor ``Phi`` with ``Nc`` nonzero coefficients,
    each a triple of indirection indices ``(atom_k, voxel_k, fiber_k)`` plus
    a value ``val_k``.

With that encoding the two SpMV hot ops of SBBNNLS become (Figure 3 of the
paper):

  DSC  (y = M w):    Y[voxel_k, :] += D[atom_k, :] * w[fiber_k] * val_k
  WC   (w = M^T y):  w[fiber_k]    += val_k * <D[atom_k, :], Y[voxel_k, :]>

This module holds the PhiTensor container plus dense materialization used as
the test oracle.  All indices are int32 (the paper's "strength reduction for
arrays": the original MATLAB code shipped them as float64).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PhiTensor:
    """COO sparse Tucker core of the LiFE matrix.

    atoms, voxels, fibers: int32[Nc] indirection vectors.
    values: float[Nc] coefficient values.
    n_atoms / n_voxels / n_fibers: static dimension sizes.
    """

    atoms: Array
    voxels: Array
    fibers: Array
    values: Array
    n_atoms: int = dataclasses.field(metadata=dict(static=True))
    n_voxels: int = dataclasses.field(metadata=dict(static=True))
    n_fibers: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_coeffs(self) -> int:
        return self.values.shape[0]

    def astype(self, dtype) -> "PhiTensor":
        return dataclasses.replace(self, values=self.values.astype(dtype))

    def take(self, order: Array) -> "PhiTensor":
        """Reorder coefficients (the paper's data restructuring primitive)."""
        return dataclasses.replace(
            self,
            atoms=jnp.take(self.atoms, order),
            voxels=jnp.take(self.voxels, order),
            fibers=jnp.take(self.fibers, order),
            values=jnp.take(self.values, order),
        )

    def validate(self) -> None:
        a, v, f = map(np.asarray, (self.atoms, self.voxels, self.fibers))
        if a.size and (a.min() < 0 or a.max() >= self.n_atoms):
            raise ValueError("atom index out of range")
        if v.size and (v.min() < 0 or v.max() >= self.n_voxels):
            raise ValueError("voxel index out of range")
        if f.size and (f.min() < 0 or f.max() >= self.n_fibers):
            raise ValueError("fiber index out of range")


def materialize_dense(phi: PhiTensor, dictionary: Array) -> Array:
    """Dense M in R^{(Nv*Ntheta) x Nf}; oracle only — O(Nv*Ntheta*Nf) memory.

    M[v*Ntheta + t, f] = sum over coefficients k with (voxel_k=v, fiber_k=f)
                         of D[atom_k, t] * val_k
    """
    n_theta = dictionary.shape[1]
    m = jnp.zeros((phi.n_voxels * n_theta, phi.n_fibers), dictionary.dtype)
    rows = phi.voxels[:, None] * n_theta + jnp.arange(n_theta)[None, :]
    cols = jnp.broadcast_to(phi.fibers[:, None], rows.shape)
    vals = dictionary[phi.atoms] * phi.values[:, None]
    return m.at[rows.reshape(-1), cols.reshape(-1)].add(vals.reshape(-1))


def demean_signal(y: Array, n_theta: int) -> Array:
    """Per-voxel demeaning of the measured diffusion signal (LiFE convention)."""
    y2 = y.reshape(-1, n_theta)
    return (y2 - y2.mean(axis=1, keepdims=True)).reshape(-1)


def make_dictionary(n_atoms: int, n_theta: int, *, key: Optional[Array] = None,
                    dtype=jnp.float32) -> Array:
    """Synthetic canonical-atom dictionary.

    Atoms model stick-like diffusion responses along quasi-uniform 3-D
    orientations, evaluated against Ntheta gradient directions — demeaned per
    atom, matching the ENCODE dictionary construction closely enough for
    performance work.
    """
    if key is None:
        key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    atom_dirs = _fibonacci_sphere(n_atoms)
    grad_dirs = np.array(jax.random.normal(k1, (n_theta, 3)))
    grad_dirs /= np.linalg.norm(grad_dirs, axis=1, keepdims=True)
    # Stick model: S(theta) = exp(-b * d * (g . n)^2)
    cos2 = (grad_dirs @ atom_dirs.T) ** 2  # (Ntheta, Na)
    sig = np.exp(-2.0 * cos2).T  # (Na, Ntheta)
    sig = sig - sig.mean(axis=1, keepdims=True)
    return jnp.asarray(sig, dtype)


def _fibonacci_sphere(n: int) -> np.ndarray:
    i = np.arange(n, dtype=np.float64) + 0.5
    phi = np.arccos(1 - 2 * i / n)
    theta = np.pi * (1 + 5 ** 0.5) * i
    return np.stack(
        [np.cos(theta) * np.sin(phi), np.sin(theta) * np.sin(phi), np.cos(phi)],
        axis=1,
    )
