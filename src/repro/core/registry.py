"""Executor registry: named factories behind one matvec/rmatvec protocol.

Replaces the if/elif executor ladder that used to live in ``core/life.py``.
Every way of running the two LiFE SpMV ops — naive scatter, restructured
segment-sum (paper and TPU sort choices), inspector-planned Pallas kernels,
runtime autotuning, and the shard_map mesh partition — registers a factory
under a name; ``LifeEngine``, ``BatchedLifeEngine``, benchmarks and tests
all resolve executors through the registry, so adding a code version is one
``@REGISTRY.register(...)`` function, not an engine edit.

Protocol: a factory takes ``(phi, problem, config, cache)`` and returns an
:class:`Executor` whose ``matvec(w) -> (Nv, Ntheta)`` and
``rmatvec(y) -> (Nf,)`` run DSC / WC for that code version.  ``cache`` is a
:class:`~repro.core.plan_cache.PlanCache`; factories that do inspector work
(tile planning, autotune measurement) route it through the cache so the cost
is paid once per dataset, not once per construction (DESIGN.md §6).

The ladder (paper §6.3.1/§6.4.1):

  naive        CPU-naive        : Figure-3 translation, scatter/gather adds
  opt-paper    CPU/GPU-opt      : per-op restructuring as the paper ships it
  opt          TPU-opt (ours)   : output-side sorts for both ops
  kernel       TPU Pallas       : inspector-planned tiled kernels
  kernel-sell  TPU Pallas/SELL  : blocked-ELL layout, direct row-block
                                  accumulation (no prefetch map, DESIGN.md §7)
  alto         linearized COO   : ALTO single-index sort order, one Phi copy
                                  serves both ops
  kernel-fcoo  TPU Pallas/F-COO : segment-flagged linearization; ONE resident
                                  copy feeds both ops via segment-scan
                                  kernels (DESIGN.md §11)
  auto         runtime autotune : measured selection (paper §4.1.2)
  shard        mesh partition   : 2-D shard_map SpMVs over inner sorted-COO
                                  cells behind the same protocol
  shard-sell   mesh + SELL      : per-cell SELL tiles feeding the Pallas
                                  SELL kernels under shard_map (DESIGN.md §9)

Format-parameterized construction: ``create_for_format`` resolves a
``LifeConfig.format`` choice ("coo"/"sell"/"alto"/"auto", the latter via
``formats.select``) to the executor that consumes the chosen layout, and
records the :class:`~repro.formats.base.FormatPlan` in the executor's
``plans`` dict so engines can report what was picked and why.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spmv
from repro.core.inspector import plan_tiles
from repro.core.plan_cache import PlanCache, spmv_plan_key, tile_plan_key
from repro.core.restructure import SpmvPlan, autotune_plan, sort_by_host
from repro.core.std import PhiTensor

Array = jax.Array
MatVec = Callable[[Array], Array]


@dataclasses.dataclass
class Executor:
    """A bound pair of SpMV closures plus inspector diagnostics."""

    name: str
    matvec: MatVec                        # w (Nf,) -> y (Nv, Ntheta)
    rmatvec: MatVec                       # y (Nv, Ntheta) -> w (Nf,)
    plans: Dict[str, object] = dataclasses.field(default_factory=dict)

    # Set by factories that can run under vmap with stacked operands; the
    # batched engine refuses executors that cannot (kernel plans and mesh
    # layouts are per-subject static shapes).
    vmappable: bool = False


ExecutorFactory = Callable[..., Executor]


class ExecutorRegistry:
    """Name -> factory mapping with decorator registration.

    ``consumes`` records which registered Phi layout a factory materializes
    and runs (every factory *takes* the canonical COO tensor; this names the
    layout it executes over).  The serving scheduler buckets jobs by it, and
    the conformance matrix (tests/test_conformance.py) derives the full set
    of executor x format pairs it must hold to the oracle from it — so a new
    executor is covered by the contract the moment it registers.
    """

    def __init__(self):
        self._factories: Dict[str, ExecutorFactory] = {}
        self._consumes: Dict[str, str] = {}
        self._mesh: Dict[str, bool] = {}

    def register(self, name: str, *, consumes: str = "coo",
                 mesh: bool = False
                 ) -> Callable[[ExecutorFactory], ExecutorFactory]:
        """Decorator registering an executor factory.

        Args:
            name: executor name (``LifeConfig.executor`` value).
            consumes: registered Phi layout the factory runs over.
            mesh: True for the mesh-partitioned path of ``consumes``
                (at most one per format; see :meth:`mesh_executor_for`).

        Raises:
            ValueError: when ``name`` is already registered.
        """
        def deco(factory: ExecutorFactory) -> ExecutorFactory:
            if name in self._factories:
                raise ValueError(f"executor {name!r} already registered")
            self._factories[name] = factory
            self._consumes[name] = consumes
            self._mesh[name] = mesh
            return factory
        return deco

    def names(self) -> Tuple[str, ...]:
        """All registered executor names, sorted."""
        return tuple(sorted(self._factories))

    def consumes(self, name: str) -> str:
        """Phi layout executor ``name`` runs over ("coo"/"sell"/"alto")."""
        if name not in self._consumes:
            raise ValueError(
                f"executor must be one of {self.names()}, got {name!r}")
        return self._consumes[name]

    def executors_for_format(self, format_name: str) -> Tuple[str, ...]:
        """All registered executors that run over ``format_name``."""
        return tuple(sorted(n for n, f in self._consumes.items()
                            if f == format_name))

    def mesh_executor_for(self, format_name: str) -> Optional[str]:
        """The mesh-partitioned executor consuming ``format_name`` (the
        factory registered with ``mesh=True``), or None when the format has
        no sharded path (e.g. alto).  Drives the selector's mesh-aware
        candidate set and the serving scheduler's mesh-slice buckets."""
        for n in self.executors_for_format(format_name):
            if self._mesh.get(n):
                return n
        return None

    def __contains__(self, name: str) -> bool:
        return name in self._factories

    def create(self, name: str, phi: PhiTensor, problem, config,
               cache: Optional[PlanCache] = None,
               tune_plan=None) -> Executor:
        """Instantiate executor ``name`` for ``phi`` (which may be a
        compacted descendant of ``problem.phi``).

        Tuning hook (DESIGN.md §10): with ``config.tune != "off"`` (and no
        explicit ``tune_plan``) the kernel autotuner resolves a
        :class:`~repro.tune.plan.TunePlan` for this (dataset, executor,
        backend) through the plan cache; the plan's launch parameters are
        substituted into the config the factory sees, and the plan itself
        lands in ``executor.plans["tune"]`` so engines can report what ran.
        An explicit ``tune_plan`` is applied verbatim (no search).
        """
        if name not in self._factories:
            raise ValueError(
                f"executor must be one of {self.names()}, got {name!r}")
        if cache is None:
            cache = PlanCache("")        # disabled cache
        if tune_plan is None and getattr(config, "tune", "off") != "off":
            from repro.tune.tuner import resolve_plan
            tune_plan = resolve_plan(name, phi, problem, config, cache)
        if tune_plan is not None:
            config = tune_plan.apply(config)
        executor = self._factories[name](phi, problem, config, cache)
        if tune_plan is not None:
            executor.plans["tune"] = tune_plan
        return executor


REGISTRY = ExecutorRegistry()


# ----------------------------------------------------------------------------
# Built-in factories
# ----------------------------------------------------------------------------

def _compute_dtype(config) -> str:
    """Resolved storage dtype a factory should build under ("auto" only
    reaches a factory when the tuner was bypassed — treat it as fp32)."""
    cd = getattr(config, "compute_dtype", "fp32")
    return "fp32" if cd == "auto" else cd


def _with_storage_dtype(phi: PhiTensor, dictionary, config):
    """bf16 storage of the static operands for the jnp executors.

    Dynamic operands (w, Y) stay fp32, so every product promotes to fp32
    before the segment/scatter reductions — bf16 storage, fp32 accumulate,
    uniformly with the Pallas paths (kernels/ops.py, DESIGN.md §10.3)."""
    if _compute_dtype(config) != "bf16":
        return phi, dictionary
    return (dataclasses.replace(
                phi, values=jnp.asarray(phi.values).astype(jnp.bfloat16)),
            jnp.asarray(dictionary).astype(jnp.bfloat16))


@REGISTRY.register("naive")
def _make_naive(phi, problem, config, cache) -> Executor:
    phi, d = _with_storage_dtype(phi, problem.dictionary, config)
    return Executor(
        name="naive",
        matvec=lambda w: spmv.dsc_naive(phi, d, w),
        rmatvec=lambda y: spmv.wc_naive(phi, d, y),
        vmappable=True)


def _sorted_pair(phi: PhiTensor, wc_dim: str):
    phi_v, order_v = sort_by_host(phi, "voxel")
    phi_w, order_w = sort_by_host(phi, wc_dim)
    return phi_v, phi_w, order_v, order_w


@REGISTRY.register("opt")
def _make_opt(phi, problem, config, cache) -> Executor:
    phi, d = _with_storage_dtype(phi, problem.dictionary, config)
    phi_v, phi_w, _, _ = _sorted_pair(phi, "fiber")
    return Executor(
        name="opt",
        matvec=lambda w: spmv.dsc(phi_v, d, w),
        rmatvec=lambda y: spmv.wc(phi_w, d, y),
        vmappable=True)


@REGISTRY.register("opt-paper")
def _make_opt_paper(phi, problem, config, cache) -> Executor:
    phi, d = _with_storage_dtype(phi, problem.dictionary, config)
    phi_v, phi_w, _, _ = _sorted_pair(phi, "atom")
    return Executor(
        name="opt-paper",
        matvec=lambda w: spmv.dsc(phi_v, d, w),
        rmatvec=lambda y: spmv.wc_atom_sorted(phi_w, d, y),
        vmappable=True)


def planned_tiles(sorted_ids: np.ndarray, n_rows: int, *, c_tile: int,
                  row_tile: int, cache: PlanCache):
    """plan_tiles through the persistent cache (content-addressed)."""
    key = tile_plan_key(sorted_ids, n_rows, c_tile=c_tile, row_tile=row_tile)
    plan = cache.get_tile_plan(key)
    if plan is None:
        plan = plan_tiles(sorted_ids, n_rows, c_tile=c_tile, row_tile=row_tile)
        cache.put_tile_plan(key, plan)
    return plan


@REGISTRY.register("kernel")
def _make_kernel(phi, problem, config, cache) -> Executor:
    from repro.kernels import ops as kops
    d = problem.dictionary
    phi_v, phi_w, _, _ = _sorted_pair(phi, "fiber")
    dsc_plan = planned_tiles(np.asarray(phi_v.voxels), phi.n_voxels,
                             c_tile=config.c_tile, row_tile=config.row_tile,
                             cache=cache)
    wc_plan = planned_tiles(np.asarray(phi_w.fibers), phi.n_fibers,
                            c_tile=config.c_tile, row_tile=config.row_tile,
                            cache=cache)
    cd = _compute_dtype(config)
    return Executor(
        name="kernel",
        matvec=kops.make_dsc(phi_v, d, dsc_plan,
                             interpret=config.kernel_interpret,
                             compute_dtype=cd),
        rmatvec=kops.make_wc(phi_w, d, wc_plan,
                             interpret=config.kernel_interpret,
                             compute_dtype=cd),
        plans=dict(dsc_tiles=dsc_plan, wc_tiles=wc_plan))


@REGISTRY.register("kernel-sell", consumes="sell")
def _make_kernel_sell(phi, problem, config, cache) -> Executor:
    """Pallas executors over the blocked-ELL layout (formats/sell.py).

    The SELL encode replaces the TilePlan inspector entirely: the layout's
    static slot arrays ARE the plan, so there is nothing to tile, no scalar
    prefetch, and no one-hot scatter in the kernels (DESIGN.md §7)."""
    from repro.formats.sell import SellPhi
    from repro.kernels import ops as kops
    d = problem.dictionary
    row_tile = getattr(config, "row_tile", 8)
    slot_tile = getattr(config, "slot_tile", 32)
    sell_dsc = SellPhi.encode(phi, op="dsc", row_tile=row_tile,
                              slot_tile=slot_tile)
    sell_wc = SellPhi.encode(phi, op="wc", row_tile=row_tile,
                             slot_tile=slot_tile)
    cd = _compute_dtype(config)
    return Executor(
        name="kernel-sell",
        matvec=kops.make_dsc_sell(sell_dsc, d,
                                  interpret=config.kernel_interpret,
                                  compute_dtype=cd),
        rmatvec=kops.make_wc_sell(sell_wc, d,
                                  interpret=config.kernel_interpret,
                                  compute_dtype=cd),
        plans=dict(sell_dsc=sell_dsc, sell_wc=sell_wc))


@REGISTRY.register("kernel-fcoo", consumes="fcoo")
def _make_kernel_fcoo(phi, problem, config, cache) -> Executor:
    """Pallas segment-scan executors over ONE F-COO copy (formats/fcoo.py).

    Unlike kernel-sell there is no per-op encode: the single linearized
    stream plus its segment metadata serves matvec AND rmatvec (the WC view
    is a permutation gather, not a copy) — the one-copy residency DESIGN.md
    §11 accounts for and table12 gates at 0.6x of SELL(DSC)+SELL(WC)."""
    from repro.formats.fcoo import FcooPhi
    from repro.kernels import ops as kops
    fc = FcooPhi.encode(phi, c_tile=config.c_tile,
                        seg_tile=getattr(config, "seg_tile", 16))
    matvec, rmatvec = kops.make_fcoo_ops(
        fc, problem.dictionary, interpret=config.kernel_interpret,
        compute_dtype=_compute_dtype(config))
    return Executor(name="kernel-fcoo", matvec=matvec, rmatvec=rmatvec,
                    plans=dict(fcoo=fc))


@REGISTRY.register("alto", consumes="alto")
def _make_alto(phi, problem, config, cache) -> Executor:
    """Both ops over one ALTO-ordered Phi copy (formats/alto.py).

    The linearized sort gives locality in every mode at once, so the same
    coefficient order feeds DSC and WC — halving resident index memory
    versus the two per-op sorted copies the other executors keep."""
    from repro.formats.alto import AltoPhi
    enc, _ = AltoPhi.encode(phi).sort()
    phi_lin, d = _with_storage_dtype(enc.decode(), problem.dictionary,
                                     config)
    # keep accounting only — retaining `enc` would hold a second
    # (lin, values) copy alive for the executor's lifetime
    meta = dict(n_coeffs=enc.n_coeffs, nbytes=enc.nbytes)
    return Executor(
        name="alto",
        matvec=lambda w: spmv.dsc_naive(phi_lin, d, w),
        rmatvec=lambda y: spmv.wc_naive(phi_lin, d, y),
        plans=dict(alto=meta),
        vmappable=True)


def create_for_format(phi, problem, config,
                      cache: Optional[PlanCache] = None,
                      allowed: Optional[Tuple[str, ...]] = None) -> Executor:
    """Resolve ``config.format`` (possibly "auto") to a bound executor.

    The chosen/loaded FormatPlan lands in ``executor.plans["format"]``.
    ``format="coo"`` (the default) preserves the pre-format behaviour:
    the executor named by ``config.executor`` over the canonical layout.
    """
    from repro.formats import select as fsel
    if cache is None:
        cache = PlanCache("")
    plan = fsel.resolve_format(phi, problem, config, cache, allowed=allowed)
    name = fsel.executor_for(plan.format, config)
    cells = (getattr(config, "shard_rows", 1)
             * getattr(config, "shard_cols", 1))
    if cells > 1 and name != REGISTRY.mesh_executor_for(plan.format):
        # never silently drop a requested partition: a format with no
        # sharded path (alto) cannot honor shard_rows x shard_cols > 1
        from repro.formats import format_names
        meshable = [f for f in format_names()
                    if REGISTRY.mesh_executor_for(f)]
        raise ValueError(
            f"format {plan.format!r} has no mesh executor; cannot honor "
            f"shard_rows x shard_cols = {cells} "
            f"(mesh-capable formats: {meshable})")
    executor = REGISTRY.create(name, phi, problem, config, cache)
    executor.plans["format"] = plan
    return executor


# per sort-dim executors: output-side sorts get segment-sum paths,
# input-side sorts keep the scatter (paper Table 2/3 combinations)
_DSC_FNS = {"atom": spmv.dsc_atom_sorted, "voxel": spmv.dsc,
            "fiber": spmv.dsc_atom_sorted}   # fiber-sort: unsorted Y path
_WC_FNS = {"atom": spmv.wc_atom_sorted, "voxel": spmv.wc_atom_sorted,
           "fiber": spmv.wc}


@REGISTRY.register("auto")
def _make_auto(phi, problem, config, cache) -> Executor:
    phi, d = _with_storage_dtype(phi, problem.dictionary, config)
    probe_dtype = problem.dictionary.dtype     # probes mimic solver operands
    atoms = np.asarray(phi.atoms)
    voxels = np.asarray(phi.voxels)
    fibers = np.asarray(phi.fibers)

    def tuned(op: str, run) -> SpmvPlan:
        key = spmv_plan_key(op, atoms, voxels, fibers)
        plan = cache.get_spmv_plan(key)
        if plan is None:
            plan = autotune_plan(op, phi, run)
            cache.put_spmv_plan(key, plan)
        if plan.order is None:      # cached choice without the permutation
            _, plan.order = sort_by_host(phi, plan.restructure)
        return plan

    w_probe = jnp.ones((phi.n_fibers,), probe_dtype)
    y_probe = jnp.ones((phi.n_voxels, d.shape[1]), probe_dtype)
    dsc_plan = tuned("dsc", lambda p, dim: _DSC_FNS[dim](p, d, w_probe))
    wc_plan = tuned("wc", lambda p, dim: _WC_FNS[dim](p, d, y_probe))

    phi_v = phi.take(jnp.asarray(dsc_plan.order))
    phi_w = phi.take(jnp.asarray(wc_plan.order))
    dsc_fn = _DSC_FNS[dsc_plan.restructure]
    wc_fn = _WC_FNS[wc_plan.restructure]
    return Executor(
        name="auto",
        matvec=lambda w: dsc_fn(phi_v, d, w),
        rmatvec=lambda y: wc_fn(phi_w, d, y),
        plans=dict(dsc=dsc_plan, wc=wc_plan),
        vmappable=True)


def _layout_positions(plan, n_voxels: int, n_fibers: int
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """global id -> padded (range-stacked) position maps, host-computed once."""
    w_pos = np.zeros(n_fibers, np.int64)
    for c in range(plan.C):
        lo, hi = plan.fiber_cuts[c], plan.fiber_cuts[c + 1]
        w_pos[lo:hi] = c * plan.nf_local + np.arange(hi - lo)
    y_pos = np.zeros(n_voxels, np.int64)
    for r in range(plan.R):
        lo, hi = plan.voxel_cuts[r], plan.voxel_cuts[r + 1]
        y_pos[lo:hi] = r * plan.nv_local + np.arange(hi - lo)
    return w_pos, y_pos


def _make_shard_executor(phi, problem, config, cache,
                         cell_format: str) -> Executor:
    """Shared factory for the mesh executors (`shard` / `shard-sell`).

    Builds an (R, C) = (shard_rows, shard_cols) mesh over the available
    devices, materializes each (voxel-range x fiber-range) cell through the
    PhiFormat protocol (``formats/shard.py:ShardPhi`` composing the inner
    ``cell_format``), and wraps the shard_map'd per-op functions with the
    global<->padded layout maps so callers see plain (Nf,) -> (Nv, Ntheta)
    closures.  The partition plan is persistent-cache-backed under a key
    that includes the mesh shape, the inner format, and the device count.
    """
    from repro import compat
    from repro.distributed import life_shard as LS
    from repro.formats.shard import encode_pair, partition_cuts

    R = getattr(config, "shard_rows", 1)
    C = getattr(config, "shard_cols", 1)
    name = "shard" if cell_format == "coo" else "shard-sell"
    if R * C > len(jax.devices()):
        raise ValueError(
            f"{name} executor needs {R * C} devices, "
            f"have {len(jax.devices())}")
    mesh = compat.make_mesh((R, C), ("data", "model"))
    d = problem.dictionary
    n_theta = d.shape[1]
    cd = _compute_dtype(config)
    plan = partition_cuts(phi, R, C, cell_format=cell_format, cache=cache)
    row_tile = getattr(config, "row_tile", 8)
    slot_tile = getattr(config, "slot_tile", 32)
    sp_dsc, sp_wc = encode_pair(phi, cell_format=cell_format, plan=plan,
                                row_tile=row_tile, slot_tile=slot_tile)
    meta = dict(nv_local=plan.nv_local, nf_local=plan.nf_local,
                n_theta=n_theta)

    w_pos, y_pos = _layout_positions(plan, phi.n_voxels, phi.n_fibers)
    w_pos_j = jnp.asarray(w_pos)
    y_pos_j = jnp.asarray(y_pos)
    nf_pad = C * plan.nf_local
    nv_pad = R * plan.nv_local

    if cell_format == "coo":
        from repro.kernels.ops import storage_cast
        dsc_sm, wc_sm = LS.make_sharded_ops(mesh, meta)
        cell = tuple(storage_cast(sp_dsc.arrays[k], cd) if k == "values"
                     else jnp.asarray(sp_dsc.arrays[k])
                     for k in ("atoms", "voxels", "fibers", "values"))
        wcell = tuple(storage_cast(sp_wc.arrays[k], cd) if k == "values"
                      else jnp.asarray(sp_wc.arrays[k])
                      for k in ("atoms", "voxels", "fibers", "values"))
        d_op = storage_cast(d, cd)

        def run_dsc(w_padded):
            return dsc_sm(*cell, d_op, w_padded)

        def run_wc(y_padded):
            return wc_sm(*wcell, d_op, y_padded)
    else:
        from repro.kernels.ops import pad_lanes, storage_cast
        dsc_sm, wc_sm = LS.make_sharded_sell_ops(
            mesh, meta, row_tile=row_tile, slot_tile=slot_tile,
            out_dtype=d.dtype,
            interpret=getattr(config, "kernel_interpret", True))
        cell = (jnp.asarray(sp_dsc.arrays["atoms"]),
                jnp.asarray(sp_dsc.arrays["others"]),
                storage_cast(sp_dsc.arrays["values"], cd))
        wcell = (jnp.asarray(sp_wc.arrays["atoms"]),
                 jnp.asarray(sp_wc.arrays["others"]),
                 storage_cast(sp_wc.arrays["values"], cd))
        d_op = pad_lanes(storage_cast(d, cd))

        def run_dsc(w_padded):
            return dsc_sm(*cell, d_op, w_padded)[:, :n_theta]

        def run_wc(y_padded):
            return wc_sm(*wcell, d_op, pad_lanes(y_padded))

    @jax.jit
    def matvec(w: Array) -> Array:
        w_padded = jnp.zeros((nf_pad,), w.dtype).at[w_pos_j].set(w)
        y_padded = run_dsc(w_padded)
        return jnp.take(y_padded, y_pos_j, axis=0)

    @jax.jit
    def rmatvec(y: Array) -> Array:
        y_padded = jnp.zeros((nv_pad, y.shape[1]), y.dtype
                             ).at[y_pos_j].set(y)
        w_padded = run_wc(y_padded)
        return jnp.take(w_padded, w_pos_j)

    return Executor(name=name, matvec=matvec, rmatvec=rmatvec,
                    plans=dict(mesh=mesh, partition=plan,
                               shard_dsc=sp_dsc, shard_wc=sp_wc))


@REGISTRY.register("shard", mesh=True)
def _make_shard(phi, problem, config, cache) -> Executor:
    """2-D mesh-partitioned SpMVs over inner sorted-COO cells."""
    return _make_shard_executor(phi, problem, config, cache, "coo")


@REGISTRY.register("shard-sell", consumes="sell", mesh=True)
def _make_shard_sell(phi, problem, config, cache) -> Executor:
    """2-D mesh-partitioned SpMVs over per-cell SELL tiles: each device's
    (voxel-range x fiber-range) cell is a blocked-ELL slot array feeding the
    Pallas SELL kernels under shard_map (DESIGN.md §9)."""
    return _make_shard_executor(phi, problem, config, cache, "sell")
