"""Model composition: init / train-loss / prefill / decode for all families.

Layers are stacked and iterated with `jax.lax.scan` (+ optional remat), so
HLO size and compile time are O(1) in depth — a hard requirement for the
88-layer / 61-layer dry-runs.  Heterogeneous structures avoid `lax.cond`
(which double-counts FLOPs in cost analysis) by construction:

  * MoE `first_k_dense` prefix layers are unrolled before the scanned MoE
    stack;
  * the Zamba2 hybrid is scanned as "super-layers" — `attn_every` Mamba2
    layers followed by one application of the shared attention+MLP block —
    with the remainder layers unrolled at the tail.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed import hints
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE

Array = jax.Array
Params = Dict[str, Any]

AUX_LOSS_WEIGHT = 0.01


# ----------------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------------

def attn_spec(cfg: ArchConfig) -> L.AttnSpec:
    rope = cfg.rope if cfg.rope in ("rope", "mrope") else "none"
    return L.AttnSpec(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, qkv_bias=cfg.qkv_bias, rope=rope,
        rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections)


def _init_attn_block(key, cfg: ArchConfig, *, moe_layer: bool) -> Params:
    k1, k2 = jax.random.split(key)
    dt = cfg.jnp_dtype
    p = {
        "ln1": L.init_norm(cfg.norm, cfg.d_model, dt),
        "attn": L.init_attention(k1, attn_spec(cfg), dt),
        "ln2": L.init_norm(cfg.norm, cfg.d_model, dt),
    }
    if moe_layer:
        p["moe"] = MOE.init_moe(k2, cfg.d_model, cfg.moe_d_ff, cfg.n_experts,
                                cfg.n_shared_experts, dt)
    else:
        p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dt)
    return p


def _init_mamba_block(key, cfg: ArchConfig) -> Params:
    return {
        "ln1": L.init_norm(cfg.norm, cfg.d_model, cfg.jnp_dtype),
        "mamba": M2.init_mamba2(
            key, cfg.d_model, d_state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
            d_conv=cfg.ssm_conv, n_groups=cfg.ssm_groups,
            dtype=cfg.jnp_dtype),
    }


def init_params(cfg: ArchConfig, key: Array) -> Params:
    dt = cfg.jnp_dtype
    keys = jax.random.split(key, 8)
    p: Params = {"final_norm": L.init_norm(cfg.norm, cfg.d_model, dt)}

    # embeddings / heads
    if cfg.family == "audio":
        p["heads"] = (jax.random.normal(
            keys[0], (cfg.n_codebooks, cfg.d_model, cfg.vocab_size),
            jnp.float32) * cfg.d_model ** -0.5).astype(dt)
    else:
        p["embed"] = (jax.random.normal(
            keys[0], (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02
        ).astype(dt)
        if not cfg.tie_embeddings:
            p["lm_head"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab_size, dt)
    if cfg.rope == "learned":
        p["pos_embed"] = (jax.random.normal(
            keys[2], (cfg.max_seq_len, cfg.d_model), jnp.float32) * 0.02
        ).astype(dt)

    # layer stacks
    if cfg.family in ("dense", "audio", "vlm"):
        lkeys = jax.random.split(keys[3], cfg.n_layers)
        p["layers"] = jax.vmap(
            lambda k: _init_attn_block(k, cfg, moe_layer=False))(lkeys)
    elif cfg.family == "moe":
        kd = cfg.first_k_dense
        if kd:
            pk = jax.random.split(keys[4], kd)
            p["prefix"] = [_init_attn_block(pk[i], cfg, moe_layer=False)
                           for i in range(kd)]
        lkeys = jax.random.split(keys[3], cfg.n_layers - kd)
        p["layers"] = jax.vmap(
            lambda k: _init_attn_block(k, cfg, moe_layer=True))(lkeys)
    elif cfg.family == "ssm":
        lkeys = jax.random.split(keys[3], cfg.n_layers)
        p["layers"] = jax.vmap(lambda k: _init_mamba_block(k, cfg))(lkeys)
    elif cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.attn_every
        tail = cfg.n_layers % cfg.attn_every
        lkeys = jax.random.split(keys[3], n_super * cfg.attn_every)
        stacked = jax.vmap(lambda k: _init_mamba_block(k, cfg))(lkeys)
        # (n_super, attn_every, ...) grouping for the super-layer scan
        p["layers"] = jax.tree.map(
            lambda a: a.reshape((n_super, cfg.attn_every) + a.shape[1:]),
            stacked)
        if tail:
            tk = jax.random.split(keys[5], tail)
            p["tail"] = jax.vmap(lambda k: _init_mamba_block(k, cfg))(tk)
        p["shared"] = _init_attn_block(keys[6], cfg, moe_layer=False)
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return p


# ----------------------------------------------------------------------------
# Embedding & logits
# ----------------------------------------------------------------------------

def embed_inputs(cfg: ArchConfig, p: Params, batch: Dict[str, Array],
                 *, offset: Array | int = 0) -> Tuple[Array, Array]:
    """Returns (x (B,S,d), positions).  positions is (B,S) or (3,B,S)."""
    if cfg.family == "audio":
        x = batch["frame_embeds"]
        B, S, _ = x.shape
        positions = offset + jnp.arange(S)[None, :] + jnp.zeros((B, 1), jnp.int32)
    elif cfg.family == "vlm":
        tok = p["embed"][batch["tokens"]]
        if "image_embeds" in batch:
            x = jnp.concatenate(
                [batch["image_embeds"].astype(tok.dtype), tok], axis=1)
        else:
            x = tok
        return x, batch["positions"]
    else:
        x = p["embed"][batch["tokens"]]
        B, S, _ = x.shape
        positions = offset + jnp.arange(S)[None, :] + jnp.zeros((B, 1), jnp.int32)
    if cfg.rope == "sinusoidal":
        x = x + L.sinusoidal_embedding(positions, cfg.d_model).astype(x.dtype)
    elif cfg.rope == "learned":
        x = x + p["pos_embed"][positions]
    return x, positions


def logits_fn(cfg: ArchConfig, p: Params, x: Array) -> Array:
    x = L.apply_norm(cfg.norm, p["final_norm"], x)
    if cfg.family == "audio":
        return jnp.einsum("bsd,cdv->bscv", x, p["heads"])
    head = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    return x @ head


# ----------------------------------------------------------------------------
# Blocks (train / prefill / decode)
# ----------------------------------------------------------------------------

def _attn_block_train(cfg: ArchConfig, lp: Params, x: Array, positions: Array,
                      *, moe_layer: bool) -> Tuple[Array, Array]:
    spec = attn_spec(cfg)
    x = hints.gathered(x)       # SP: all-gather(seq) once per layer
    h = L.apply_norm(cfg.norm, lp["ln1"], x)
    x = x + L.attention_train(lp["attn"], spec, h, positions)
    h = L.apply_norm(cfg.norm, lp["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if moe_layer:
        out, aux = MOE.moe_ffn(lp["moe"], h, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor)
    else:
        out = L.mlp(lp["mlp"], h)
    return hints.residual(x + out), aux


def _attn_block_prefill(cfg, lp, x, positions, *, moe_layer):
    spec = attn_spec(cfg)
    x = hints.gathered(x)
    h = L.apply_norm(cfg.norm, lp["ln1"], x)
    out, kv = L.attention_prefill(lp["attn"], spec, h, positions)
    x = x + out
    h = L.apply_norm(cfg.norm, lp["ln2"], x)
    if moe_layer:
        ff, _ = MOE.moe_ffn(lp["moe"], h, top_k=cfg.top_k,
                            capacity_factor=cfg.capacity_factor)
    else:
        ff = L.mlp(lp["mlp"], h)
    return x + ff, kv


def _attn_block_decode(cfg, lp, x, positions, kv, cache_index, *, moe_layer):
    spec = attn_spec(cfg)
    h = L.apply_norm(cfg.norm, lp["ln1"], x)
    out, kv_new = L.attention_decode(lp["attn"], spec, h, positions, kv,
                                     cache_index)
    x = x + out
    h = L.apply_norm(cfg.norm, lp["ln2"], x)
    if moe_layer:
        ff, _ = MOE.moe_ffn(lp["moe"], h, top_k=cfg.top_k,
                            capacity_factor=cfg.capacity_factor)
    else:
        ff = L.mlp(lp["mlp"], h)
    return x + ff, kv_new


def _mamba_kwargs(cfg: ArchConfig) -> Dict[str, Any]:
    return dict(d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
                expand=cfg.ssm_expand, n_groups=cfg.ssm_groups)


# ----------------------------------------------------------------------------
# Forward passes
# ----------------------------------------------------------------------------

def forward_train(cfg: ArchConfig, p: Params, batch: Dict[str, Array]
                  ) -> Tuple[Array, Array]:
    """Returns (logits, aux_loss)."""
    x, positions = embed_inputs(cfg, p, batch)

    if cfg.family in ("dense", "audio", "vlm", "moe"):
        if cfg.family == "moe" and cfg.first_k_dense:
            for lp in p["prefix"]:
                x, _ = _attn_block_train(cfg, lp, x, positions, moe_layer=False)
        moe_layer = cfg.family == "moe"

        def body(carry, lp):
            x, aux = carry
            x = hints.residual(x)          # sequence-parallel saved residual
            x, a = _attn_block_train(cfg, lp, x, positions,
                                     moe_layer=moe_layer)
            return (x, aux + a), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                   p["layers"])
    elif cfg.family == "ssm":
        def body(x, lp):
            x = hints.residual(x)
            x = hints.gathered(x)
            h = L.apply_norm(cfg.norm, lp["ln1"], x)
            return x + M2.mamba2_forward(lp["mamba"], h, **_mamba_kwargs(cfg)), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, p["layers"])
        aux = jnp.zeros((), jnp.float32)
    elif cfg.family == "hybrid":
        x = _hybrid_train(cfg, p, x, positions)
        aux = jnp.zeros((), jnp.float32)
    else:
        raise ValueError(cfg.family)
    return logits_fn(cfg, p, x), aux


def _hybrid_train(cfg: ArchConfig, p: Params, x: Array, positions: Array
                  ) -> Array:
    def mamba_once(x, lp):
        x = hints.gathered(x)
        h = L.apply_norm(cfg.norm, lp["ln1"], x)
        return x + M2.mamba2_forward(lp["mamba"], h, **_mamba_kwargs(cfg)), None

    def super_body(x, group_lp):
        x = hints.residual(x)
        x, _ = jax.lax.scan(mamba_once, x, group_lp)
        x, _ = _attn_block_train(cfg, p["shared"], x, positions,
                                 moe_layer=False)
        return x, None

    body_fn = jax.checkpoint(super_body) if cfg.remat else super_body
    x, _ = jax.lax.scan(body_fn, x, p["layers"])
    if "tail" in p:
        x, _ = jax.lax.scan(mamba_once, x, p["tail"])
    return x


def loss_fn(cfg: ArchConfig, p: Params, batch: Dict[str, Array]
            ) -> Tuple[Array, Dict[str, Array]]:
    logits, aux = forward_train(cfg, p, batch)
    if cfg.family == "audio":
        labels = batch["codes"]                      # (B, S, C)
        lg = logits.astype(jnp.float32)              # (B, S, C, V)
        ls = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(ls, labels[..., None], axis=-1)[..., 0]
        loss = nll.mean()
    else:
        labels = batch["labels"]
        lg = logits.astype(jnp.float32)
        ls = jax.nn.log_softmax(lg, axis=-1)
        mask = labels >= 0
        safe = jnp.maximum(labels, 0)
        nll = -jnp.take_along_axis(ls, safe[..., None], axis=-1)[..., 0]
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    total = loss + AUX_LOSS_WEIGHT * aux
    return total, {"loss": loss, "aux": aux}


def prefill(cfg: ArchConfig, p: Params, batch: Dict[str, Array]
            ) -> Tuple[Array, Dict[str, Array]]:
    """Returns (last-position logits, cache dict)."""
    x, positions = embed_inputs(cfg, p, batch)
    cache: Dict[str, Array] = {}
    if cfg.family in ("dense", "audio", "vlm", "moe"):
        prefix_kv = []
        if cfg.family == "moe" and cfg.first_k_dense:
            for lp in p["prefix"]:
                x, kv = _attn_block_prefill(cfg, lp, x, positions,
                                            moe_layer=False)
                prefix_kv.append(kv)
        moe_layer = cfg.family == "moe"

        def body(x, lp):
            x = hints.residual(x)
            x, kv = _attn_block_prefill(cfg, lp, x, positions,
                                        moe_layer=moe_layer)
            return x, kv

        x, kvs = jax.lax.scan(body, x, p["layers"])
        k, v = kvs
        if prefix_kv:
            k = jnp.concatenate([jnp.stack([kv[0] for kv in prefix_kv]), k])
            v = jnp.concatenate([jnp.stack([kv[1] for kv in prefix_kv]), v])
        cache = {"k": k, "v": v}
    elif cfg.family == "ssm":
        def body(x, lp):
            x = hints.residual(x)
            x = hints.gathered(x)
            h = L.apply_norm(cfg.norm, lp["ln1"], x)
            y, ssm, conv = M2.mamba2_prefill(lp["mamba"], h,
                                             **_mamba_kwargs(cfg))
            return x + y, (ssm, conv)

        x, (ssm, conv) = jax.lax.scan(body, x, p["layers"])
        cache = {"ssm": ssm, "conv": conv}
    elif cfg.family == "hybrid":
        x, cache = _hybrid_prefill(cfg, p, x, positions)
    logits = logits_fn(cfg, p, x[:, -1:, :])
    return logits, cache


def _hybrid_prefill(cfg, p, x, positions):
    def mamba_once(x, lp):
        x = hints.gathered(x)
        h = L.apply_norm(cfg.norm, lp["ln1"], x)
        y, ssm, conv = M2.mamba2_prefill(lp["mamba"], h, **_mamba_kwargs(cfg))
        return x + y, (ssm, conv)

    def super_body(x, group_lp):
        x, states = jax.lax.scan(mamba_once, x, group_lp)
        x, kv = _attn_block_prefill(cfg, p["shared"], x, positions,
                                    moe_layer=False)
        return x, (states, kv)

    x, (states, kvs) = jax.lax.scan(super_body, x, p["layers"])
    ssm = states[0].reshape((-1,) + states[0].shape[2:])
    conv = states[1].reshape((-1,) + states[1].shape[2:])
    if "tail" in p:
        x, (ssm_t, conv_t) = jax.lax.scan(mamba_once, x, p["tail"])
        ssm = jnp.concatenate([ssm, ssm_t])
        conv = jnp.concatenate([conv, conv_t])
    return x, {"ssm": ssm, "conv": conv, "k": kvs[0], "v": kvs[1]}


def decode_step(cfg: ArchConfig, p: Params, batch: Dict[str, Array]
                ) -> Tuple[Array, Dict[str, Array]]:
    """One-token serve step.  batch: tokens/frame_embeds (B,1), cache,
    cache_index.  Returns (logits (B,1,V...), updated cache)."""
    cache = batch["cache"]
    idx = batch["cache_index"]
    x, positions = embed_inputs(cfg, p, batch, offset=idx)
    if cfg.family in ("dense", "audio", "vlm", "moe"):
        moe_layer = cfg.family == "moe"
        kd = cfg.first_k_dense if cfg.family == "moe" else 0
        k, v = cache["k"], cache["v"]
        new_k, new_v = k, v
        for i in range(kd):
            kv_i = (k[i], v[i])
            x, kv_n = _attn_block_decode(cfg, p["prefix"][i], x, positions,
                                         kv_i, idx, moe_layer=False)
            new_k = new_k.at[i].set(kv_n[0])
            new_v = new_v.at[i].set(kv_n[1])

        def body(x, inp):
            lp, kc, vc = inp
            x, kv_n = _attn_block_decode(cfg, lp, x, positions, (kc, vc),
                                         idx, moe_layer=moe_layer)
            return x, kv_n

        x, (ks, vs) = jax.lax.scan(body, x, (p["layers"], k[kd:], v[kd:]))
        new_k = new_k.at[kd:].set(ks) if kd else ks
        new_v = new_v.at[kd:].set(vs) if kd else vs
        new_cache = {"k": new_k, "v": new_v}
    elif cfg.family == "ssm":
        def body(x, inp):
            lp, ssm, conv = inp
            h = L.apply_norm(cfg.norm, lp["ln1"], x)
            y, ssm2, conv2 = M2.mamba2_decode(lp["mamba"], h, ssm, conv,
                                              **_mamba_kwargs(cfg))
            return x + y, (ssm2, conv2)

        x, (ssm, conv) = jax.lax.scan(body, x,
                                      (p["layers"], cache["ssm"], cache["conv"]))
        new_cache = {"ssm": ssm, "conv": conv}
    elif cfg.family == "hybrid":
        x, new_cache = _hybrid_decode(cfg, p, x, positions, cache, idx)
    else:
        raise ValueError(cfg.family)
    new_cache["index"] = idx + 1
    return logits_fn(cfg, p, x), new_cache


def _hybrid_decode(cfg, p, x, positions, cache, idx):
    n_super = cfg.n_layers // cfg.attn_every
    per = cfg.attn_every
    ssm = cache["ssm"]
    conv = cache["conv"]
    ssm_g = ssm[: n_super * per].reshape((n_super, per) + ssm.shape[1:])
    conv_g = conv[: n_super * per].reshape((n_super, per) + conv.shape[1:])

    def mamba_once(x, inp):
        lp, s, c = inp
        h = L.apply_norm(cfg.norm, lp["ln1"], x)
        y, s2, c2 = M2.mamba2_decode(lp["mamba"], h, s, c, **_mamba_kwargs(cfg))
        return x + y, (s2, c2)

    def super_body(x, inp):
        group_lp, s_g, c_g, kc, vc = inp
        x, (s2, c2) = jax.lax.scan(mamba_once, x, (group_lp, s_g, c_g))
        x, kv_n = _attn_block_decode(cfg, p["shared"], x, positions,
                                     (kc, vc), idx, moe_layer=False)
        return x, (s2, c2, kv_n[0], kv_n[1])

    x, (s2, c2, ks, vs) = jax.lax.scan(
        super_body, x, (p["layers"], ssm_g, conv_g, cache["k"], cache["v"]))
    new_ssm = s2.reshape((-1,) + s2.shape[2:])
    new_conv = c2.reshape((-1,) + c2.shape[2:])
    if "tail" in p:
        x, (st, ct) = jax.lax.scan(
            mamba_once, x,
            (p["tail"], ssm[n_super * per:], conv[n_super * per:]))
        new_ssm = jnp.concatenate([new_ssm, st])
        new_conv = jnp.concatenate([new_conv, ct])
    return x, {"ssm": new_ssm, "conv": new_conv, "k": ks, "v": vs}
