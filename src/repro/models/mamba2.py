"""Mamba2 (SSD — state-space duality) layer: chunked train/prefill + decode.

Follows the minimal SSD formulation (Dao & Gu 2024): within-chunk quadratic
term + inter-chunk recurrent state passing.  The chunked scan keeps HLO size
O(1) in sequence length and the recurrence O(S/Q) sequential steps; decode is
the O(1) state update, which is what makes `long_500k` feasible for the
SSM/hybrid architectures.

Projections are stored *split* (z, x, B, C, dt) rather than as one fused
in_proj, and the depthwise causal conv is likewise split per stream: the
fused layout would force GSPMD to reshard at every `jnp.split` along a
`model`-sharded feature axis, while the split layout shards each stream
cleanly (TP on heads/channels).  Mathematically identical to the fused form.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, rmsnorm

Array = jax.Array
Params = Dict[str, Any]


def init_mamba2(key, d_model: int, *, d_state: int, head_dim: int = 64,
                expand: int = 2, d_conv: int = 4, n_groups: int = 1,
                dtype=jnp.bfloat16) -> Params:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    gn = n_groups * d_state
    ks = jax.random.split(key, 9)
    conv = lambda k, c: (jax.random.normal(k, (d_conv, c), jnp.float32) * 0.1
                         ).astype(dtype)
    return {
        "wz": dense_init(ks[0], d_model, d_inner, dtype),
        "wx": dense_init(ks[1], d_model, d_inner, dtype),
        "wb": dense_init(ks[2], d_model, gn, dtype),
        "wc": dense_init(ks[3], d_model, gn, dtype),
        "wdt": dense_init(ks[4], d_model, n_heads, dtype),
        "conv_wx": conv(ks[5], d_inner),
        "conv_bx": jnp.zeros((d_inner,), dtype),
        "conv_wb": conv(ks[6], gn),
        "conv_bb": jnp.zeros((gn,), dtype),
        "conv_wc": conv(ks[7], gn),
        "conv_bc": jnp.zeros((gn,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[8], d_inner, d_model, dtype),
    }


def _causal_conv(w: Array, bias: Array, x: Array) -> Array:
    """Depthwise causal conv + SiLU over the sequence dim.  x: (B, S, C)."""
    d_conv = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + x.shape[1], :] * w[i] for i in range(d_conv))
    return jax.nn.silu(out + bias)


def ssd_chunked(x: Array, dt: Array, a: Array, b: Array, c: Array,
                chunk: int, h0: Array | None = None
                ) -> Tuple[Array, Array]:
    """Chunked SSD scan.

    x: (B, S, H, P)   dt: (B, S, H)   a: (H,) negative decay rates
    b, c: (B, S, G, N) with G groups broadcast over heads.
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    assert S % chunk == 0
    nch = S // chunk
    rep = H // G

    # heads split as (g, r): avoids materializing head-repeated B/C tensors
    xr = x.reshape(B, nch, chunk, G, rep, P)
    dtr = dt.reshape(B, nch, chunk, G, rep)
    bg = b.reshape(B, nch, chunk, G, N)
    cg = c.reshape(B, nch, chunk, G, N)

    da = dtr * a.reshape(G, rep)[None, None, None]        # (B,c,Q,G,r) negative
    da_cs = jnp.cumsum(da, axis=2)
    # within-chunk decay L[q, s] = exp(sum_{s<t<=q} da_t), lower-triangular.
    # seg must be clamped BEFORE exp: in the masked (s > q) region it is
    # large-positive, and although where() discards exp(inf) in the forward,
    # the VJP computes 0 * inf = NaN.
    seg = da_cs[:, :, :, None] - da_cs[:, :, None, :]     # (B,c,Q,Q,G,r)
    qi = jnp.arange(chunk)
    tri = (qi[:, None] >= qi[None, :])[None, None, :, :, None, None]
    L = jnp.where(tri, jnp.exp(jnp.where(tri, seg, 0.0)), 0.0)

    xdt = xr * dtr[..., None]                             # (B,c,Q,G,r,P)
    cb = jnp.einsum("bcqgn,bcsgn->bcqsg", cg, bg)         # shared across r
    y_diag = jnp.einsum("bcqsg,bcqsgr,bcsgrp->bcqgrp",
                        cb, L.astype(cg.dtype), xdt)

    # chunk-final states
    decay_to_end = jnp.exp(da_cs[:, :, -1:] - da_cs)      # (B,c,Q,G,r)
    states = jnp.einsum("bcqgn,bcqgr,bcqgrp->bcgrpn",
                        bg, decay_to_end.astype(bg.dtype), xdt)
    chunk_decay = jnp.exp(da_cs[:, :, -1])                # (B,c,G,r)

    def scan_body(h, inp):
        st, dec = inp
        h_new = h * dec[..., None, None].astype(h.dtype) + st.astype(h.dtype)
        return h_new, h.astype(st.dtype)

    h_init = (jnp.zeros((B, G, rep, P, N), jnp.float32) if h0 is None
              else h0.reshape(B, G, rep, P, N).astype(jnp.float32))
    h_last, h_prevs = jax.lax.scan(
        scan_body, h_init,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_prevs = h_prevs.swapaxes(0, 1)                      # (B,c,G,r,P,N)

    decay_from_start = jnp.exp(da_cs)                     # (B,c,Q,G,r)
    y_off = jnp.einsum("bcqgn,bcgrpn,bcqgr->bcqgrp",
                       cg, h_prevs.astype(cg.dtype),
                       decay_from_start.astype(cg.dtype))
    y = (y_diag + y_off).reshape(B, S, H, P)
    return y, h_last.reshape(B, H, P, N)


def mamba2_prefill(p: Params, x: Array, *, d_state: int, head_dim: int = 64,
                   expand: int = 2, n_groups: int = 1, chunk: int = 128):
    """Full-sequence forward.  x: (B, S, d_model).

    Returns (y, ssm_state (B,H,P,N), conv_state (B, d_conv-1, C_x+C_b+C_c)).
    """
    B, S, d_model = x.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    z = x @ p["wz"]
    xs_raw = x @ p["wx"]
    b_raw = x @ p["wb"]
    c_raw = x @ p["wc"]
    dt = x @ p["wdt"]
    xs = _causal_conv(p["conv_wx"], p["conv_bx"], xs_raw)
    b = _causal_conv(p["conv_wb"], p["conv_bb"], b_raw)
    c = _causal_conv(p["conv_wc"], p["conv_bc"], c_raw)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xs.reshape(B, S, n_heads, head_dim)
    bh = b.reshape(B, S, n_groups, d_state)
    ch = c.reshape(B, S, n_groups, d_state)
    pad = (-S) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bh = jnp.pad(bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ch = jnp.pad(ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, h_last = ssd_chunked(xh, dt, a, bh, ch, min(chunk, xh.shape[1]))
    y = y[:, :S]
    y = y + xs.reshape(B, S, n_heads, head_dim) \
        * p["d_skip"][None, None, :, None].astype(y.dtype)
    y = _gated_norm(p, y.reshape(B, S, d_inner), z).astype(x.dtype)
    d_conv = p["conv_wx"].shape[0]
    raw = jnp.concatenate([xs_raw, b_raw, c_raw], axis=-1)
    if S >= d_conv - 1:
        conv_state = raw[:, S - (d_conv - 1):, :]
    else:
        conv_state = jnp.pad(raw, ((0, 0), (d_conv - 1 - S, 0), (0, 0)))
    return y @ p["out_proj"], h_last, conv_state


def mamba2_forward(p: Params, x: Array, **kw) -> Array:
    return mamba2_prefill(p, x, **kw)[0]


def mamba2_decode(p: Params, x: Array, ssm_state: Array, conv_state: Array,
                  *, d_state: int, head_dim: int = 64, expand: int = 2,
                  n_groups: int = 1):
    """Single-token decode.  x: (B, 1, d_model).

    Returns (y (B,1,d_model), new_ssm_state, new_conv_state).
    """
    B, S1, d_model = x.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    gn = n_groups * d_state
    z = x @ p["wz"]
    xs_raw = x @ p["wx"]
    b_raw = x @ p["wb"]
    c_raw = x @ p["wc"]
    dt = x @ p["wdt"]
    raw = jnp.concatenate([xs_raw, b_raw, c_raw], axis=-1)
    window = jnp.concatenate([conv_state, raw], axis=1)    # (B, d_conv, C)
    new_conv_state = window[:, 1:, :]
    wx, wb_, wc_ = window[..., :d_inner], window[..., d_inner:d_inner + gn], \
        window[..., d_inner + gn:]
    conv1 = lambda w, bias, win: jax.nn.silu(
        jnp.einsum("bkc,kc->bc", win, w) + bias)
    xs = conv1(p["conv_wx"], p["conv_bx"], wx)
    b = conv1(p["conv_wb"], p["conv_bb"], wb_)
    c = conv1(p["conv_wc"], p["conv_bc"], wc_)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(p["a_log"])
    xh = xs.reshape(B, n_heads, head_dim)
    rep = n_heads // n_groups
    bh = jnp.repeat(b.reshape(B, n_groups, d_state), rep, axis=1)
    ch = jnp.repeat(c.reshape(B, n_groups, d_state), rep, axis=1)
    decay = jnp.exp(dt * a[None, :])                       # (B,H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt.astype(jnp.float32),
                     xh.astype(jnp.float32), bh.astype(jnp.float32))
    h_new = (ssm_state * decay[..., None, None] + upd).astype(ssm_state.dtype)
    y = jnp.einsum("bhpn,bhn->bhp", h_new.astype(jnp.float32),
                   ch.astype(jnp.float32))
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = _gated_norm(p, y, z).astype(x.dtype)
    return y @ p["out_proj"], h_new, new_conv_state


def _gated_norm(p: Params, y: Array, z: Array) -> Array:
    """RMSNorm(y * silu(z)) — Mamba2's gated output norm."""
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return rmsnorm({"scale": p["norm_scale"]}, y)
