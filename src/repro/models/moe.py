"""Mixture-of-Experts layer with sort-based (restructured) dispatch.

This is the paper's technique promoted to a first-class framework feature:
the router produces an *indirection vector* (token -> expert), and instead of
scattering with atomics we **restructure** — sort token assignments by expert
id — so each expert's tokens form a contiguous sub-vector, execute a grouped
matmul over segment boundaries (the BLAS-call analogue; `kernels/moe_gmm.py`
is the Pallas executor for the TPU hot path), and un-sort the results.

For distribution, experts shard over the `model` mesh axis (EP) and the
dispatch becomes an all-to-all along that axis — the computation-partitioning
choice of §4.1.3 at mesh granularity.

The dense-capacity formulation below (fixed capacity per expert, sort +
static slicing) is jit/GSPMD-friendly: every shape is static, tokens over
capacity are dropped (standard Switch-style), and dropped slots carry zero
weight.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Array = jax.Array
Params = Dict[str, Any]


def init_moe(key, d_model: int, d_ff: int, n_experts: int, n_shared: int,
             dtype) -> Params:
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "wi_gate": _expert_init(ks[1], n_experts, d_model, d_ff, dtype),
        "wi_up": _expert_init(ks[2], n_experts, d_model, d_ff, dtype),
        "wo": _expert_init(ks[3], n_experts, d_ff, d_model, dtype),
    }
    if n_shared:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], d_model, d_ff * n_shared, "swiglu", dtype)
    return p


def _expert_init(key, e: int, d_in: int, d_out: int, dtype) -> Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (e, d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def moe_ffn(p: Params, x: Array, *, top_k: int, capacity_factor: float = 1.25,
            ) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (out, aux_loss).

    GShard-style *grouped* dispatch: tokens are split into G dispatch groups
    (G = |batch mesh axes|, 1 off-mesh), each group restructures (sorts by
    expert) **locally**, and only the (group, expert)-bucketed activations
    cross the mesh — an all-to-all along `model` — instead of a global sort
    shuffling every token across all chips.  Math is identical for G=1 and
    differs only in per-group (vs global) capacity truncation otherwise.
    """
    from repro.distributed import hints
    B, S, d = x.shape
    n_tokens = B * S
    n_experts = p["router"].shape[1]
    groups = hints.axis_size(hints.batch_axes()) if hints.active() else 1
    if n_tokens % groups:
        groups = 1
    tg = n_tokens // groups
    xg = x.reshape(groups, tg, d)
    xg = hints.constrain(xg, hints.batch_axes(), None, None)

    # per-group capacity, multiple of 8 for clean layouts
    capacity = int(capacity_factor * tg * top_k / n_experts)
    capacity = max(8, -(-capacity // 8) * 8)

    out_g, aux = _dispatch_group(p, xg, top_k, capacity, n_experts)
    out = out_g.reshape(n_tokens, d)

    if "shared" in p:
        from repro.models.layers import mlp
        out = out + mlp(p["shared"], xg.reshape(n_tokens, d))
    return out.reshape(B, S, d), aux


def _dispatch_group(p: Params, xg: Array, top_k: int, capacity: int,
                    n_experts: int) -> Tuple[Array, Array]:
    """Vectorized over groups.  xg: (G, T, d)."""
    from repro.distributed import hints
    G, T, d = xg.shape
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)      # (G, T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style), averaged over groups
    me = probs.mean(axis=1)                                  # (G, E)
    onehot_counts = jnp.sum(
        jax.nn.one_hot(expert_ids, n_experts, dtype=jnp.float32),
        axis=(1, 2)) / (T * top_k)                           # (G, E)
    aux = n_experts * jnp.mean(jnp.sum(me * onehot_counts, axis=-1))

    # ---- local restructuring: sort (token, k) slots by expert id ----
    # Scatter-free formulation: both the dispatch (slot -> token) and the
    # combine (token -> slot) are *gathers* through the sort permutation and
    # its inverse.  Scatter-adds would (a) serialize on TPU and (b) promote
    # bf16 buffers to f32 on the CPU validation backend; gathers do neither.
    tk = T * top_k
    flat_expert = expert_ids.reshape(G, tk)
    flat_gate = gate_vals.reshape(G, tk).astype(xg.dtype)
    order = jnp.argsort(flat_expert, axis=1)                 # restructuring
    sorted_expert = jnp.take_along_axis(flat_expert, order, axis=1)
    inv_order = jnp.argsort(order, axis=1)                   # slot -> rank

    # segment starts per expert + rank of each slot within its segment
    first = jax.vmap(lambda se: jnp.searchsorted(
        se, jnp.arange(n_experts), side="left"))(sorted_expert)   # (G, E)
    cap_pos = inv_order - jnp.take_along_axis(first, flat_expert, axis=1)
    keep = cap_pos < capacity                                # (G, Tk)
    slot_id = jnp.clip(flat_expert * capacity + cap_pos, 0,
                       n_experts * capacity - 1)

    # dispatch: which token fills expert slot (e, c)?  pure gather
    idx_sorted = first[:, :, None] + jnp.arange(capacity)[None, None, :]
    idx_c = jnp.clip(idx_sorted, 0, tk - 1).reshape(G, -1)   # (G, E*cap)
    e_at = jnp.take_along_axis(sorted_expert, idx_c, axis=1)
    valid = ((idx_sorted.reshape(G, -1) < tk)
             & (e_at == jnp.repeat(jnp.arange(n_experts), capacity)[None]))
    tok_at = jnp.take_along_axis(order, idx_c, axis=1) // top_k
    xe = jnp.where(valid[..., None],
                   jnp.take_along_axis(xg, tok_at[..., None], axis=1), 0)
    xe = xe.reshape(G, n_experts, capacity, d)
    # EP: experts over `model`, groups over the batch axes (all-to-all)
    xe = hints.constrain(xe, hints.batch_axes(), "model", None, None)

    # expert FFN over contiguous segments (BLAS-call analogue; the Pallas
    # moe_gmm kernel executes this on the TPU target)
    h = jnp.einsum("gecd,edf->gecf", xe, p["wi_gate"])
    u = jnp.einsum("gecd,edf->gecf", xe, p["wi_up"])
    ye = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * u, p["wo"])
    ye = hints.constrain(ye, hints.batch_axes(), "model", None, None)

    # combine: per-k gather + accumulate — never materializes the full
    # (T*k, d) duplicated-token buffer (k-fold activation blowup)
    ye_flat = ye.reshape(G, n_experts * capacity, d)
    slot_tk = slot_id.reshape(G, T, top_k)
    keep_tk = keep.reshape(G, T, top_k)
    gate_tk = flat_gate.reshape(G, T, top_k)
    out = jnp.zeros((G, T, d), xg.dtype)
    for j in range(top_k):
        rows = jnp.take_along_axis(ye_flat, slot_tk[:, :, j][..., None],
                                   axis=1)
        out = out + jnp.where(keep_tk[:, :, j][..., None],
                              rows * gate_tk[:, :, j][..., None], 0)
    return out, aux
