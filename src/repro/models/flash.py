"""Flash attention (causal, GQA) in pure JAX with a custom VJP.

Why custom_vjp: differentiating a lax.scan saves every per-step carry — for
the chunked-attention scan that is O(S * n_pairs) and was measured at ~50 GB
/device on the 4k train dry-run.  Defining the backward by hand (standard
flash-attention recompute) keeps residuals at O(S) — q, k, v, out, lse — and
recomputes chunk-pair probabilities transiently.

The pair-list scan walks only lower-triangular (i, j<=i) chunk pairs, so HLO
FLOPs equal the true causal cost (no masked-out waste) — this is what the
roofline's useful-flops ratio sees.  On the TPU target this maps onto a fused
kernel (splash-style); this formulation defines the memory-feasible lowering
and the exact reference semantics.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _pairs(nq: int) -> np.ndarray:
    return np.asarray([(i, j) for i in range(nq) for j in range(i + 1)],
                      np.int32)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q: Array, k: Array, v: Array, chunk: int) -> Array:
    """q: (B,S,KV,G,hd), k/v: (B,S,KV,hd) -> (B,S,KV,G,hd).  Causal."""
    out, _ = _fwd(q, k, v, chunk)
    return out


def _fwd(q, k, v, chunk: int):
    B, S, KV, G, hd = q.shape
    assert S % chunk == 0
    n = S // chunk
    scale = 1.0 / np.sqrt(hd)
    qc = q.reshape(B, n, chunk, KV, G, hd)
    kc = k.reshape(B, n, chunk, KV, hd)
    vc = v.reshape(B, n, chunk, KV, hd)

    acc0 = jnp.zeros((n, B, chunk, KV, G, hd), jnp.float32)
    m0 = jnp.full((n, B, chunk, KV, G), -1e30, jnp.float32)
    l0 = jnp.zeros((n, B, chunk, KV, G), jnp.float32)
    mask = _diag_mask(chunk)

    def body(carry, pair):
        acc, m, l = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qc, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
        s = jnp.einsum("bqkgh,bskh->bqkgs", qi, kj).astype(jnp.float32) * scale
        s = jnp.where((i == j) & ~mask[None, :, None, None, :], -1e30, s)
        m_prev = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        l_prev = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        a_prev = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        a_new = a_prev * alpha[..., None] + jnp.einsum(
            "bqkgs,bskh->bqkgh", p.astype(v.dtype), vj).astype(jnp.float32)
        return ((jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 0),
                 jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0),
                 jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)), None)

    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.asarray(_pairs(n)))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).transpose(1, 0, 2, 3, 4, 5) \
        .reshape(B, S, KV, G, hd).astype(q.dtype)
    lse = (m + jnp.log(l_safe)).transpose(1, 0, 2, 3, 4) \
        .reshape(B, S, KV, G)
    return out, (q, k, v, out, lse)


def _diag_mask(chunk: int) -> Array:
    qi = jnp.arange(chunk)
    return qi[:, None] >= qi[None, :]          # (q, s) allowed


def _bwd(chunk: int, res, dout):
    q, k, v, out, lse = res
    B, S, KV, G, hd = q.shape
    n = S // chunk
    scale = 1.0 / np.sqrt(hd)
    qc = q.reshape(B, n, chunk, KV, G, hd)
    kc = k.reshape(B, n, chunk, KV, hd)
    vc = v.reshape(B, n, chunk, KV, hd)
    doc = dout.reshape(B, n, chunk, KV, G, hd)
    lsec = lse.reshape(B, n, chunk, KV, G)
    # D_i = rowsum(dout * out)
    dsum = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                   axis=-1).reshape(B, n, chunk, KV, G)
    mask = _diag_mask(chunk)

    dq0 = jnp.zeros((n, B, chunk, KV, G, hd), jnp.float32)
    dk0 = jnp.zeros((n, B, chunk, KV, hd), jnp.float32)
    dv0 = jnp.zeros((n, B, chunk, KV, hd), jnp.float32)

    def body(carry, pair):
        dq, dk, dv = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qc, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
        di = jax.lax.dynamic_index_in_dim(doc, i, 1, keepdims=False)
        lsei = jax.lax.dynamic_index_in_dim(lsec, i, 1, keepdims=False)
        dsi = jax.lax.dynamic_index_in_dim(dsum, i, 1, keepdims=False)
        s = jnp.einsum("bqkgh,bskh->bqkgs", qi, kj).astype(jnp.float32) * scale
        s = jnp.where((i == j) & ~mask[None, :, None, None, :], -1e30, s)
        p = jnp.exp(s - lsei[..., None])                     # (B,q,KV,G,s)
        dv_j = jnp.einsum("bqkgs,bqkgh->bskh", p, di.astype(jnp.float32))
        dp = jnp.einsum("bqkgh,bskh->bqkgs", di, vj).astype(jnp.float32)
        ds = p * (dp - dsi[..., None]) * scale
        dq_i = jnp.einsum("bqkgs,bskh->bqkgh", ds, kj)
        dk_j = jnp.einsum("bqkgs,bqkgh->bskh", ds, qi.astype(jnp.float32))
        dq = dq.at[i].add(dq_i)
        dk = dk.at[j].add(dk_j)
        dv = dv.at[j].add(dv_j)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0),
                                   jnp.asarray(_pairs(n)))
    dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, KV, G, hd).astype(q.dtype)
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, S, KV, hd).astype(k.dtype)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, S, KV, hd).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(lambda q, k, v, c: _fwd(q, k, v, c), _bwd)
