"""Core transformer layers — functional, explicit param pytrees (no flax).

Every init_* returns a nested dict of arrays; every apply function is pure.
Attention supports GQA/MQA, optional QKV bias (qwen1.5), RoPE / M-RoPE
(qwen2-vl) / sinusoidal (musicgen) / learned (granite) positions, a
blockwise (flash-style, triangular pair-list) path for long sequences, and a
KV-cache decode path.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
Params = Dict[str, Any]


# ----------------------------------------------------------------------------
# Initializers & norms
# ----------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["scale"]


def init_layernorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = jnp.square(xf - mu).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y.astype(x.dtype)) * p["scale"] + p["bias"]


def apply_norm(kind: str, p: Params, x: Array) -> Array:
    return rmsnorm(p, x) if kind == "rms" else layernorm(p, x)


def init_norm(kind: str, d: int, dtype) -> Params:
    return init_rmsnorm(d, dtype) if kind == "rms" else init_layernorm(d, dtype)


# ----------------------------------------------------------------------------
# Positional encodings
# ----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 1e4) -> Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions: Array, theta: float,
                sections: Tuple[int, int, int]) -> Array:
    """Multimodal RoPE (Qwen2-VL): positions (3, B, S) for (t, h, w);
    head_dim/2 frequency slots are split across the three sections."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                    # (hd/2,)
    # section assignment per frequency slot
    sec = np.zeros(hd // 2, np.int32)
    ofs = 0
    for i, s in enumerate(sections):
        sec[ofs: ofs + s] = i
        ofs += s
    sec_j = jnp.asarray(sec)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32).transpose(1, 2, 0),   # (B, S, 3)
        jnp.broadcast_to(sec_j[None, None, :],
                         positions.shape[1:] + (hd // 2,)), axis=-1)
    angles = pos * freqs                              # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions: Array, d_model: int) -> Array:
    half = d_model // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------------
# Attention (GQA / MQA), blockwise + decode paths
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope: str = "rope"           # rope | mrope | none
    rope_theta: float = 1e4
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)


def init_attention(key, spec: AttnSpec, dtype) -> Params:
    ks = jax.random.split(key, 4)
    H, KV, hd, d = spec.n_heads, spec.n_kv_heads, spec.head_dim, spec.d_model
    p = {
        "wq": dense_init(ks[0], d, H * hd, dtype),
        "wk": dense_init(ks[1], d, KV * hd, dtype),
        "wv": dense_init(ks[2], d, KV * hd, dtype),
        "wo": dense_init(ks[3], H * hd, d, dtype),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _project_qkv(p: Params, spec: AttnSpec, x: Array,
                 positions: Array) -> Tuple[Array, Array, Array]:
    B, S, _ = x.shape
    H, KV, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if spec.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if spec.rope == "rope":
        pos2d = positions if positions.ndim == 2 else positions[0]
        q = apply_rope(q, pos2d, spec.rope_theta)
        k = apply_rope(k, pos2d, spec.rope_theta)
    elif spec.rope == "mrope":
        q = apply_mrope(q, positions, spec.rope_theta, spec.mrope_sections)
        k = apply_mrope(k, positions, spec.rope_theta, spec.mrope_sections)
    return q, k, v


def dense_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    kv_offset: int = 0) -> Array:
    """Reference attention; fine for short S.  q: (B,Sq,H,hd) k/v: (B,Skv,KV,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) / np.sqrt(hd)
    if causal:
        qpos = kv_offset + jnp.arange(Sq)[:, None]
        kpos = jnp.arange(k.shape[1])[None, :]
        scores = jnp.where(qpos >= kpos, scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, H, hd)


def blockwise_attention(q: Array, k: Array, v: Array, *, q_chunk: int = 512,
                        kv_chunk: int = 512) -> Array:
    """Causal flash-style attention via a triangular (i, j<=i) pair-list scan.

    Computes only the lower-triangular chunk pairs, so HLO FLOPs match the
    causal roofline (no masked-out waste), at the cost of a sequential scan —
    on the TPU target this path is replaced by a fused kernel; here it defines
    the memory-feasible lowering for 32k+ sequences.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    assert S % q_chunk == 0 and S % kv_chunk == 0
    nq, nk = S // q_chunk, S // kv_chunk
    assert q_chunk == kv_chunk, "triangular pairing assumes equal chunks"
    qc = q.reshape(B, nq, q_chunk, KV, G, hd)
    kc = k.reshape(B, nk, kv_chunk, KV, hd)
    vc = v.reshape(B, nk, kv_chunk, KV, hd)

    pairs = np.asarray([(i, j) for i in range(nq) for j in range(i + 1)], np.int32)
    scale = 1.0 / np.sqrt(hd)

    acc0 = jnp.zeros((nq, B, q_chunk, KV, G, hd), jnp.float32)
    m0 = jnp.full((nq, B, q_chunk, KV, G), -1e30, jnp.float32)
    l0 = jnp.zeros((nq, B, q_chunk, KV, G), jnp.float32)

    def body(carry, pair):
        acc, m, l = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qc, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
        s = jnp.einsum("bqkgh,bskh->bqkgs", qi, kj).astype(jnp.float32) * scale
        diag = i == j
        qpos = jnp.arange(q_chunk)[:, None]
        kpos = jnp.arange(kv_chunk)[None, :]
        mask = jnp.where(diag, (qpos >= kpos), True)
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_prev = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        l_prev = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        a_prev = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        a_new = a_prev * alpha[..., None] + jnp.einsum(
            "bqkgs,bskh->bqkgh", p.astype(q.dtype), vj).astype(jnp.float32)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.asarray(pairs))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd)
    return out.astype(q.dtype)


def _self_attention(q: Array, k: Array, v: Array, *,
                    block_threshold: int = 1024) -> Array:
    """Causal self-attention; flash (custom-VJP chunked) beyond threshold.

    For the flash path KV heads are expanded to H *before* the kernel and all
    three tensors are constrained to the heads-over-`model` TP layout, so the
    pair scan is collective-free (the expanded KV is TP-sharded, hence cheap;
    dk/dv sum back over the expansion automatically).
    """
    from repro.distributed import hints
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if S <= block_threshold and not hints.active():
        return dense_attention(q, k, v, causal=True)
    from repro.models.flash import flash_attention
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    q = hints.attn_heads(q)
    k = hints.attn_heads(k)
    v = hints.attn_heads(v)
    if S <= block_threshold:
        out = dense_attention(q, k, v, causal=True)
    else:
        chunk = 512 if S % 512 == 0 else _chunk_of(S)
        out = flash_attention(q[:, :, :, None, :], k, v, chunk)
        out = out.reshape(B, S, H, hd)
    return hints.attn_heads(out)


def attention_train(p: Params, spec: AttnSpec, x: Array, positions: Array,
                    ) -> Array:
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, spec, x, positions)
    out = _self_attention(q, k, v)
    return out.reshape(B, S, -1) @ p["wo"]


def attention_prefill(p: Params, spec: AttnSpec, x: Array, positions: Array,
                      ) -> Tuple[Array, Tuple[Array, Array]]:
    """Prefill: returns (output, (k_cache, v_cache))."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, spec, x, positions)
    out = _self_attention(q, k, v)
    return out.reshape(B, S, -1) @ p["wo"], (k, v)


def attention_decode(p: Params, spec: AttnSpec, x: Array, positions: Array,
                     cache: Tuple[Array, Array], cache_index: Array,
                     ) -> Tuple[Array, Tuple[Array, Array]]:
    """Single-token decode against a (B, S_max, KV, hd) cache.

    cache_index: current fill level (tokens already in cache).
    """
    B, S1, _ = x.shape  # S1 == 1
    q, k_new, v_new = _project_qkv(p, spec, x, positions)
    k_cache, v_cache = cache
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, cache_index, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, cache_index, 1)
    H, KV, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    G = H // KV
    qg = q.reshape(B, S1, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_cache).astype(jnp.float32) / np.sqrt(hd)
    valid = jnp.arange(k_cache.shape[1]) <= (cache_index + S1 - 1)   # (S_max,)
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    probs = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v_cache).reshape(B, S1, H * hd)
    return out @ p["wo"], (k_cache, v_cache)


def _chunk_of(s: int) -> int:
    for c in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if s % c == 0:
            return c
    return 1


# ----------------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"wi_gate": dense_init(ks[0], d_model, d_ff, dtype),
                "wi_up": dense_init(ks[1], d_model, d_ff, dtype),
                "wo": dense_init(ks[2], d_ff, d_model, dtype)}
    return {"wi": dense_init(ks[0], d_model, d_ff, dtype),
            "wo": dense_init(ks[1], d_ff, d_model, dtype)}


def mlp(p: Params, x: Array) -> Array:
    if "wi_gate" in p:
        return (jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])) @ p["wo"]
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]
