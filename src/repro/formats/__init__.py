"""Sparse-format subsystem: swappable Phi layouts (DESIGN.md §7).

Importing this package registers the built-in formats:

  coo   sorted-COO PhiTensor (the canonical layout every pre-existing
        executor consumes)                                — formats/coo.py
  sell  sliced-ELL/blocked layout for direct row-block Pallas
        accumulation (no prefetched row map, no one-hot)  — formats/sell.py
  alto  bit-interleaved linearized single-index encoding  — formats/alto.py
  fcoo  segment-flagged linearization; ONE resident copy serves both
        ops via segment-scan kernels (DESIGN.md §11)      — formats/fcoo.py

``formats.select`` picks one per dataset from inspector statistics with an
autotune fallback; engines reach it via ``LifeConfig(format="auto")``.

``formats.shard`` composes the above: an (R x C) mesh partition whose cells
are inner ``coo``/``sell`` encodes (DESIGN.md §9).  It satisfies the
PhiFormat contract but is *not* a registered leaf format — what the
registry sees are the ``shard``/``shard-sell`` executors consuming it.
"""
from repro.formats.base import (FORMATS, FORMAT_VERSION, FormatPlan,
                                PhiFormat, canonical_triples, format_names,
                                get_format, register_format)
from repro.formats.alto import AltoPhi
from repro.formats.coo import CooPhi
from repro.formats.fcoo import FcooPhi
from repro.formats.sell import SellPhi
from repro.formats.shard import ShardPhi, partition_cuts

__all__ = [
    "FORMATS", "FORMAT_VERSION", "FormatPlan", "PhiFormat",
    "canonical_triples", "format_names", "get_format", "register_format",
    "AltoPhi", "CooPhi", "FcooPhi", "SellPhi", "ShardPhi", "partition_cuts",
]
