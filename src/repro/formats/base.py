"""PhiFormat protocol + format registry (DESIGN.md §7).

The paper's whole argument is that SpMV performance is decided by the data
*representation*; Chen et al. (arXiv:1805.11938) show no single sparse format
wins across matrices on many-core hardware, and ALTO (arXiv:2403.06348)
argues the same for sparse tensors.  This package therefore makes the Phi
layout a first-class, swappable object:

  * every concrete layout (:mod:`~repro.formats.coo`,
    :mod:`~repro.formats.sell`, :mod:`~repro.formats.alto`) registers itself
    under a name,
  * all of them share one contract — ``encode`` from the canonical COO
    :class:`~repro.core.std.PhiTensor`, ``decode`` back to the *exact* same
    coefficient multiset (order may differ; triples and values round-trip
    bit-exactly), plus storage accounting (``nbytes``, ``padding_overhead``),
  * :mod:`~repro.formats.select` picks one per dataset from inspector
    statistics, with the choice serialized as a :class:`FormatPlan` through
    the persistent plan cache.

Executors consume formats through :mod:`repro.core.registry`; the format
name reaches engines via ``LifeConfig(format=...)`` (``"auto"`` delegates to
the selector).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.core.std import PhiTensor

#: bump on any incompatible change to a format's on-disk/plan representation
FORMAT_VERSION = 1

#: output ("row") dimension per SpMV op — voxel rows for DSC, fiber rows
#: for WC (DESIGN.md §2: we sort/layout by the output dimension on TPU).
OUTPUT_DIMS = {"dsc": "voxel", "wc": "fiber"}


@runtime_checkable
class PhiFormat(Protocol):
    """Structural contract every concrete Phi layout satisfies.

    Concrete classes are dataclasses; ``encode`` is a classmethod building
    the layout from the canonical COO tensor, ``decode`` inverts it exactly.
    """

    name: ClassVar[str]

    @classmethod
    def encode(cls, phi: PhiTensor, *, op: str = "dsc", **params) -> "PhiFormat":
        """Build the layout from the canonical COO tensor.

        Args:
            phi: canonical COO Phi.
            op: which SpMV the encode is laid out for ("dsc"/"wc") — only
                meaningful for per-op layouts like SELL; one-copy layouts
                (ALTO, F-COO) ignore it.
            **params: layout geometry (e.g. ``row_tile``/``slot_tile``).
        """
        ...

    def decode(self) -> PhiTensor:
        """Invert :meth:`encode`: the exact same coefficient multiset
        (order may differ; triples and values round-trip bit-exactly)."""
        ...

    @property
    def nbytes(self) -> int:
        """Resident bytes of the encoded layout (indices + values)."""
        ...

    @property
    def padding_overhead(self) -> float:
        """Stored slots / real coefficients - 1 (0.0 = no padding waste)."""
        ...


FORMATS: Dict[str, type] = {}


def register_format(cls):
    """Class decorator: register a PhiFormat implementation by ``cls.name``."""
    name = cls.name
    if name in FORMATS:
        raise ValueError(f"format {name!r} already registered")
    FORMATS[name] = cls
    return cls


def format_names() -> Tuple[str, ...]:
    """All registered format names, sorted."""
    return tuple(sorted(FORMATS))


def get_format(name: str):
    """The registered PhiFormat class for ``name``.

    Raises:
        ValueError: when no format is registered under ``name``.
    """
    if name not in FORMATS:
        raise ValueError(f"format must be one of {format_names()}, got {name!r}")
    return FORMATS[name]


def canonical_triples(phi: PhiTensor) -> Tuple[np.ndarray, ...]:
    """(atoms, voxels, fibers, values) sorted by (atom, voxel, fiber).

    Round-trip tests compare layouts through this canonical order because
    formats are free to permute coefficients (that reordering *is* the
    optimization); the multiset of (triple, value) pairs is the invariant.
    """
    a = np.asarray(phi.atoms, np.int64)
    v = np.asarray(phi.voxels, np.int64)
    f = np.asarray(phi.fibers, np.int64)
    vals = np.asarray(phi.values)
    order = np.lexsort((f, v, a))
    return a[order], v[order], f[order], vals[order]


@dataclasses.dataclass
class FormatPlan:
    """Per-dataset format choice, serialized through the PlanCache.

    ``format``: chosen format name; ``reason``: how it was decided —
      "heuristic"  inspector run-length statistics were decisive;
      "autotune"   the measured arbitration loop timed the candidates;
      "explicit"   the caller forced ``config.format``, nothing selected;
      "predicted"  a trained :mod:`repro.learn` predictor answered a cache
                   miss from ``phi_stats`` features with zero measurements
                   (DESIGN.md §14) — served immediately, then upgraded in
                   place by background refinement to one of the reasons
                   above;
    ``params``: layout geometry (row_tile / slot_tile for SELL); ``stats``:
    the inspector statistics the decision was based on, kept so benchmarks,
    audits and the :mod:`repro.learn` harvester can explain (or train on)
    the choice without re-running the inspector.
    """

    format: str
    reason: str = "heuristic"
    params: Dict[str, int] = dataclasses.field(default_factory=dict)
    stats: Dict[str, float] = dataclasses.field(default_factory=dict)

    def describe(self) -> str:
        """One-line human-readable summary (format, reason, geometry)."""
        ps = ",".join(f"{k}={v}" for k, v in sorted(self.params.items()))
        return f"format={self.format} ({self.reason}{'; ' + ps if ps else ''})"
