"""ALTO: adaptive linearized single-index Phi encoding (arXiv:2403.06348).

Instead of three indirection vectors, each coefficient carries ONE integer
whose bits interleave the (atom, voxel, fiber) coordinates round-robin from
the LSB — a mode-agnostic space-filling-curve order.  Properties this buys
the LiFE workload:

  * **one ordering serves both ops**: sorting by the linearized index gives
    locality in *all* modes at once (nearby coefficients share nearby
    atoms, voxels and fibers), so a single Phi copy feeds DSC and WC
    instead of the two per-op sorted copies the COO executors keep;
  * **cheap host-side re-sorting and compaction**: the sort key is a flat
    ``uint64`` vector (one ``np.argsort``) and weight compaction is a
    boolean mask on two arrays — no three-vector shuffles — which matters
    because ``compact_by_weight`` re-runs every ``compact_every`` SBBNNLS
    iterations;
  * **3x index-memory reduction** while resident (8 bytes vs 3x4 per
    coefficient at rest; decode back to int32 triples is vectorized bit
    surgery, done lazily per op).

Bit budget: ``bits(Na)+bits(Nv)+bits(Nf) <= 64`` — at the paper's largest
STN96 instance (Na=1160, Nv=2.6e5, Nf=5e5) that is 11+18+19 = 48 bits, so
uint64 covers real connectomes with headroom.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, List, Tuple

import numpy as np

from repro.core.std import PhiTensor
from repro.formats.base import register_format

MODES = ("atom", "voxel", "fiber")


def _mode_bits(n_atoms: int, n_voxels: int, n_fibers: int) -> Tuple[int, ...]:
    """Bits needed to represent the largest index of each mode."""
    return tuple(max(0, int(n - 1).bit_length())
                 for n in (n_atoms, n_voxels, n_fibers))


def _interleave_positions(bits: Tuple[int, ...]) -> Dict[str, List[int]]:
    """Round-robin bit placement from the LSB: round k assigns bit k of each
    mode that still has bits left.  Low-order bits of every mode land in the
    low-order bits of the linearized index — the ALTO locality property."""
    pos: Dict[str, List[int]] = {m: [] for m in MODES}
    p = 0
    for k in range(max(bits) if bits else 0):
        for m, b in zip(MODES, bits):
            if k < b:
                pos[m].append(p)
                p += 1
    return pos


@register_format
@dataclasses.dataclass
class AltoPhi:
    """Linearized Phi: one uint64 index + one value per coefficient."""

    name: ClassVar[str] = "alto"

    lin: np.ndarray                      # uint64 (Nc,)
    values: np.ndarray                   # fp (Nc,)
    n_atoms: int
    n_voxels: int
    n_fibers: int

    # -- encode / decode ------------------------------------------------------
    @classmethod
    def encode(cls, phi: PhiTensor, *, op: str = "dsc", **_params) -> "AltoPhi":
        bits = _mode_bits(phi.n_atoms, phi.n_voxels, phi.n_fibers)
        if sum(bits) > 64:
            raise ValueError(
                f"mode sizes need {sum(bits)} bits, uint64 has 64")
        pos = _interleave_positions(bits)
        lin = np.zeros(phi.n_coeffs, np.uint64)
        for mode, idx in zip(MODES, (phi.atoms, phi.voxels, phi.fibers)):
            idx64 = np.asarray(idx, np.uint64)
            for k, p in enumerate(pos[mode]):
                lin |= ((idx64 >> np.uint64(k)) & np.uint64(1)) << np.uint64(p)
        return cls(lin=lin, values=np.asarray(phi.values).copy(),
                   n_atoms=phi.n_atoms, n_voxels=phi.n_voxels,
                   n_fibers=phi.n_fibers)

    def _extract_mode(self, mode: str) -> np.ndarray:
        """De-interleave one mode's coordinate from the linearized index."""
        bits = _mode_bits(self.n_atoms, self.n_voxels, self.n_fibers)
        idx = np.zeros(self.lin.size, np.uint64)
        for k, p in enumerate(_interleave_positions(bits)[mode]):
            idx |= ((self.lin >> np.uint64(p)) & np.uint64(1)) << np.uint64(k)
        return idx.astype(np.int32)

    def _delinearize(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        return tuple(self._extract_mode(mode) for mode in MODES)

    def decode(self) -> PhiTensor:
        import jax.numpy as jnp
        atoms, voxels, fibers = self._delinearize()
        return PhiTensor(
            atoms=jnp.asarray(atoms), voxels=jnp.asarray(voxels),
            fibers=jnp.asarray(fibers), values=jnp.asarray(self.values),
            n_atoms=self.n_atoms, n_voxels=self.n_voxels,
            n_fibers=self.n_fibers)

    # -- host-side restructuring ---------------------------------------------
    def sort(self) -> Tuple["AltoPhi", np.ndarray]:
        """Order by the linearized index (the ALTO locality order).
        Returns (sorted AltoPhi, permutation) — one flat argsort, the cheap
        re-sorting the linearization exists for."""
        order = np.argsort(self.lin, kind="stable")
        return dataclasses.replace(
            self, lin=self.lin[order], values=self.values[order]), order

    def compact(self, keep: np.ndarray) -> "AltoPhi":
        """Drop coefficients where ``keep`` is False (weight compaction):
        a boolean mask over two flat arrays, no triple shuffling."""
        keep = np.asarray(keep, bool)
        return dataclasses.replace(
            self, lin=self.lin[keep], values=self.values[keep])

    def fibers_of(self) -> np.ndarray:
        """Just the fiber coordinates (for weight-compaction masks) without
        paying for the full delinearization."""
        return self._extract_mode("fiber")

    # -- accounting -----------------------------------------------------------
    @property
    def n_coeffs(self) -> int:
        return int(self.lin.size)

    @property
    def nbytes(self) -> int:
        return int(self.lin.nbytes + self.values.nbytes)

    @property
    def padding_overhead(self) -> float:
        return 0.0                      # exactly Nc slots, no padding
