"""Per-dataset format selection: heuristic first, measurement when unsure.

Chen et al. (arXiv:1805.11938) show no single SpMV format wins across
matrices on many-core hardware; this module is that observation applied to
Phi.  The decision pipeline:

1. **Cache** — the choice is a :class:`~repro.formats.base.FormatPlan`
   keyed by ``plan_cache.format_plan_key`` (full index content + geometry +
   candidate set, format-versioned); a warm engine rebuild loads it and
   never re-runs selection.
2. **Predict** — a trained :class:`~repro.learn.model.Predictor` beside
   the cache directory answers the miss from ``phi_stats`` features alone
   (``reason="predicted"``, zero measurements); the measured pipeline is
   enqueued on :data:`repro.learn.refine.QUEUE` so background refinement
   upgrades the cached entry in place (DESIGN.md §14).
3. **Heuristic** — from ``core/inspector.py:phi_stats`` run-length
   statistics: SELL's padding overhead is computable in O(Nc) without
   encoding anything.  Overhead at most ``sell_accept`` extra slots per
   coefficient -> take SELL outright (dense uniform rows: the direct
   row-block kernels win and the padding is cheap); at least
   ``sell_reject`` -> SELL is struck from the candidate set (skewed row
   degrees: padding would dominate bytes moved).
4. **Autotune fallback** — whenever more than one candidate survives the
   heuristic (SELL in its ambiguous zone, or COO vs ALTO with no static
   signal between them), measure: the same three-runs-per-candidate loop
   as the paper's runtime restructuring selection, literally reused from
   ``restructure.autotune_plan`` with the format encoders plugged in as
   the ``sorter`` and the DSC executors (the dominant op, ~2
   calls/iteration vs WC's ~1.5) as the ``run``.  ``autotune_plan`` in
   turn times through :mod:`repro.tune.search` — the same measurement
   loop the kernel autotuner uses — so format choice and tile choice
   share one cost currency (DESIGN.md §10.2).

``resolve_format`` is the engine entry point: it also handles explicit
``LifeConfig(format="sell"/"alto"/"coo")`` requests (no selection, plan
records ``reason="explicit"``) and maps the chosen format to the executor
registry name.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import spmv
from repro.core.inspector import phi_stats
from repro.core.restructure import autotune_plan, sort_by_host
from repro.core.std import PhiTensor
from repro.formats import fcoo as fcoo_mod
from repro.formats import sell as sell_mod
from repro.formats.fcoo import FcooPhi
from repro.formats.base import FormatPlan, format_names
from repro.formats.sell import DEFAULT_ROW_TILE, DEFAULT_SLOT_TILE, SellPhi

#: SELL padding-overhead thresholds (extra slots per real coefficient)
DEFAULT_SELL_ACCEPT = 1.0
DEFAULT_SELL_REJECT = 4.0

#: format name -> executor registry name; None = defer to config.executor
#: (COO is what every pre-existing executor already consumes)
_FORMAT_EXECUTORS = {"coo": None, "sell": "kernel-sell", "alto": "alto",
                     "fcoo": "kernel-fcoo"}

#: default "auto" candidate set (every leaf format)
DEFAULT_CANDIDATES = ("coo", "sell", "alto", "fcoo")


def _mesh_cells(config) -> int:
    return (getattr(config, "shard_rows", 1)
            * getattr(config, "shard_cols", 1))


def executor_for(format_name: str, config) -> str:
    """Registry name that runs a format.

    Resolution order: (1) a multi-cell mesh request
    (``shard_rows * shard_cols > 1``) maps to the format's mesh executor
    from the registry's ``mesh=`` metadata — asking for a partition is the
    strongest signal, so it wins even over an explicit single-device
    executor; (2) an explicitly configured executor that itself consumes
    the format (so ``executor="shard-sell", format="sell"`` runs the
    sharded path on a 1x1 mesh, not ``kernel-sell``); (3) the static
    single-device mapping above (COO defers to config.executor)."""
    if format_name not in _FORMAT_EXECUTORS:
        raise ValueError(
            f"format must be one of {format_names()}, got {format_name!r}")
    from repro.core.registry import REGISTRY
    requested = getattr(config, "executor", "opt")
    if _mesh_cells(config) > 1:
        sharded = REGISTRY.mesh_executor_for(format_name)
        if sharded is not None:
            return sharded
    if requested in REGISTRY and REGISTRY.consumes(requested) == format_name:
        return requested
    mapped = _FORMAT_EXECUTORS[format_name]
    return requested if mapped is None else mapped


def _geometry(config) -> Tuple[int, int]:
    return (getattr(config, "row_tile", DEFAULT_ROW_TILE),
            getattr(config, "slot_tile", DEFAULT_SLOT_TILE))


def choose_format(
    phi: PhiTensor,
    dictionary,
    *,
    row_tile: int = DEFAULT_ROW_TILE,
    slot_tile: int = DEFAULT_SLOT_TILE,
    allowed: Tuple[str, ...] = DEFAULT_CANDIDATES,
    sell_accept: float = DEFAULT_SELL_ACCEPT,
    sell_reject: float = DEFAULT_SELL_REJECT,
    cache=None,
    predictor=None,
) -> FormatPlan:
    """Pick a Phi format for one dataset (see module docstring pipeline)."""
    if not allowed:
        raise ValueError("allowed must name at least one format")
    key = None
    if cache is not None and cache.enabled:
        from repro.core.plan_cache import format_plan_key
        key = format_plan_key(
            np.asarray(phi.atoms), np.asarray(phi.voxels),
            np.asarray(phi.fibers),
            sizes=(phi.n_atoms, phi.n_voxels, phi.n_fibers),
            row_tile=row_tile, slot_tile=slot_tile, allowed=allowed,
            sell_accept=sell_accept, sell_reject=sell_reject)
        plan = cache.get_format_plan(key)
        if plan is not None:
            if plan.reason == "predicted":
                # a predicted entry that is still serving hits was never
                # refined (process restart dropped the queue) — re-enqueue
                _enqueue_refinement(key, cache, phi, dictionary, allowed,
                                    row_tile, slot_tile, sell_accept,
                                    sell_reject)
            return plan

    stats = phi_stats(phi, row_tile=row_tile, slot_tile=slot_tile)
    params = dict(row_tile=row_tile, slot_tile=slot_tile)

    if predictor is not None:
        with obs.span("select.predicted") as sp:
            fmt = predictor.predict_format(stats, allowed=allowed)
            sp.set_attr("format", fmt)
        if fmt is not None:
            obs.counter("learn.predict", kind="format", outcome="hit").inc()
            plan = FormatPlan(fmt, "predicted", params, stats)
            if key is not None:
                cache.put_format_plan(key, plan)
                _enqueue_refinement(key, cache, phi, dictionary, allowed,
                                    row_tile, slot_tile, sell_accept,
                                    sell_reject)
            return plan
        obs.counter("learn.predict", kind="format", outcome="fallback").inc()

    plan = _decide_format(phi, dictionary, stats, params, allowed,
                          row_tile=row_tile, slot_tile=slot_tile,
                          sell_accept=sell_accept, sell_reject=sell_reject)
    if key is not None:
        cache.put_format_plan(key, plan)
    return plan


def _decide_format(phi, dictionary, stats, params, allowed, *, row_tile,
                   slot_tile, sell_accept, sell_reject) -> FormatPlan:
    """Heuristic + measured rungs of the ladder (no cache, no predictor).

    Factored out so background refinement can re-run exactly this under
    the same thresholds and overwrite a predicted plan in place."""
    overhead = max(stats["dsc.sell_overhead"], stats["wc.sell_overhead"])
    candidates = tuple(allowed)
    # strike SELL on heavy skew — unless it is the only candidate the
    # caller permits, in which case the caller's constraint wins
    if "sell" in candidates and overhead >= sell_reject and len(candidates) > 1:
        candidates = tuple(f for f in candidates if f != "sell")
    if "sell" in candidates and overhead <= sell_accept:
        return FormatPlan("sell", "heuristic", params, stats)
    if len(candidates) == 1:
        return FormatPlan(candidates[0], "heuristic", params, stats)
    return FormatPlan(_measure_formats(phi, dictionary, candidates,
                                       row_tile, slot_tile),
                      "autotune", params, stats)


def _enqueue_refinement(key, cache, phi, dictionary, allowed, row_tile,
                        slot_tile, sell_accept, sell_reject) -> None:
    """Queue the measured pipeline to upgrade a predicted plan in place."""
    from repro.learn import refine

    def _task() -> None:
        stats = phi_stats(phi, row_tile=row_tile, slot_tile=slot_tile)
        params = dict(row_tile=row_tile, slot_tile=slot_tile)
        plan = _decide_format(phi, dictionary, stats, params, allowed,
                              row_tile=row_tile, slot_tile=slot_tile,
                              sell_accept=sell_accept,
                              sell_reject=sell_reject)
        cache.put_format_plan(key, plan)

    refine.QUEUE.push("format", key, _task)


def _measure_formats(phi: PhiTensor, dictionary, allowed: Tuple[str, ...],
                     row_tile: int, slot_tile: int) -> str:
    """Autotune fallback: time the DSC executor of each candidate format
    through restructure.autotune_plan's measurement loop."""
    w_probe = jnp.ones((phi.n_fibers,), dictionary.dtype)

    def sorter(p: PhiTensor, fmt: str):
        if fmt == "sell":
            return SellPhi.encode(p, op="dsc", row_tile=row_tile,
                                  slot_tile=slot_tile), None
        if fmt == "alto":
            # prepare the actual registry executor so arbitration charges
            # ALTO whatever its real DSC path costs — timing dsc_naive over
            # a decoded COO tensor instead would keep "winning" for alto
            # even after its executor changes (untuned, untracked build:
            # selection must not recurse into the kernel autotuner)
            from types import SimpleNamespace
            from repro.core.registry import REGISTRY
            ex = REGISTRY.create(
                "alto", p, SimpleNamespace(dictionary=dictionary),
                SimpleNamespace(tune="off"))
            return ex, None
        if fmt == "fcoo":
            return FcooPhi.encode(p), None
        return sort_by_host(p, "voxel")            # coo

    def run(prepared, fmt: str):
        if fmt == "sell":
            return sell_mod.dsc_reference(prepared, dictionary, w_probe)
        if fmt == "alto":
            return prepared.matvec(w_probe)        # the registry executor
        if fmt == "fcoo":
            return fcoo_mod.dsc_reference(prepared, dictionary, w_probe)
        return spmv.dsc(prepared, dictionary, w_probe)  # coo, voxel-sorted

    plan = autotune_plan("dsc", phi, run, candidates=tuple(allowed),
                         sorter=sorter)
    return plan.restructure                        # holds the format name


def resolve_format(phi: PhiTensor, problem, config, cache=None,
                   allowed: Optional[Tuple[str, ...]] = None,
                   mesh_aware: bool = True) -> FormatPlan:
    """Engine entry point: honor an explicit ``config.format`` or select.

    ``allowed`` restricts the candidate set (the batched engine passes the
    vmappable subset — SELL widths are per-subject static shapes).  Under a
    multi-cell mesh request (``shard_rows * shard_cols > 1``) the "auto"
    candidate set is further restricted to formats with a registered mesh
    executor — alto has no sharded path, so selecting it would silently
    drop the requested partitioning.  Callers for whom the mesh is
    placement-only (the batched engine: ``shard_rows/cols`` just
    device_put the stacked operands, no mesh executor runs) pass
    ``mesh_aware=False`` to keep the full candidate set.
    """
    fmt = getattr(config, "format", "coo")
    row_tile, slot_tile = _geometry(config)
    params = dict(row_tile=row_tile, slot_tile=slot_tile)
    if fmt != "auto":
        if fmt not in _FORMAT_EXECUTORS:
            raise ValueError(
                f"format must be one of {format_names() + ('auto',)}, "
                f"got {fmt!r}")
        if allowed is not None and fmt not in allowed:
            raise ValueError(
                f"format {fmt!r} is not supported here (allowed: {allowed})")
        return FormatPlan(fmt, "explicit", params)
    candidates = (tuple(allowed) if allowed is not None
                  else DEFAULT_CANDIDATES)
    if mesh_aware and _mesh_cells(config) > 1:
        from repro.core.registry import REGISTRY
        mesh_ok = tuple(f for f in candidates
                        if REGISTRY.mesh_executor_for(f) is not None)
        if not mesh_ok:
            raise ValueError(
                f"no candidate format in {candidates} has a mesh executor "
                f"(shard_rows x shard_cols = {_mesh_cells(config)})")
        candidates = mesh_ok
    predictor = None
    if (getattr(config, "predict", "auto") != "off"
            and cache is not None and cache.enabled):
        from repro.learn import load_predictor
        predictor = load_predictor(cache.directory)
    return choose_format(
        phi, problem.dictionary, row_tile=row_tile, slot_tile=slot_tile,
        allowed=candidates,
        sell_accept=getattr(config, "sell_accept", DEFAULT_SELL_ACCEPT),
        sell_reject=getattr(config, "sell_reject", DEFAULT_SELL_REJECT),
        cache=cache, predictor=predictor)
