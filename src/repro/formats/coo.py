"""Sorted-COO format: the canonical PhiTensor plus a remembered sort.

This wraps the representation the repo has always used (``core/std.py``) in
the :class:`~repro.formats.base.PhiFormat` contract: encode = stable sort by
the op's output dimension (the restructuring of DESIGN.md §2), decode =
undo the permutation, so the original coefficient order round-trips exactly.
All existing segment-sum executors consume this layout unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.restructure import sort_by_host
from repro.core.std import PhiTensor
from repro.formats.base import OUTPUT_DIMS, register_format


@register_format
@dataclasses.dataclass
class CooPhi:
    """COO coefficients stably sorted along ``sort_dim``.

    ``order`` is the applied permutation (original -> sorted), kept so
    ``decode`` restores the exact input ordering and so plans can replay
    the restructuring without re-sorting (the paper's amortization).
    """

    name: ClassVar[str] = "coo"

    phi: PhiTensor                       # sorted coefficients
    sort_dim: str                        # "atom" | "voxel" | "fiber"
    order: np.ndarray                    # int64[Nc] permutation applied

    @classmethod
    def encode(cls, phi: PhiTensor, *, op: str = "dsc",
               sort_dim: Optional[str] = None, **_params) -> "CooPhi":
        dim = OUTPUT_DIMS[op] if sort_dim is None else sort_dim
        sorted_phi, order = sort_by_host(phi, dim)
        return cls(phi=sorted_phi, sort_dim=dim, order=np.asarray(order))

    def decode(self) -> PhiTensor:
        inverse = np.empty_like(self.order)
        inverse[self.order] = np.arange(self.order.size)
        return self.phi.take(jnp.asarray(inverse, jnp.int32))

    @property
    def n_coeffs(self) -> int:
        return self.phi.n_coeffs

    @property
    def nbytes(self) -> int:
        p = self.phi
        return int(p.atoms.size * p.atoms.dtype.itemsize
                   + p.voxels.size * p.voxels.dtype.itemsize
                   + p.fibers.size * p.fibers.dtype.itemsize
                   + p.values.size * p.values.dtype.itemsize)

    @property
    def padding_overhead(self) -> float:
        return 0.0                      # COO stores exactly Nc slots
