"""Shard: partitioned Phi layout — (R x C) mesh cells of an inner format.

The 2-D mesh partition of DESIGN.md §4/§9 used to live as bespoke padded-COO
arrays inside ``distributed/life_shard.py``, invisible to the format
subsystem.  This module makes the partition itself a layout that satisfies
the :class:`~repro.formats.base.PhiFormat` contract:

  * :func:`partition_cuts` turns the equal-nnz coefficient boundaries of
    ``core/inspector.py:shard_boundaries`` into *id-space* voxel/fiber range
    cuts (an :class:`~repro.core.inspector.ShardPlan`, serialized through the
    persistent plan cache under a mesh-topology-aware key),
  * :meth:`ShardPhi.encode` materializes every (voxel-range x fiber-range)
    cell through the inner format's contract on a *localized* cell
    PhiTensor — ``SellPhi.encode`` for the blocked-ELL Pallas kernels,
    ``CooPhi``'s stable output-dim restructuring (applied host-side; the
    per-cell loop must not pay device round-trips) for the
    sorted-segment-sum executors — then stacks the cells into common-shape
    device operands (padding slots carry value 0 and are inert through
    both ops, the §4.2.1.2 sync-free invariant at mesh granularity),
  * :meth:`ShardPhi.decode` inverts each cell through the inner format's
    decoder and re-globalizes the indices, so the coefficient multiset
    round-trips exactly (the formats contract).

``ShardPhi`` is deliberately *not* in the ``FORMATS`` registry: it is a
composite wrapper, not a leaf layout a dataset can select — what the
selector and the conformance matrix see are the executors that consume it
(``shard`` over inner COO, ``shard-sell`` over inner SELL, registered in
``core/registry.py`` with ``consumes=`` naming the inner cell format).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.inspector import ShardPlan, shard_boundaries
from repro.core.std import PhiTensor
from repro.formats.base import OUTPUT_DIMS
from repro.formats.sell import DEFAULT_ROW_TILE, DEFAULT_SLOT_TILE, SellPhi

#: inner per-cell layouts ShardPhi can materialize
CELL_FORMATS = ("coo", "sell")


def _id_cuts(sorted_ids: np.ndarray, n_ids: int, k: int) -> np.ndarray:
    """Coefficient-offset boundaries -> id-space range cuts for one mode.

    A coefficient cut at offset ``i < n`` becomes the id starting the next
    range (``sorted_ids[i]``); only the final cut maps to ``n_ids``.  An
    interior cut of 0 (the smallest id owns at least its shard's whole
    nnz share) therefore yields an empty leading range instead of a
    non-monotone boundary.
    """
    idx_cuts = shard_boundaries(sorted_ids, k)
    n = sorted_ids.size
    cuts = np.asarray(
        [0] + [int(sorted_ids[i]) if i < n else n_ids
               for i in idx_cuts[1:]], np.int64)
    cuts[-1] = n_ids
    return cuts


def partition_cuts(phi: PhiTensor, R: int, C: int, *,
                   cell_format: str = "coo", cache=None) -> ShardPlan:
    """Equal-nnz (voxel x fiber) range cuts snapped to sub-vector boundaries.

    Routed through the persistent plan cache when one is supplied: the key
    (``plan_cache.shard_plan_key``) covers the full index content, the mesh
    shape, the inner cell format, and the *device count* — so a warm engine
    rebuild on the same topology skips the partitioning entirely, while the
    same dataset opened on a different mesh or host misses cleanly.
    """
    if R < 1 or C < 1:
        raise ValueError(f"mesh shape must be positive, got ({R}, {C})")
    atoms = np.asarray(phi.atoms)
    voxels = np.asarray(phi.voxels)
    fibers = np.asarray(phi.fibers)
    key = None
    if cache is not None and cache.enabled:
        from repro.core.plan_cache import shard_plan_key
        key = shard_plan_key(
            atoms, voxels, fibers,
            sizes=(phi.n_atoms, phi.n_voxels, phi.n_fibers), R=R, C=C,
            cell_format=cell_format, n_devices=len(jax.devices()))
        plan = cache.get_shard_plan(key)
        if plan is not None and (plan.R, plan.C) == (R, C):
            return plan
    plan = ShardPlan(
        R=R, C=C,
        voxel_cuts=_id_cuts(np.sort(voxels), phi.n_voxels, R),
        fiber_cuts=_id_cuts(np.sort(fibers), phi.n_fibers, C))
    if key is not None:
        cache.put_shard_plan(key, plan)
    return plan


def _cell_index_sets(voxels: np.ndarray, fibers: np.ndarray,
                     plan: ShardPlan):
    """Per-cell coefficient index sets + counts for one partition.

    One O(R*C*Nc) host sweep; both per-op encodes of an executor share the
    result through :func:`encode_pair` instead of recomputing it."""
    row_of = np.searchsorted(plan.voxel_cuts, voxels, side="right") - 1
    col_of = np.searchsorted(plan.fiber_cuts, fibers, side="right") - 1
    cell_idx: Dict[tuple, np.ndarray] = {}
    cell_nnz = np.zeros((plan.R, plan.C), np.int64)
    for r in range(plan.R):
        for c in range(plan.C):
            idx = np.nonzero((row_of == r) & (col_of == c))[0]
            cell_idx[(r, c)] = idx
            cell_nnz[r, c] = idx.size
    return cell_idx, cell_nnz


def encode_pair(phi: PhiTensor, *, cell_format: str = "coo", R: int = 1,
                C: int = 1, row_tile: int = DEFAULT_ROW_TILE,
                slot_tile: int = DEFAULT_SLOT_TILE,
                plan: Optional[ShardPlan] = None, cache=None):
    """Both per-op layouts (DSC + WC) from one partition sweep.

    Returns ``(shard_dsc, shard_wc)`` sharing the same ShardPlan and cell
    index sets — what the mesh executors build."""
    if plan is None:
        plan = partition_cuts(phi, R, C, cell_format=cell_format,
                              cache=cache)
    cells = _cell_index_sets(np.asarray(phi.voxels), np.asarray(phi.fibers),
                             plan)
    common = dict(cell_format=cell_format, plan=plan, row_tile=row_tile,
                  slot_tile=slot_tile, _cells=cells)
    return (ShardPhi.encode(phi, op="dsc", **common),
            ShardPhi.encode(phi, op="wc", **common))


@dataclasses.dataclass
class ShardPhi:
    """Stacked (R x C) cell operands of one op, inner-format encoded.

    ``arrays`` (all numpy, localized indices, padding slots value 0):

      cell_format="coo"  : ``atoms``/``voxels``/``fibers``/``values``,
                           each ``(R, C, nnz_max)``, sorted by the op's
                           output dimension within the cell (the padded
                           tail carries the last local row id so the sort
                           key stays monotone for ``indices_are_sorted``
                           segment sums; its values are 0, so it is inert);
      cell_format="sell" : ``atoms``/``others``/``values``, each
                           ``(R, C, rows_padded, width)`` blocked-ELL slot
                           arrays (``others`` = fibers for DSC, voxels for
                           WC), plus ``row_nnz`` ``(R, C, n_rows_local)``.

    ``cell_nnz`` is the exact per-cell coefficient count — the decode mask
    and the padding audit.
    """

    name: ClassVar[str] = "shard"

    op: str                              # "dsc" | "wc"
    cell_format: str                     # "coo" | "sell"
    R: int
    C: int
    voxel_cuts: np.ndarray               # int64 (R+1,)
    fiber_cuts: np.ndarray               # int64 (C+1,)
    nv_local: int
    nf_local: int
    n_atoms: int
    n_voxels: int
    n_fibers: int
    arrays: Dict[str, np.ndarray]
    cell_nnz: np.ndarray                 # int64 (R, C)
    row_tile: int = 0                    # SELL geometry (0 for coo cells)
    slot_tile: int = 0

    # -- encode / decode ------------------------------------------------------
    @classmethod
    def encode(cls, phi: PhiTensor, *, op: str = "dsc",
               cell_format: str = "coo", R: int = 1, C: int = 1,
               row_tile: int = DEFAULT_ROW_TILE,
               slot_tile: int = DEFAULT_SLOT_TILE,
               plan: Optional[ShardPlan] = None, cache=None,
               _cells=None, **_params) -> "ShardPhi":
        if cell_format not in CELL_FORMATS:
            raise ValueError(
                f"cell format must be one of {CELL_FORMATS}, "
                f"got {cell_format!r}")
        if plan is None:
            plan = partition_cuts(phi, R, C, cell_format=cell_format,
                                  cache=cache)
        R, C = plan.R, plan.C
        nv_local, nf_local = plan.nv_local, plan.nf_local

        atoms = np.asarray(phi.atoms)
        voxels = np.asarray(phi.voxels)
        fibers = np.asarray(phi.fibers)
        values = np.asarray(phi.values)
        cell_idx, cell_nnz = (_cell_index_sets(voxels, fibers, plan)
                              if _cells is None else _cells)

        def cell_phi(r: int, c: int) -> PhiTensor:
            """Localized cell tensor (numpy-backed: the R*C encode loop
            must not pay device round-trips per cell)."""
            idx = cell_idx[(r, c)]
            return PhiTensor(
                atoms=atoms[idx].astype(np.int32),
                voxels=(voxels[idx] - plan.voxel_cuts[r]).astype(np.int32),
                fibers=(fibers[idx] - plan.fiber_cuts[c]).astype(np.int32),
                values=values[idx],
                n_atoms=phi.n_atoms, n_voxels=nv_local, n_fibers=nf_local)

        if cell_format == "coo":
            nnz_max = max(1, int(cell_nnz.max()))
            out = dict(atoms=np.zeros((R, C, nnz_max), np.int32),
                       voxels=np.zeros((R, C, nnz_max), np.int32),
                       fibers=np.zeros((R, C, nnz_max), np.int32),
                       values=np.zeros((R, C, nnz_max), values.dtype))
            # the padded tail must extend the op's output-dim sort key
            # monotonically: the sharded executors promise
            # indices_are_sorted=True to segment_sum, and value-0 slots are
            # inert regardless of the row they land on (same dummy-slot
            # idiom as core/batched.py:_pad_sorted)
            out_key = "voxels" if OUTPUT_DIMS[op] == "voxel" else "fibers"
            pad_id = max(0, (nv_local if out_key == "voxels"
                             else nf_local) - 1)
            out[out_key] = np.full((R, C, nnz_max), pad_id, np.int32)
            for (r, c), idx in cell_idx.items():
                cp = cell_phi(r, c)
                # CooPhi's restructuring (stable sort by the op's output
                # dim) applied host-side: CooPhi.encode sorts through
                # jnp.take, which would cost 4 device transfers per cell
                key = cp.voxels if out_key == "voxels" else cp.fibers
                order = np.argsort(key, kind="stable")
                n = idx.size
                out["atoms"][r, c, :n] = cp.atoms[order]
                out["voxels"][r, c, :n] = cp.voxels[order]
                out["fibers"][r, c, :n] = cp.fibers[order]
                out["values"][r, c, :n] = cp.values[order]
            row_tile = slot_tile = 0
        else:
            cells = {rc: SellPhi.encode(cell_phi(*rc), op=op,
                                        row_tile=row_tile,
                                        slot_tile=slot_tile)
                     for rc in cell_idx}
            width = max(s.width for s in cells.values())
            rows_padded = next(iter(cells.values())).atoms.shape[0]
            n_rows_local = next(iter(cells.values())).n_rows
            out = dict(atoms=np.zeros((R, C, rows_padded, width), np.int32),
                       others=np.zeros((R, C, rows_padded, width), np.int32),
                       values=np.zeros((R, C, rows_padded, width),
                                       values.dtype),
                       row_nnz=np.zeros((R, C, n_rows_local), np.int32))
            for (r, c), s in cells.items():
                w = s.width
                out["atoms"][r, c, :, :w] = s.atoms
                out["others"][r, c, :, :w] = s.others
                out["values"][r, c, :, :w] = s.values
                out["row_nnz"][r, c] = s.row_nnz

        return cls(op=op, cell_format=cell_format, R=R, C=C,
                   voxel_cuts=plan.voxel_cuts, fiber_cuts=plan.fiber_cuts,
                   nv_local=nv_local, nf_local=nf_local,
                   n_atoms=phi.n_atoms, n_voxels=phi.n_voxels,
                   n_fibers=phi.n_fibers, arrays=out, cell_nnz=cell_nnz,
                   row_tile=row_tile, slot_tile=slot_tile)

    def decode(self) -> PhiTensor:
        """Invert every cell through the inner format and re-globalize."""
        parts = {k: [] for k in ("atoms", "voxels", "fibers", "values")}
        for r in range(self.R):
            for c in range(self.C):
                p = self._decode_cell(r, c)
                parts["atoms"].append(np.asarray(p.atoms))
                parts["voxels"].append(np.asarray(p.voxels)
                                       + self.voxel_cuts[r])
                parts["fibers"].append(np.asarray(p.fibers)
                                       + self.fiber_cuts[c])
                parts["values"].append(np.asarray(p.values))
        return PhiTensor(
            atoms=jnp.asarray(np.concatenate(parts["atoms"]), jnp.int32),
            voxels=jnp.asarray(np.concatenate(parts["voxels"]), jnp.int32),
            fibers=jnp.asarray(np.concatenate(parts["fibers"]), jnp.int32),
            values=jnp.asarray(np.concatenate(parts["values"])),
            n_atoms=self.n_atoms, n_voxels=self.n_voxels,
            n_fibers=self.n_fibers)

    def _decode_cell(self, r: int, c: int) -> PhiTensor:
        if self.cell_format == "coo":
            n = int(self.cell_nnz[r, c])
            return PhiTensor(
                atoms=jnp.asarray(self.arrays["atoms"][r, c, :n]),
                voxels=jnp.asarray(self.arrays["voxels"][r, c, :n]),
                fibers=jnp.asarray(self.arrays["fibers"][r, c, :n]),
                values=jnp.asarray(self.arrays["values"][r, c, :n]),
                n_atoms=self.n_atoms, n_voxels=self.nv_local,
                n_fibers=self.nf_local)
        cell = SellPhi(
            op=self.op, atoms=self.arrays["atoms"][r, c],
            others=self.arrays["others"][r, c],
            values=self.arrays["values"][r, c],
            row_nnz=self.arrays["row_nnz"][r, c],
            row_tile=self.row_tile, slot_tile=self.slot_tile,
            n_atoms=self.n_atoms, n_voxels=self.nv_local,
            n_fibers=self.nf_local)
        return cell.decode()

    # -- geometry / accounting ------------------------------------------------
    @property
    def plan(self) -> ShardPlan:
        return ShardPlan(R=self.R, C=self.C, voxel_cuts=self.voxel_cuts,
                         fiber_cuts=self.fiber_cuts)

    @property
    def n_coeffs(self) -> int:
        return int(self.cell_nnz.sum())

    @property
    def nbytes(self) -> int:
        return int(sum(a.nbytes for a in self.arrays.values())
                   + self.voxel_cuts.nbytes + self.fiber_cuts.nbytes
                   + self.cell_nnz.nbytes)

    @property
    def padding_overhead(self) -> float:
        """Allocated value slots / real coefficients - 1 across all cells."""
        return self.arrays["values"].size / max(1, self.n_coeffs) - 1.0


# ----------------------------------------------------------------------------
# Pure-numpy references over the stacked cell arrays.  Same dataflow as the
# shard_map executors minus the mesh: the single-device oracle for the
# distributed path, and the only way to exercise multi-cell layouts (and
# their padding-inertness invariant) in a single-device test process.
# ----------------------------------------------------------------------------

def _cell_operands(shard: ShardPhi, r: int, c: int):
    """(atoms, out-dim local ids, other-dim local ids, values), flattened."""
    out_dim = OUTPUT_DIMS[shard.op]
    a = shard.arrays["atoms"][r, c].ravel()
    vals = shard.arrays["values"][r, c].ravel()
    if shard.cell_format == "coo":
        v = shard.arrays["voxels"][r, c].ravel()
        f = shard.arrays["fibers"][r, c].ravel()
    else:
        rows_padded, width = shard.arrays["atoms"].shape[2:]
        rows = np.repeat(np.arange(rows_padded, dtype=np.int64), width)
        others = shard.arrays["others"][r, c].ravel()
        v, f = (rows, others) if out_dim == "voxel" else (others, rows)
    return a, v, f, vals


def dsc_reference(shard: ShardPhi, dictionary, w) -> np.ndarray:
    """y = M w over the stacked cell arrays (padding slots exercised)."""
    d = np.asarray(dictionary)
    w = np.asarray(w)
    y = np.zeros((shard.n_voxels, d.shape[1]), d.dtype)
    for r in range(shard.R):
        for c in range(shard.C):
            a, v, f, vals = _cell_operands(shard, r, c)
            # padding rows may exceed the global range; their values are 0,
            # so clipping the index keeps them inert without branching
            vg = np.minimum(v + shard.voxel_cuts[r], shard.n_voxels - 1)
            fg = np.minimum(f + shard.fiber_cuts[c], shard.n_fibers - 1)
            np.add.at(y, vg, d[a] * (w[fg] * vals)[:, None])
    return y


def wc_reference(shard: ShardPhi, dictionary, y) -> np.ndarray:
    """w = M^T y over the stacked cell arrays."""
    d = np.asarray(dictionary)
    y = np.asarray(y)
    w = np.zeros((shard.n_fibers,), d.dtype)
    for r in range(shard.R):
        for c in range(shard.C):
            a, v, f, vals = _cell_operands(shard, r, c)
            vg = np.minimum(v + shard.voxel_cuts[r], shard.n_voxels - 1)
            fg = np.minimum(f + shard.fiber_cuts[c], shard.n_fibers - 1)
            np.add.at(w, fg, (d[a] * y[vg]).sum(axis=1) * vals)
    return w
