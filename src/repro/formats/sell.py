"""SELL: sliced-ELL/blocked Phi layout for direct row-block accumulation.

The COO Pallas path (``kernels/dsc.py``/``wc.py`` over a ``TilePlan``) pays
two irregularity taxes inside the kernel: a scalar-prefetched ``row_block``
map drives the output BlockSpec, and the within-tile scatter is a one-hot
MXU matmul.  SELL removes both by moving the irregularity into the *layout*:

  * coefficients are sorted by the op's output dimension (voxel for DSC,
    fiber for WC — DESIGN.md §2) and laid out row-major: slot ``[r, s]``
    holds the ``s``-th coefficient of output row ``r``,
  * every row's run is padded to the common ``width`` (a ``slot_tile``
    multiple) with inert slots (value 0), and rows are padded to a
    ``row_tile`` multiple — so a ``(row_tile, slot_tile)`` block of the
    layout touches exactly the ``row_tile`` output rows of block ``i``,
    statically, with **no** prefetched row map and **no** one-hot matmul:
    the kernel reduces over the slot axis and accumulates straight into the
    output block (``kernels/dsc.py:dsc_sell_pallas``).

The price is padding: ``width`` is the max per-row run length rounded up,
so skewed row-degree distributions waste slots — exactly the format
trade-off :mod:`repro.formats.select` arbitrates with the run-length
statistics from ``core/inspector.py:phi_stats`` (Chen et al.
arXiv:1805.11938: no single format wins; pick per dataset).  Per-slice
widths (``slice_widths``) are kept for accounting: they are what a ragged
SELL-C-sigma would allocate, and the gap to the uniform width is reported
by ``benchmarks/table12_formats.py``.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import numpy as np

from repro.core.inspector import sell_geometry
from repro.core.std import PhiTensor
from repro.formats.base import OUTPUT_DIMS, register_format

DEFAULT_ROW_TILE = 8         # output rows per block (f32 sublane multiple)
DEFAULT_SLOT_TILE = 32       # slots consumed per kernel grid step


def _dims_for(op: str):
    """(output dim, other dim) index-vector names for an op."""
    out = OUTPUT_DIMS[op]
    return out, ("fiber" if out == "voxel" else "voxel")


@register_format
@dataclasses.dataclass
class SellPhi:
    """Blocked-ELL Phi for one op, dense ``(n_rows_padded, width)`` arrays.

    ``atoms``/``others``/``values``: slot ``[r, s]`` is the ``s``-th
    coefficient of output row ``r`` (``others`` is the non-output indirection
    vector: fibers for DSC, voxels for WC; padding slots hold index 0 and
    value 0 so they contribute nothing).  ``row_nnz`` is the exact per-row
    coefficient count — the decode mask and the padding audit.
    """

    name: ClassVar[str] = "sell"

    op: str                              # "dsc" | "wc"
    atoms: np.ndarray                    # int32 (n_rows_padded, width)
    others: np.ndarray                   # int32 (n_rows_padded, width)
    values: np.ndarray                   # fp    (n_rows_padded, width)
    row_nnz: np.ndarray                  # int32 (n_rows,)
    row_tile: int
    slot_tile: int
    n_atoms: int
    n_voxels: int
    n_fibers: int

    # -- encode / decode ------------------------------------------------------
    @classmethod
    def encode(cls, phi: PhiTensor, *, op: str = "dsc",
               row_tile: int = DEFAULT_ROW_TILE,
               slot_tile: int = DEFAULT_SLOT_TILE, **_params) -> "SellPhi":
        out_dim, other_dim = _dims_for(op)
        vec = {"atom": phi.atoms, "voxel": phi.voxels, "fiber": phi.fibers}
        out_ids = np.asarray(vec[out_dim], np.int64)
        n_rows = {"voxel": phi.n_voxels, "fiber": phi.n_fibers}[out_dim]
        nc = out_ids.size

        order = np.argsort(out_ids, kind="stable")
        out_sorted = out_ids[order]
        row_nnz = np.bincount(out_sorted, minlength=n_rows).astype(np.int32)
        max_nnz = int(row_nnz.max()) if nc else 0
        width, n_rows_padded = sell_geometry(max_nnz, n_rows,
                                             row_tile=row_tile,
                                             slot_tile=slot_tile)

        atoms = np.zeros((n_rows_padded, width), np.int32)
        others = np.zeros((n_rows_padded, width), np.int32)
        np_vals = np.asarray(phi.values)
        values = np.zeros((n_rows_padded, width), np_vals.dtype)
        if nc:
            row_start = np.zeros(n_rows + 1, np.int64)
            np.cumsum(row_nnz, out=row_start[1:])
            slot = np.arange(nc) - row_start[out_sorted]      # pos within row
            flat = out_sorted * width + slot
            atoms.reshape(-1)[flat] = np.asarray(phi.atoms, np.int32)[order]
            others.reshape(-1)[flat] = np.asarray(vec[other_dim], np.int32)[order]
            values.reshape(-1)[flat] = np_vals[order]
        return cls(op=op, atoms=atoms, others=others, values=values,
                   row_nnz=row_nnz, row_tile=row_tile, slot_tile=slot_tile,
                   n_atoms=phi.n_atoms, n_voxels=phi.n_voxels,
                   n_fibers=phi.n_fibers)

    def decode(self) -> PhiTensor:
        import jax.numpy as jnp
        out_dim, _ = _dims_for(self.op)
        width = self.atoms.shape[1]
        mask = (np.arange(width)[None, :]
                < self.row_nnz[:, None].astype(np.int64))      # (n_rows, W)
        rows = np.broadcast_to(
            np.arange(self.n_rows)[:, None], mask.shape)[mask]
        trimmed = slice(0, self.n_rows)
        atoms = self.atoms[trimmed][mask]
        others = self.others[trimmed][mask]
        values = self.values[trimmed][mask]
        out32 = rows.astype(np.int32)
        voxels, fibers = ((out32, others) if out_dim == "voxel"
                          else (others, out32))
        return PhiTensor(
            atoms=jnp.asarray(atoms), voxels=jnp.asarray(voxels),
            fibers=jnp.asarray(fibers), values=jnp.asarray(values),
            n_atoms=self.n_atoms, n_voxels=self.n_voxels,
            n_fibers=self.n_fibers)

    # -- geometry / accounting ------------------------------------------------
    @property
    def n_rows(self) -> int:
        return self.n_voxels if self.op == "dsc" else self.n_fibers

    @property
    def n_coeffs(self) -> int:
        return int(self.row_nnz.sum())

    @property
    def width(self) -> int:
        return self.atoms.shape[1]

    @property
    def n_row_blocks(self) -> int:
        return self.atoms.shape[0] // self.row_tile

    @property
    def n_chunks(self) -> int:
        return self.width // self.slot_tile

    @property
    def slice_widths(self) -> np.ndarray:
        """Per row-block width a ragged SELL-C-sigma would allocate
        (max row nnz in the slice, rounded up to the slot tile)."""
        padded = np.zeros(self.atoms.shape[0], np.int64)
        padded[: self.n_rows] = self.row_nnz
        per_slice = padded.reshape(-1, self.row_tile).max(axis=1)
        return -(-per_slice // self.slot_tile) * self.slot_tile

    @property
    def nbytes(self) -> int:
        return int(self.atoms.nbytes + self.others.nbytes + self.values.nbytes
                   + self.row_nnz.nbytes)

    @property
    def padding_overhead(self) -> float:
        """Allocated slots / real coefficients - 1 over the dense layout."""
        slots = self.atoms.size
        return slots / max(1, self.n_coeffs) - 1.0


# ----------------------------------------------------------------------------
# Pure-jnp reference executors over the SELL layout.  Same dataflow as the
# Pallas kernels (kernels/dsc.py:dsc_sell_pallas) minus the blocking: the
# test oracle for the kernels, and the measurement proxy formats/select.py
# times when arbitrating formats (off-TPU the kernels run in interpret mode,
# whose timing says nothing about the layout).
# ----------------------------------------------------------------------------

def dsc_reference(sell: SellPhi, dictionary, w):
    """y = M w over the SELL layout: per-row slot reduction, no scatter."""
    import jax.numpy as jnp
    atoms = jnp.asarray(sell.atoms)
    fibers = jnp.asarray(sell.others)              # DSC: others = fibers
    values = jnp.asarray(sell.values)
    scaled = jnp.take(w, fibers) * values          # (rows_padded, W)
    contrib = jnp.take(dictionary, atoms, axis=0) * scaled[..., None]
    return contrib.sum(axis=1)[: sell.n_voxels]    # (Nv, Ntheta)


def wc_reference(sell: SellPhi, dictionary, y):
    """w = M^T y over the SELL layout: per-row dot accumulation."""
    import jax.numpy as jnp
    atoms = jnp.asarray(sell.atoms)
    voxels = jnp.asarray(sell.others)              # WC: others = voxels
    values = jnp.asarray(sell.values)
    yg = jnp.take(y, voxels, axis=0)               # (rows_padded, W, Ntheta)
    dots = (jnp.take(dictionary, atoms, axis=0) * yg).sum(-1) * values
    return dots.sum(axis=1)[: sell.n_fibers]       # (Nf,)
