"""F-COO: one sorted, segment-flagged Phi linearization serving BOTH ops.

Every other layout in this package is per-op: SELL encodes a voxel-row copy
for DSC and a fiber-row copy for WC, doubling resident bytes per tenant.
F-COO (Liu et al., arXiv:1705.09905) keeps *one* flat coefficient stream and
moves the per-op irregularity into segment metadata:

  * coefficients are lexsorted once, voxel-major ``(voxel, fiber, atom)`` —
    the DSC order — and padded to a ``c_tile`` multiple with inert slots
    (value 0, indices repeating the last real coefficient),
  * the WC (fiber-major) view is a stable permutation ``wc_perm`` over the
    same stream — no second copy of the index/value arrays,
  * for each op the stream is cut into fixed ``c_tile`` chunks; within a
    chunk, runs of equal output ids form *segments*.  The segment flags
    (``ids[i] != ids[i-1]``, chunk-local) are stored prefix-summed as
    per-slot segment ranks (``dsc_ranks`` / ``wc_ranks``), and a small
    ``(n_chunks, K)`` map (``seg_rows_*``) names each segment's output row
    (padding segments point at a dummy row one past the end).

The kernel pair (:mod:`repro.kernels.fcoo`) turns each chunk's segment
reduction into a one-hot ``(K, c_tile)`` MXU matmul and writes per-chunk
segment partials; a single batched scatter-add over ``seg_rows_*`` folds
chunk boundaries (a run split across chunks becomes two segments that land
on the same output row).  Because every chunk owns its own output block,
the grid needs no cross-step accumulation at all — the F-COO analogue of
the paper's synchronization-free reduction.

Accounting is fully honest: ``nbytes`` counts every array the executor
keeps resident (stream + wc_perm + both rank vectors + both segment maps).
That is ~28 B/coefficient versus the two SELL copies' padded slot arrays —
``benchmarks/table12_formats.py`` reports the ratio and
``benchmarks/check_regression.py`` gates it at 0.6x.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Tuple

import numpy as np

from repro.core.std import PhiTensor
from repro.formats.base import register_format

DEFAULT_C_TILE = 256          # coefficients per chunk (grid step)
DEFAULT_SEG_TILE = 16         # K (segments per chunk) rounds up to this


def chunk_segment_map(ids: np.ndarray, c_tile: int, seg_tile: int,
                      dummy_row: int) -> Tuple[np.ndarray, np.ndarray, int]:
    """Segment metadata for one op over a padded id stream.

    ``ids``: int array, ``ids.size % c_tile == 0`` — the output ids of the
    (already linearized) coefficient stream.  Returns
    ``(seg_rows, ranks, k)``:

      * ``ranks`` (int32, like ``ids``): chunk-local segment index of every
        slot — the prefix sum of the segment flags
        ``flag[i] = ids[i] != ids[i-1]`` with the flag reset at each chunk
        boundary (this IS the segment-scan primitive, host-side),
      * ``seg_rows`` (int32 ``(n_chunks, k)``): segment -> output row;
        entries past a chunk's last segment hold ``dummy_row``,
      * ``k``: max segments in any chunk, rounded up to ``seg_tile``.

    Correctness does not require ``ids`` to be sorted — an unsorted stream
    just fragments into more segments (larger ``k``); the scatter over
    ``seg_rows`` lands every segment on its own row regardless.
    """
    if ids.size % c_tile:
        raise ValueError(f"ids.size={ids.size} not a c_tile={c_tile} multiple")
    n_chunks = ids.size // c_tile
    if n_chunks == 0:
        return (np.zeros((0, seg_tile), np.int32),
                np.zeros((0,), np.int32), seg_tile)
    ids2 = np.asarray(ids).reshape(n_chunks, c_tile)
    flags = np.zeros((n_chunks, c_tile), np.int32)
    flags[:, 1:] = ids2[:, 1:] != ids2[:, :-1]
    ranks = np.cumsum(flags, axis=1, dtype=np.int32)
    max_segs = int(ranks[:, -1].max()) + 1
    k = -(-max_segs // seg_tile) * seg_tile
    seg_rows = np.full((n_chunks, k), dummy_row, np.int32)
    seg_rows[np.repeat(np.arange(n_chunks), c_tile),
             ranks.reshape(-1)] = ids2.reshape(-1)
    return seg_rows, ranks.reshape(-1), k


@register_format
@dataclasses.dataclass
class FcooPhi:
    """One resident F-COO linearization serving DSC and WC.

    ``atoms``/``voxels``/``fibers``/``values``: the padded stream in DSC
    (voxel-major) order.  ``wc_perm`` re-reads the same stream fiber-major.
    ``dsc_ranks``/``wc_ranks`` are the per-slot chunk-local segment ranks,
    ``seg_rows_dsc``/``seg_rows_wc`` the segment -> output-row maps (dummy
    rows ``n_voxels`` / ``n_fibers`` absorb padding segments and are
    trimmed by the combine).
    """

    name: ClassVar[str] = "fcoo"

    atoms: np.ndarray                    # int32 (Ncp,)
    voxels: np.ndarray                   # int32 (Ncp,)
    fibers: np.ndarray                   # int32 (Ncp,)
    values: np.ndarray                   # fp    (Ncp,)
    wc_perm: np.ndarray                  # int32 (Ncp,) fiber-major view
    dsc_ranks: np.ndarray                # int32 (Ncp,)
    wc_ranks: np.ndarray                 # int32 (Ncp,)
    seg_rows_dsc: np.ndarray             # int32 (n_chunks, k_dsc)
    seg_rows_wc: np.ndarray              # int32 (n_chunks, k_wc)
    c_tile: int
    seg_tile: int
    n_coeffs: int                        # real (unpadded) coefficient count
    n_atoms: int
    n_voxels: int
    n_fibers: int

    # -- encode / decode ------------------------------------------------------
    @classmethod
    def encode(cls, phi: PhiTensor, *, op: str = "dsc",
               c_tile: int = DEFAULT_C_TILE,
               seg_tile: int = DEFAULT_SEG_TILE, **_params) -> "FcooPhi":
        """Linearize once; ``op`` is accepted for protocol uniformity and
        ignored — the whole point is that one encode serves both ops."""
        a = np.asarray(phi.atoms, np.int64)
        v = np.asarray(phi.voxels, np.int64)
        f = np.asarray(phi.fibers, np.int64)
        vals = np.asarray(phi.values)
        nc = a.size
        # total order up to identical triples: any input permutation of the
        # coefficients linearizes to the same layout (property-tested)
        order = np.lexsort((a, f, v))
        ncp = -(-nc // c_tile) * c_tile

        def lay(x, fill):
            out = np.empty(ncp, np.int32)
            out[:nc] = x[order]
            out[nc:] = fill
            return out

        atoms = lay(a, a[order[-1]] if nc else 0)
        voxels = lay(v, v[order[-1]] if nc else 0)
        fibers = lay(f, f[order[-1]] if nc else 0)
        values = np.zeros(ncp, vals.dtype)
        if nc:
            values[:nc] = vals[order]
        # fiber-major view over the SAME stream (stable: voxel-major within
        # a fiber); padding slots repeat the last real fiber id, so they
        # merge into its final segment and stay inert (value 0)
        wc_perm = np.argsort(fibers, kind="stable").astype(np.int32)
        seg_rows_dsc, dsc_ranks, _ = chunk_segment_map(
            voxels, c_tile, seg_tile, phi.n_voxels)
        seg_rows_wc, wc_ranks, _ = chunk_segment_map(
            fibers[wc_perm], c_tile, seg_tile, phi.n_fibers)
        return cls(atoms=atoms, voxels=voxels, fibers=fibers, values=values,
                   wc_perm=wc_perm, dsc_ranks=dsc_ranks, wc_ranks=wc_ranks,
                   seg_rows_dsc=seg_rows_dsc, seg_rows_wc=seg_rows_wc,
                   c_tile=c_tile, seg_tile=seg_tile, n_coeffs=nc,
                   n_atoms=phi.n_atoms, n_voxels=phi.n_voxels,
                   n_fibers=phi.n_fibers)

    def decode(self) -> PhiTensor:
        import jax.numpy as jnp
        nc = self.n_coeffs
        return PhiTensor(
            atoms=jnp.asarray(self.atoms[:nc]),
            voxels=jnp.asarray(self.voxels[:nc]),
            fibers=jnp.asarray(self.fibers[:nc]),
            values=jnp.asarray(self.values[:nc]),
            n_atoms=self.n_atoms, n_voxels=self.n_voxels,
            n_fibers=self.n_fibers)

    # -- geometry / accounting ------------------------------------------------
    @property
    def n_chunks(self) -> int:
        return self.atoms.size // self.c_tile if self.c_tile else 0

    @property
    def k_dsc(self) -> int:
        return self.seg_rows_dsc.shape[1]

    @property
    def k_wc(self) -> int:
        return self.seg_rows_wc.shape[1]

    @property
    def nbytes(self) -> int:
        """Every array the executor keeps resident — stream, WC view
        permutation, both rank vectors, both segment maps.  Nothing is
        excluded: this is the number the 0.6x-of-SELL gate holds."""
        return int(self.atoms.nbytes + self.voxels.nbytes
                   + self.fibers.nbytes + self.values.nbytes
                   + self.wc_perm.nbytes + self.dsc_ranks.nbytes
                   + self.wc_ranks.nbytes + self.seg_rows_dsc.nbytes
                   + self.seg_rows_wc.nbytes)

    @property
    def padding_overhead(self) -> float:
        """Padded slots / real coefficients - 1 (tail padding only)."""
        return self.atoms.size / max(1, self.n_coeffs) - 1.0


# ----------------------------------------------------------------------------
# Pure-jnp reference executors over the F-COO layout.  Same dataflow as the
# Pallas kernels (kernels/fcoo.py) minus the chunking: the test oracle, and
# the measurement proxy formats/select.py times when arbitrating formats.
# ----------------------------------------------------------------------------

def dsc_reference(fc: FcooPhi, dictionary, w):
    """y = M w over the linearized stream (padding slots carry value 0)."""
    import jax.numpy as jnp
    if fc.atoms.size == 0:
        return jnp.zeros((fc.n_voxels, dictionary.shape[1]),
                         dictionary.dtype)
    atoms = jnp.asarray(fc.atoms)
    voxels = jnp.asarray(fc.voxels)
    scaled = jnp.take(w, jnp.asarray(fc.fibers)) * jnp.asarray(fc.values)
    contrib = jnp.take(dictionary, atoms, axis=0) * scaled[:, None]
    y = jnp.zeros((fc.n_voxels, dictionary.shape[1]), contrib.dtype)
    return y.at[voxels].add(contrib)


def wc_reference(fc: FcooPhi, dictionary, y):
    """w = M^T y over the same resident stream."""
    import jax.numpy as jnp
    if fc.atoms.size == 0:
        return jnp.zeros((fc.n_fibers,), dictionary.dtype)
    atoms = jnp.asarray(fc.atoms)
    voxels = jnp.asarray(fc.voxels)
    dots = (jnp.take(dictionary, atoms, axis=0)
            * jnp.take(y, voxels, axis=0)).sum(-1) * jnp.asarray(fc.values)
    w = jnp.zeros((fc.n_fibers,), dots.dtype)
    return w.at[jnp.asarray(fc.fibers)].add(dots)
