"""granite-34b — dense code model (gpt_bigcode-style), 88L d6144 48H
(MQA kv=1) ff24576 vocab 49152; learned positions, LayerNorm, GELU MLP.
[arXiv:2405.04324; hf]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    rope="learned", norm="layer", mlp="gelu", max_seq_len=8192,
))
