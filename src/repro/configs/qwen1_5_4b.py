"""qwen1.5-4b — dense with QKV bias, 40L d2560 20H (GQA kv=20) ff6912
vocab 151936.  [hf:Qwen/Qwen1.5 family; hf]

20 heads don't divide the 16-way model axis: attention shards on head_dim
instead (DESIGN.md §4 sharding notes)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
    d_ff=6912, vocab_size=151936, qkv_bias=True, rope_theta=5e6,
))
