"""kimi-k2-1t-a32b — trillion-param MoE: 61L d7168 64H (GQA kv=8),
MoE 384 experts top-8 with expert ff2048 + 1 shared expert, first layer
dense, vocab 163840.  [paper-table; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=18432, vocab_size=163840,
    n_experts=384, top_k=8, moe_d_ff=2048,
    n_shared_experts=1, first_k_dense=1,
))
