"""Architecture config schema + registry + shape suite.

Every assigned architecture ships as `src/repro/configs/<id>.py` exporting
CONFIG (exact published geometry) and registering itself.  `reduced()`
derives a CPU-smoke-testable variant of the same family.  `input_specs()`
produces ShapeDtypeStruct stand-ins per input shape for the dry-run (no
allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

SHAPES = {
    # name: (seq_len, global_batch, step kind)
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

ARCH_IDS = (
    "deepseek-7b", "stablelm-12b", "qwen1.5-4b", "granite-34b",
    "zamba2-1.2b", "musicgen-large", "qwen2-vl-7b",
    "phi3.5-moe-42b-a6.6b", "kimi-k2-1t-a32b", "mamba2-2.7b",
    "life-stn96",
)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope: str = "rope"               # rope | mrope | sinusoidal | learned
    rope_theta: float = 1e4
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    norm: str = "rms"
    mlp: str = "swiglu"
    tie_embeddings: bool = False
    max_seq_len: int = 8192          # learned-position table size
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1
    attn_every: int = 0              # hybrid: shared attn+mlp block period
    # modality frontends (stubs: input_specs provide embeddings)
    n_codebooks: int = 0             # audio (EnCodec streams)
    vision_tokens: int = 0           # vlm: image patch embeddings per sample
    # numerics / runtime
    dtype: str = "bfloat16"
    remat: bool = True

    # -- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def jnp_dtype(self):
        return getattr(jnp, self.dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def supports(self, shape: str) -> bool:
        """Which of the input shapes this arch runs (skips documented in
        DESIGN.md §4: long_500k needs sub-quadratic attention)."""
        if shape == "long_500k":
            return self.sub_quadratic
        return True

    def param_count(self) -> int:
        """Analytic parameter count (drives MODEL_FLOPS in the roofline)."""
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        n = 0
        if self.family in ("dense", "moe", "audio", "vlm"):
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d
            n += L * attn
        if self.family in ("dense", "audio", "vlm"):
            ff = d * self.d_ff * (3 if self.mlp == "swiglu" else 2)
            n += L * ff
        if self.family == "moe":
            ff_moe = 3 * d * self.moe_d_ff
            dense_layers = self.first_k_dense
            moe_layers = L - dense_layers
            n += moe_layers * (self.n_experts * ff_moe + d * self.n_experts)
            n += moe_layers * self.n_shared_experts * ff_moe
            n += dense_layers * 3 * d * self.d_ff
        if self.family in ("ssm", "hybrid"):
            gn = self.ssm_groups * self.ssm_state
            per = d * (2 * self.d_inner + 2 * gn + self.ssm_heads) \
                + self.d_inner * d
            n += L * per
        if self.family == "hybrid" and self.attn_every:
            n += d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d + 3 * d * self.d_ff
        if self.n_codebooks:
            n += self.n_codebooks * self.vocab_size * d       # heads
            n += self.vocab_size * d                          # embed (stub side)
        elif self.vocab_size:
            n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(n)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        attn = L * (d * hd * (self.n_heads + 2 * self.n_kv_heads)
                    + self.n_heads * hd * d)
        ff_moe = 3 * d * self.moe_d_ff
        moe_layers = L - self.first_k_dense
        act = attn + moe_layers * ((self.top_k + self.n_shared_experts) * ff_moe
                                   + d * self.n_experts)
        act += self.first_k_dense * 3 * d * self.d_ff
        act += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(act)


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        importlib.import_module(
            "repro.configs." + name.replace("-", "_").replace(".", "_"))
    return _REGISTRY[name]


def reduced(cfg: ArchConfig, *, n_layers: int = 2, d_model: int = 64,
            vocab: int = 128) -> ArchConfig:
    """Small same-family variant for CPU smoke tests."""
    kw: Dict[str, Any] = dict(
        name=cfg.name + "-reduced", n_layers=n_layers, d_model=d_model,
        vocab_size=min(cfg.vocab_size, vocab) if cfg.vocab_size else 0,
        max_seq_len=256, dtype="float32", remat=False,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=max(1, 4 * cfg.n_kv_heads // max(cfg.n_heads, 1)),
                  head_dim=16)
    if cfg.d_ff:
        kw.update(d_ff=4 * d_model)
    if cfg.n_experts:
        # capacity_factor = n_experts => drop-free routing, so the
        # prefill/decode == forward consistency tests are exact
        kw.update(n_experts=4, top_k=min(2, cfg.top_k), moe_d_ff=2 * d_model,
                  n_shared_experts=min(1, cfg.n_shared_experts),
                  first_k_dense=min(1, cfg.first_k_dense),
                  capacity_factor=4.0)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16)
    if cfg.attn_every:
        kw.update(attn_every=2)
    if cfg.n_codebooks:
        kw.update(n_codebooks=cfg.n_codebooks)
    if cfg.vision_tokens:
        kw.update(vision_tokens=16)
    return dataclasses.replace(cfg, **kw)


# ----------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no device allocation)
# ----------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: str,
                overrides: Optional[Dict[str, int]] = None) -> Dict[str, Any]:
    """Batch specs for `shape` (see SHAPES).  For decode shapes this is the
    serve_step batch (one new token + KV/SSM cache of seq_len)."""
    seq, batch, kind = SHAPES[shape]
    if overrides:
        seq = overrides.get("seq_len", seq)
        batch = overrides.get("global_batch", batch)
    f = lambda s, dt: jax.ShapeDtypeStruct(s, dt)
    i32, dt = jnp.int32, cfg.jnp_dtype
    if kind == "train":
        return _train_batch(cfg, batch, seq, f, i32, dt)
    if kind == "prefill":
        return _prefill_batch(cfg, batch, seq, f, i32, dt)
    return _decode_batch(cfg, batch, seq, f, i32, dt)


def _train_batch(cfg, batch, seq, f, i32, dt):
    if cfg.family == "audio":
        return dict(frame_embeds=f((batch, seq, cfg.d_model), dt),
                    codes=f((batch, seq, cfg.n_codebooks), i32))
    if cfg.family == "vlm":
        vt = cfg.vision_tokens
        return dict(tokens=f((batch, seq - vt), i32),
                    image_embeds=f((batch, vt, cfg.d_model), dt),
                    positions=f((3, batch, seq), i32),
                    labels=f((batch, seq), i32))
    return dict(tokens=f((batch, seq), i32), labels=f((batch, seq), i32))


def _prefill_batch(cfg, batch, seq, f, i32, dt):
    b = _train_batch(cfg, batch, seq, f, i32, dt)
    b.pop("labels", None)
    b.pop("codes", None)
    return b


def _decode_batch(cfg, batch, seq, f, i32, dt):
    """One new token + caches filled to seq tokens."""
    batch_specs: Dict[str, Any] = dict(
        cache_index=f((), i32))
    if cfg.family == "audio":
        batch_specs["frame_embeds"] = f((batch, 1, cfg.d_model), dt)
    else:
        batch_specs["tokens"] = f((batch, 1), i32)
    if cfg.family == "vlm":
        batch_specs["positions"] = f((3, batch, 1), i32)
    batch_specs["cache"] = cache_specs(cfg, batch, seq, f, dt)
    return batch_specs


def cache_specs(cfg, batch, seq, f, dt):
    hd = cfg.resolved_head_dim
    cache: Dict[str, Any] = {}
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        cache["k"] = f((cfg.n_layers, batch, seq, cfg.n_kv_heads, hd), dt)
        cache["v"] = f((cfg.n_layers, batch, seq, cfg.n_kv_heads, hd), dt)
    if cfg.family in ("ssm", "hybrid"):
        gn = cfg.ssm_groups * cfg.ssm_state
        c_tot = cfg.d_inner + 2 * gn
        cache["ssm"] = f((cfg.n_layers, batch, cfg.ssm_heads,
                          cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        cache["conv"] = f((cfg.n_layers, batch, cfg.ssm_conv - 1, c_tot), dt)
    if cfg.family == "hybrid" and cfg.attn_every:
        n_apps = sum(1 for i in range(cfg.n_layers)
                     if i % cfg.attn_every == cfg.attn_every - 1)
        cache["k"] = f((n_apps, batch, seq, cfg.n_kv_heads, hd), dt)
        cache["v"] = f((n_apps, batch, seq, cfg.n_kv_heads, hd), dt)
    return cache
