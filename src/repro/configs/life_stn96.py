"""life-stn96 — the paper's own application: LiFE/SBBNNLS over an STN96-like
connectome (Ntheta=96).  Not an LM; `supports()` is irrelevant — the LiFE
dry-run lowers the SBBNNLS iteration over the 2-D (voxel x fiber) mesh
partition instead of train/serve steps (launch/dryrun.py special-cases it)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="life-stn96", family="life",
    n_layers=0, d_model=96,          # d_model doubles as Ntheta
))
