"""musicgen-large — decoder-only over EnCodec tokens: 48L d2048 32H (MHA)
ff8192, 4 codebooks x vocab 2048, sinusoidal positions.  [arXiv:2306.05284]

Backbone only: the EnCodec frontend is a stub — input_specs provide
precomputed frame embeddings; text cross-attention conditioning omitted
(DESIGN.md §4)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048, n_codebooks=4,
    rope="sinusoidal", norm="layer", mlp="gelu",
))
