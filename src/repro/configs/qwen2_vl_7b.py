"""qwen2-vl-7b — VLM backbone: 28L d3584 28H (GQA kv=4) ff18944 vocab
152064, M-RoPE.  [arXiv:2409.12191; hf]

Backbone only: the dynamic-resolution ViT is a stub — input_specs provide
precomputed patch embeddings + 3-D (t,h,w) position ids."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064, qkv_bias=True,
    rope="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
    vision_tokens=1024,
))
