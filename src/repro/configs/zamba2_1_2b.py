"""zamba2-1.2b — hybrid: 38 Mamba2 layers (d2048, ssm_state 64) + a shared
attention+MLP block (32H kv=32, ff8192) applied every 6 layers with separate
KV caches per application.  [arXiv:2411.15242; hf]

Simplification noted in DESIGN.md: the shared block reuses one weight set
(as Zamba2 does) but omits the per-application LoRA deltas and the
concat-with-embedding input path."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, attn_every=6,
))
