"""mamba2-2.7b — attention-free SSD: 64L d2560, ssm_state 128, head_dim 64,
expand 2 (80 ssm heads), vocab 50280.  [arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
))
