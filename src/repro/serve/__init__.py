"""Multi-tenant LiFE serving subsystem (DESIGN.md §8, §13).

Turns the three engines and two caches of the preceding layers into a
service: jobs arrive continuously, compatible subjects are micro-batched
through :class:`~repro.core.batched.BatchedLifeEngine`, long solves are
time-sliced fairly across tenants through the stepped SBBNNLS API, and every
in-flight solver state survives a kill via :mod:`repro.checkpoint.manager`.

:class:`~repro.serve.frontend.LifeFrontend` is the traffic-facing front
line: async submission (``submit_async`` → :class:`JobHandle`), a bounded
admission queue with configurable backpressure, per-job failure isolation
(one bad tenant fails alone, batch-mates keep running), and graceful
drain-and-checkpoint shutdown.
"""
from repro.serve.frontend import (BACKPRESSURE_POLICIES, AdmissionQueueFull,
                                  JobHandle, LifeFrontend, ShutdownError)
from repro.serve.scheduler import (BATCHABLE_FORMATS, Job, JobCancelledError,
                                   JobFailedError, Scheduler, dataset_key)
from repro.serve.service import LifeService

__all__ = ["AdmissionQueueFull", "BACKPRESSURE_POLICIES",
           "BATCHABLE_FORMATS", "Job", "JobCancelledError", "JobFailedError",
           "JobHandle", "LifeFrontend", "LifeService", "Scheduler",
           "ShutdownError", "dataset_key"]
