"""Multi-tenant LiFE serving subsystem (DESIGN.md §8).

Turns the three engines and two caches of the preceding layers into a
service: jobs arrive continuously, compatible subjects are micro-batched
through :class:`~repro.core.batched.BatchedLifeEngine`, long solves are
time-sliced fairly across tenants through the stepped SBBNNLS API, and every
in-flight solver state survives a kill via :mod:`repro.checkpoint.manager`.
"""
from repro.serve.scheduler import (BATCHABLE_FORMATS, Job, Scheduler,
                                   dataset_key)
from repro.serve.service import LifeService

__all__ = ["BATCHABLE_FORMATS", "Job", "LifeService", "Scheduler",
           "dataset_key"]
