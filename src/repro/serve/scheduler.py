"""Job queue + micro-batch scheduler for multi-tenant LiFE solves.

The serving problem (DESIGN.md §8): SBBNNLS solves run for hundreds of
iterations, subjects arrive continuously, and the hardware is best used
batched — so the scheduler must (a) group compatible subjects into one
vmapped computation, (b) admit late arrivals without restarting anyone, and
(c) share the device fairly between tenants with different priorities and
deadlines.  All three reduce to the stepped solver API
(:func:`repro.core.sbbnnls.sbbnnls_steps`): state in -> k iterations ->
state out, with the Barzilai-Borwein parity riding in the state, so slicing
and re-batching never change the trajectory.

Bucketing policy
----------------
A job lands in the bucket keyed by its *batch-compatibility class*:

  (Nv, Nf, Ntheta, dictionary digest, format, tune mode, compute dtype)

Tuning settings are part of the class (DESIGN.md §10.4): jobs tuned
differently must not share a micro-batch — a bf16-storage job stacked with
an fp32 job would silently run one of them under the other's numerics, and
a tune="full" job batched with tune="off" would either skip a requested
search or impose an unrequested one.

Jobs in one bucket can be stacked into a single
:class:`~repro.core.batched.BatchedLifeEngine` (same geometry, same shared
dictionary; coefficient counts may differ — the engine pads).  The key uses
the *requested* format: jobs asking for the same vmappable format
(``BATCHABLE_FORMATS``: coo, alto, or "auto" — which resolves inside the
batched engine) share one bucket engine, while an "auto" job and an
explicit "coo" job stay in separate buckets even when selection would pick
coo (resolving at submit would mean running format selection on the intake
path).  SELL's per-subject static slot shapes cannot stack, so
``format="sell"`` jobs get solo buckets running a
:class:`~repro.core.life.LifeEngine` behind the same stepped interface;
``format="fcoo"`` is solo for the same reason (per-subject static chunk
and segment-map shapes).

Continuous batching
-------------------
Bucket membership is re-evaluated every tick: queued arrivals are admitted,
finished jobs leave, and the bucket engine is rebuilt only when the member
set changed.  Rebuilds are cheap by construction — every inspector product
(FormatPlan, autotune choice, tile plan) is content-addressed in the shared
:class:`~repro.core.plan_cache.PlanCache`, so re-batching the same datasets
hits the cache rather than re-running selection.  Solver states are carried
over verbatim: a subject that already ran 80 iterations keeps its weights
and parity when a newcomer joins the stack.

Time-slicing
------------
Each ``tick()`` serves the most urgent bucket for at most ``slice_iters``
iterations: earliest deadline first, then highest priority, then the bucket
that has been served least (so starvation is bounded by the slice length).

Mesh slices
-----------
A job may request a device-mesh slice (``Job.mesh = (R, C)``): its solve
runs on the sharded executor for its format — resolved from the registry's
``mesh=``/``consumes=`` metadata (``shard`` for coo, ``shard-sell`` for
sell).  Mesh jobs name their cell format explicitly: ``format="auto"``
would make the executed topology depend on a selection the intake path
never ran, so it is rejected at submit rather than resolved inconsistently.
Mesh jobs get solo buckets keyed by their topology: the mesh is a per-job
placement, and the sharded operand layouts are per-subject static shapes
that cannot stack under vmap.  ``submit`` validates the slice fits the
available devices, and the per-bucket engine config threads
``shard_rows``/``shard_cols`` through so plan-cache keys (which include the
mesh shape and device count) hit on re-buckets of the same topology.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.batched import BatchedLifeEngine
from repro.core.life import LifeConfig, LifeEngine
from repro.core.registry import REGISTRY
from repro.core.plan_cache import PlanCache
from repro.core.sbbnnls import SbbnnlsState, sbbnnls_init
from repro.data.dmri import LifeProblem

#: formats whose stacked operands run under vmap — eligible for shared
#: micro-batch buckets ("auto" restricts itself to the vmappable subset
#: inside BatchedLifeEngine; SELL widths are per-subject static shapes)
BATCHABLE_FORMATS = ("auto", "coo", "alto")

_SOLO_FORMATS = ("sell", "fcoo")

#: statuses a job never leaves (failure isolation, DESIGN.md §13.3)
TERMINAL_STATUSES = ("done", "failed", "cancelled")


class JobFailedError(RuntimeError):
    """Raised when a result is read off a job whose solve failed.

    The executor's original exception is both chained (``__cause__``) and
    carried on ``.error`` so clients on the async front line can retrieve
    it from the handle without parsing the message."""

    def __init__(self, job_id: str, error: BaseException):
        super().__init__(f"job {job_id!r} failed: {error!r}")
        self.job_id = job_id
        self.error = error


class JobCancelledError(RuntimeError):
    """Raised when a result is read off a cancelled job."""

    def __init__(self, job_id: str):
        super().__init__(f"job {job_id!r} was cancelled")
        self.job_id = job_id


def _is_solo(fmt: str, mesh: Optional[Tuple[int, int]]) -> bool:
    """Solo-bucket predicate: SELL operands cannot stack under vmap, and a
    mesh slice is a per-job placement — either way the job never shares an
    engine.  Single definition for both the bucket key and the bucket."""
    return fmt in _SOLO_FORMATS or mesh is not None


def dataset_key(problem: LifeProblem) -> str:
    """Content digest of one subject's full dataset (Phi + signal + dict).

    Two submissions with byte-identical data share the digest; any change —
    different seed, compaction, new acquisition — misses cleanly.  The
    service uses it to (a) verify a resumed job is being re-attached to the
    same data and (b) key FormatPlan/plan-cache reuse across requests.
    """
    h = hashlib.sha256()
    phi = problem.phi
    h.update(np.int64([phi.n_atoms, phi.n_voxels, phi.n_fibers]).tobytes())
    for arr in (phi.atoms, phi.voxels, phi.fibers):
        h.update(np.ascontiguousarray(np.asarray(arr), np.int64).tobytes())
    for arr in (phi.values, problem.b, problem.dictionary):
        h.update(np.ascontiguousarray(np.asarray(arr), np.float64).tobytes())
    return h.hexdigest()[:16]


def _dict_digest(problem: LifeProblem) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(np.asarray(problem.dictionary),
                             np.float64).tobytes()).hexdigest()[:16]


@dataclasses.dataclass
class Job:
    """One tenant's solve request plus its in-flight progress."""

    job_id: str
    problem: LifeProblem
    n_iters: int
    priority: int = 0                     # higher runs sooner (tie-break)
    deadline: Optional[float] = None      # absolute time.monotonic() seconds
    format: str = "auto"
    # (R, C) device-mesh slice request; None = single-device engines.
    # Mesh jobs run the sharded executor for their format in a solo bucket.
    mesh: Optional[Tuple[int, int]] = None
    # kernel-autotuning knobs (None = inherit the scheduler config at
    # submit); both are part of the batch-compatibility class — jobs tuned
    # differently never share a micro-batch (DESIGN.md §10.4)
    tune: Optional[str] = None            # "off" | "cached" | "full"
    compute_dtype: Optional[str] = None   # "fp32" | "bf16" | "auto"
    # warm-start weights (Nf,): the solver starts from sbbnnls_init(w0)
    # instead of all-ones — the repeat-visit path for Phi-delta
    # resubmissions and virtual lesions (DESIGN.md §15.3).  Not part of
    # the batch-compatibility class: states are initialized per job, so
    # warm and cold jobs share a micro-batch freely.
    w0: Optional[np.ndarray] = None
    # None = unset (stamped at submit); 0.0 is a legitimate monotonic time
    submitted_at: Optional[float] = None
    # -- progress (owned by the scheduler) --------------------------------
    state: Optional[SbbnnlsState] = None
    done: int = 0                         # iterations completed
    losses: List[np.ndarray] = dataclasses.field(default_factory=list)
    status: str = "queued"    # queued | running | done | failed | cancelled
    dataset: str = ""                     # content digest, set on submit
    dict_digest: str = ""                 # dictionary digest (bucket key part)
    finished_at: Optional[float] = None
    # seconds spent in previous service incarnations (restored on resume);
    # end-to-end latency = prior_elapsed + (finished_at - submitted_at)
    prior_elapsed: float = 0.0
    # the exception that failed this job (status == "failed")
    error: Optional[BaseException] = None

    @property
    def remaining(self) -> int:
        return max(0, self.n_iters - self.done)

    def result(self) -> Tuple[jnp.ndarray, np.ndarray]:
        """(final weights (Nf,), per-iteration loss trace)."""
        if self.status == "failed":
            assert self.error is not None
            raise JobFailedError(self.job_id, self.error) from self.error
        if self.status == "cancelled":
            raise JobCancelledError(self.job_id)
        if self.state is None:
            raise RuntimeError(f"job {self.job_id!r} has not run yet")
        losses = (np.concatenate(self.losses) if self.losses
                  else np.zeros((0,)))
        return self.state.w, losses


class _Bucket:
    """Jobs sharing one batch-compatibility class + their cached engine."""

    def __init__(self, key: Tuple, fmt: str, arrival: int,
                 mesh: Optional[Tuple[int, int]] = None,
                 tune: str = "off", compute_dtype: str = "fp32"):
        self.key = key
        self.format = fmt
        self.mesh = mesh
        self.tune = tune
        self.compute_dtype = compute_dtype
        self.solo = _is_solo(fmt, mesh)
        self.jobs: List[Job] = []
        self.iters_served = 0             # virtual time for fairness
        self.arrival = arrival
        self._engine = None
        self._engine_sig: Optional[Tuple[str, ...]] = None

    # -- urgency ordering --------------------------------------------------
    def urgency(self) -> Tuple:
        deadline = min((j.deadline for j in self.jobs
                        if j.deadline is not None), default=float("inf"))
        priority = max(j.priority for j in self.jobs)
        return (deadline, -priority, self.iters_served, self.arrival)

    # -- engine construction (memoized on the member set) ------------------
    def _config(self, base: LifeConfig) -> LifeConfig:
        cfg = dataclasses.replace(base, format=self.format, tune=self.tune,
                                  compute_dtype=self.compute_dtype)
        if self.mesh is not None:
            R, C = self.mesh
            # submit validated the format has a mesh executor
            cfg = dataclasses.replace(
                cfg, shard_rows=R, shard_cols=C,
                executor=REGISTRY.mesh_executor_for(self.format))
        return cfg

    def engine(self, base: LifeConfig, cache: PlanCache):
        sig = tuple(j.job_id for j in self.jobs)
        if self._engine is None or self._engine_sig != sig:
            cfg = self._config(base)
            if self.solo:
                self._engine = LifeEngine(self.jobs[0].problem, cfg, cache)
            else:
                self._engine = BatchedLifeEngine(
                    [j.problem for j in self.jobs], cfg, cache)
            self._engine_sig = sig
        # pin the searched dtype the moment it resolves: engine rebuilds
        # (member churn) and checkpoint manifests must see the numerics
        # that actually ran, not the open "auto" request — a re-search
        # after plan-cache eviction could otherwise flip the dtype
        # mid-trajectory.  Late arrivals into an already-pinned bucket are
        # pinned here too (they keyed on "auto" but run the bucket engine).
        if self.compute_dtype == "auto":
            self.compute_dtype = self._engine.resolved_compute_dtype
        for j in self.jobs:
            if j.compute_dtype == "auto":
                j.compute_dtype = self.compute_dtype
        return self._engine

    # -- the time slice ----------------------------------------------------
    def run_slice(self, base: LifeConfig, cache: PlanCache,
                  slice_iters: int) -> List[Job]:
        """Advance every member by k <= slice_iters iterations; a member
        whose remaining budget is below k bounds the whole slice, so no job
        ever overruns its requested n_iters.  Returns members that finished.
        """
        engine = self.engine(base, cache)
        k = min([slice_iters] + [j.remaining for j in self.jobs])
        # warm starts: a job carrying w0 gets its state from
        # sbbnnls_init(w0) instead of the engine's all-ones default —
        # per job, so one micro-batch can mix warm and cold members
        for j in self.jobs:
            if j.state is None and j.w0 is not None:
                j.state = sbbnnls_init(
                    jnp.asarray(j.w0, j.problem.dictionary.dtype))
        if self.solo:
            job = self.jobs[0]
            if job.state is None:
                job.state = engine.init_state()
            if k:
                job.state, ls = engine.step(job.state, k)
                job.losses.append(ls)
                job.done += k
        else:
            if any(j.state is None for j in self.jobs):
                fresh = engine.init_states()
                for i, j in enumerate(self.jobs):
                    if j.state is None:
                        j.state = SbbnnlsState(w=fresh.w[i], it=fresh.it[i],
                                               loss=fresh.loss[i])
            states = SbbnnlsState(
                w=jnp.stack([j.state.w for j in self.jobs]),
                it=jnp.stack([j.state.it for j in self.jobs]),
                loss=jnp.stack([j.state.loss for j in self.jobs]))
            if k:
                states, losses = engine.step(states, k)
            for i, job in enumerate(self.jobs):
                job.state = SbbnnlsState(w=states.w[i], it=states.it[i],
                                         loss=states.loss[i])
                if k:
                    job.losses.append(losses[i])
                    job.done += k
        self.iters_served += k * len(self.jobs)
        finished = [j for j in self.jobs if j.remaining == 0]
        for job in finished:
            job.status = "done"
            job.finished_at = time.monotonic()
        self.jobs = [j for j in self.jobs if j.remaining > 0]
        return finished


class Scheduler:
    """Continuous-batching micro-batch scheduler over stepped solves."""

    def __init__(self, config: Optional[LifeConfig] = None, *,
                 slice_iters: int = 16, cache: Optional[PlanCache] = None):
        self.config = config if config is not None else LifeConfig()
        if getattr(self.config, "compact_every", 0) > 0:
            # silently never compacting would be worse than refusing: the
            # stepped path drives engines directly and bypasses the
            # compaction loop in LifeEngine.run()
            raise ValueError(
                "weight compaction (compact_every > 0) is not supported by "
                "the serving scheduler; run those solves through LifeEngine")
        self.cache = cache if cache is not None else PlanCache(
            self.config.plan_cache_dir, self.config.plan_cache_max_bytes)
        self.slice_iters = slice_iters
        self._queue: List[Job] = []
        self._buckets: Dict[Tuple, _Bucket] = {}
        self._jobs: Dict[str, Job] = {}
        self._arrivals = itertools.count()
        self._last_served: Optional[Tuple] = None
        # obs instruments, fetched once and held (DESIGN.md §12.2) — every
        # call below is an allocation-free no-op while obs is disabled.
        # Counter invariant, maintained across submit()/tick()/cancel():
        #   serve.jobs.admitted == serve.jobs.completed + serve.jobs.failed
        #                          + serve.jobs.cancelled
        #                          + serve.queue.depth + serve.jobs.running
        self._m_admitted = obs.counter("serve.jobs.admitted")
        self._m_completed = obs.counter("serve.jobs.completed")
        self._m_failed = obs.counter("serve.jobs.failed")
        self._m_cancelled = obs.counter("serve.jobs.cancelled")
        self._m_preempted = obs.counter("serve.preemptions")
        self._g_queue = obs.gauge("serve.queue.depth")
        self._g_running = obs.gauge("serve.jobs.running")
        self._g_buckets = obs.gauge("serve.buckets.live")
        self._h_queue = obs.histogram("serve.queue.depth")
        self._h_occupancy = obs.histogram("serve.bucket.occupancy")
        self._h_slice = obs.histogram("serve.slice.seconds")

    # -- intake ------------------------------------------------------------
    def submit(self, job: Job) -> Job:
        if job.job_id in self._jobs:
            raise ValueError(f"job id {job.job_id!r} already submitted")
        if "/" in job.job_id:
            raise ValueError("job ids must not contain '/' "
                             "(they key checkpoint array paths)")
        if job.format not in BATCHABLE_FORMATS + _SOLO_FORMATS:
            raise ValueError(
                f"format must be one of "
                f"{BATCHABLE_FORMATS + _SOLO_FORMATS}, got {job.format!r}")
        # tuning knobs: inherit the scheduler config when unset, then
        # validate eagerly (intake is the last place a bad value fails
        # cheaply).  validate_config reads .tune/.compute_dtype, so the
        # Job itself is the config it validates — one rule set with the
        # engines, not a hand-kept copy.
        if job.tune is None:
            job.tune = getattr(self.config, "tune", "off")
        if job.compute_dtype is None:
            job.compute_dtype = getattr(self.config, "compute_dtype", "fp32")
        from repro.tune.tuner import validate_config
        validate_config(job)
        if job.mesh is not None:
            R, C = job.mesh
            if R < 1 or C < 1:
                raise ValueError(f"mesh shape must be positive, "
                                 f"got {job.mesh}")
            if R * C > len(jax.devices()):
                raise ValueError(
                    f"mesh slice ({R}, {C}) needs {R * C} devices, "
                    f"have {len(jax.devices())}")
            if REGISTRY.mesh_executor_for(job.format) is None:
                meshable = tuple(
                    f for f in BATCHABLE_FORMATS + _SOLO_FORMATS
                    if REGISTRY.mesh_executor_for(f))
                raise ValueError(
                    f"format {job.format!r} has no mesh executor; mesh "
                    f"jobs must name an explicit cell format from "
                    f"{meshable}")
        if job.w0 is not None:
            w0 = np.asarray(job.w0)
            nf = job.problem.phi.n_fibers
            if w0.shape != (nf,):
                raise ValueError(f"w0 has shape {w0.shape}, expected "
                                 f"({nf},) for this problem")
            if not np.all(np.isfinite(w0)) or bool((w0 < 0).any()):
                raise ValueError("w0 must be finite and nonnegative "
                                 "(SBBNNLS iterates live in the "
                                 "nonnegative orthant)")
            job.w0 = w0
        if not job.dataset:
            job.dataset = dataset_key(job.problem)
        if not job.dict_digest:
            job.dict_digest = _dict_digest(job.problem)
        if job.submitted_at is None:      # 0.0 is a valid monotonic stamp
            job.submitted_at = time.monotonic()
        self._jobs[job.job_id] = job
        self._queue.append(job)
        self._m_admitted.inc()
        self._g_queue.set(float(len(self._queue)))
        return job

    def _bucket_key(self, job: Job) -> Tuple:
        phi = job.problem.phi
        return (phi.n_voxels, phi.n_fibers, job.problem.dictionary.shape[1],
                job.dict_digest, job.format, job.mesh,
                job.tune, job.compute_dtype,
                job.job_id if _is_solo(job.format, job.mesh) else "")

    def _admit(self) -> None:
        """Move queued jobs into buckets — the continuous-batching step:
        arrivals join their bucket's *next* micro-batch; nothing in flight
        restarts (states persist across the engine rebuild)."""
        for job in self._queue:
            key = self._bucket_key(job)
            if key not in self._buckets:
                self._buckets[key] = _Bucket(key, job.format,
                                             next(self._arrivals),
                                             mesh=job.mesh, tune=job.tune,
                                             compute_dtype=job.compute_dtype)
            self._buckets[key].jobs.append(job)
            job.status = "running"
        self._queue.clear()

    # -- the loop ----------------------------------------------------------
    def tick(self) -> List[Job]:
        """Admit arrivals, serve the most urgent bucket one time slice.

        Returns the jobs that reached a terminal state during this tick
        (``status`` is "done" or "failed").  An executor exception never
        propagates: the poisoned bucket is quarantined — each member is
        retried in a single-job probe so one bad tenant cannot condemn its
        batch-mates — and only the jobs that fail alone are marked
        ``failed`` with the exception captured (DESIGN.md §13.3).  Every
        other bucket stays servable."""
        with obs.span("scheduler.tick"):
            self._h_queue.observe(float(len(self._queue)))
            self._admit()
            self._g_queue.set(0.0)         # _admit drained the queue
            live = [b for b in self._buckets.values() if b.jobs]
            self._g_buckets.set(float(len(live)))
            self._g_running.set(float(sum(len(b.jobs) for b in live)))
            if not live:
                return []
            bucket = min(live, key=_Bucket.urgency)
            # a preemption = the most urgent bucket displaced the one served
            # last tick while that one still had members waiting to run
            last = self._last_served
            if (last is not None and last != bucket.key
                    and last in self._buckets and self._buckets[last].jobs):
                self._m_preempted.inc()
            self._last_served = bucket.key
            self._h_occupancy.observe(float(len(bucket.jobs)))
            timed = obs.SWITCH.on          # guard the clock reads, not just
            t0 = time.monotonic() if timed else 0.0   # the observe() call
            try:
                with obs.span("scheduler.slice",
                              {"format": bucket.format,
                               "jobs": len(bucket.jobs)}):
                    finished = bucket.run_slice(self.config, self.cache,
                                                self.slice_iters)
            except Exception as exc:
                finished = self._quarantine(bucket, exc)
            if timed:
                self._h_slice.observe(time.monotonic() - t0)
            done = [j for j in finished if j.status == "done"]
            if done:
                self._m_completed.inc(float(len(done)))
            if finished:
                self._g_running.dec(float(len(finished)))
            cur = self._buckets.get(bucket.key)
            if cur is not None and not cur.jobs:
                del self._buckets[bucket.key]
            return finished

    # -- failure isolation (DESIGN.md §13.3) -------------------------------
    def _fail(self, job: Job, exc: BaseException) -> None:
        job.status = "failed"
        job.error = exc
        job.finished_at = time.monotonic()
        self._m_failed.inc()

    def _quarantine(self, bucket: _Bucket, exc: Exception) -> List[Job]:
        """A slice raised: evict the poisoned bucket and bisect to the bad
        tenant(s).  Single-member buckets fail outright; multi-member
        buckets retry each job through a one-job probe bucket of the same
        compatibility class — members that succeed alone keep their
        advanced state and re-bucket together (micro-batching resumes next
        tick), members that fail alone are the poisoned ones.  Returns the
        jobs that reached a terminal state (failed, plus any that finished
        during their probe)."""
        jobs = list(bucket.jobs)
        self._buckets.pop(bucket.key, None)
        if len(jobs) == 1:
            self._fail(jobs[0], exc)
            return jobs
        terminal: List[Job] = []
        survivors: List[Job] = []
        with obs.span("scheduler.quarantine",
                      {"format": bucket.format, "jobs": len(jobs)}):
            for job in jobs:
                probe = _Bucket(bucket.key, bucket.format, bucket.arrival,
                                mesh=bucket.mesh, tune=bucket.tune,
                                compute_dtype=bucket.compute_dtype)
                probe.jobs = [job]
                try:
                    terminal.extend(probe.run_slice(self.config, self.cache,
                                                    self.slice_iters))
                except Exception as probe_exc:
                    self._fail(job, probe_exc)
                    terminal.append(job)
                else:
                    if job.remaining > 0:
                        survivors.append(job)
        if survivors:
            fresh = _Bucket(bucket.key, bucket.format,
                            next(self._arrivals), mesh=bucket.mesh,
                            tune=bucket.tune,
                            compute_dtype=bucket.compute_dtype)
            fresh.iters_served = bucket.iters_served   # fairness carries over
            fresh.jobs = survivors
            self._buckets[bucket.key] = fresh
        return terminal

    # -- cancellation ------------------------------------------------------
    def cancel(self, job_id: str) -> bool:
        """Cancel a queued or running job; returns False when the job is
        already terminal.  A running job leaves its bucket immediately (the
        engine signature invalidates, so batch-mates re-batch without it);
        its partial state stays readable on the Job for post-mortems but
        ``result()`` raises :class:`JobCancelledError`."""
        job = self._jobs[job_id]
        if job.status in TERMINAL_STATUSES:
            return False
        if job in self._queue:
            self._queue.remove(job)
            self._g_queue.set(float(len(self._queue)))
        else:
            bucket = next((b for b in self._buckets.values()
                           if job in b.jobs), None)
            if bucket is not None:
                bucket.jobs.remove(job)
                if not bucket.jobs:
                    del self._buckets[bucket.key]
                self._g_running.dec()
        job.status = "cancelled"
        job.finished_at = time.monotonic()
        self._m_cancelled.inc()
        return True

    def active(self) -> bool:
        return bool(self._queue) or any(b.jobs
                                        for b in self._buckets.values())

    def run_until_idle(self, max_ticks: Optional[int] = None) -> List[Job]:
        """Drive tick() until every submitted job completed."""
        finished: List[Job] = []
        ticks = 0
        while self.active():
            if max_ticks is not None and ticks >= max_ticks:
                break
            finished.extend(self.tick())
            ticks += 1
        return finished

    # -- introspection -----------------------------------------------------
    def job(self, job_id: str) -> Job:
        return self._jobs[job_id]

    def jobs(self) -> Sequence[Job]:
        return list(self._jobs.values())

    def in_flight(self) -> List[Job]:
        """Jobs admitted or queued but not terminal (checkpoint targets)."""
        return [j for j in self._jobs.values()
                if j.status not in TERMINAL_STATUSES]
