"""LifeService: the serving front — submit / drive / checkpoint / resume.

Wraps :class:`~repro.serve.scheduler.Scheduler` with the durability story
(DESIGN.md §8.3): every ``checkpoint_every`` ticks the service snapshots all
in-flight solver states through :mod:`repro.checkpoint.manager` (atomic
rename, retention, the same machinery training jobs use).  A killed service
restarts, probes its checkpoint directory, and re-adopts each solve at the
exact iteration it left off — bit-compatibly, because a
:class:`~repro.core.sbbnnls.SbbnnlsState` is the *complete* solver state
(weights + iteration parity + last loss) and float arrays round-trip ``.npz``
losslessly.

Resume protocol: solve *data* is not checkpointed (at scale it lives in the
dataset store; here the client resubmits it).  The checkpoint manifest
records each job's dataset digest; on resubmission with a known ``job_id``
the service verifies the digest matches before re-attaching the restored
state, so a resumed job can never silently continue on different data.

Plan reuse across restarts is free: the scheduler's engines share one
persistent :class:`~repro.core.plan_cache.PlanCache`, keyed by dataset
content — a restarted service rebuilds its engines from cached FormatPlans /
autotune choices / tile plans instead of re-measuring.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint import manager as ckpt
from repro.core.life import LifeConfig
from repro.core.plan_cache import PlanCache
from repro.core.sbbnnls import SbbnnlsState
from repro.data.dmri import LifeProblem
from repro.serve.scheduler import Job, Scheduler, dataset_key


class LifeService:
    """Multi-tenant solve service with checkpointed, resumable jobs."""

    def __init__(self, config: Optional[LifeConfig] = None, *,
                 ckpt_dir: Optional[str] = None, checkpoint_every: int = 4,
                 slice_iters: int = 16, keep: int = 3,
                 cache: Optional[PlanCache] = None):
        self.config = config if config is not None else LifeConfig()
        self.scheduler = Scheduler(self.config, slice_iters=slice_iters,
                                   cache=cache)
        self.ckpt_dir = ckpt_dir
        self.checkpoint_every = checkpoint_every
        self.keep = keep
        self._tick = 0
        self._completed: Dict[str, Job] = {}
        self._failed: Dict[str, Job] = {}
        # job_id -> (restored arrays, manifest meta) awaiting resubmission
        self._resumable: Dict[str, Tuple[dict, dict]] = {}
        # obs instruments (no-ops while disabled, DESIGN.md §12.2)
        self._h_latency = obs.histogram("serve.job.latency.seconds")
        self._m_checkpoints = obs.counter("serve.checkpoints")
        self._m_ckpt_jobs = obs.counter("serve.jobs.checkpointed")
        self._m_resumed = obs.counter("serve.jobs.resumed")
        if ckpt_dir:
            self._load_resumable(ckpt_dir)

    # -- resume ------------------------------------------------------------
    def _load_resumable(self, ckpt_dir: str) -> None:
        latest = ckpt.load_latest(ckpt_dir)
        if latest is None:
            return
        step, flat, manifest = latest
        self._tick = step
        for job_id, meta in manifest.get("jobs", {}).items():
            arrays = {k.split(ckpt.SEP, 1)[1]: v for k, v in flat.items()
                      if k.split(ckpt.SEP, 1)[0] == job_id}
            if {"w", "it", "loss"} <= set(arrays):
                self._resumable[job_id] = (arrays, meta)

    @property
    def resumable_jobs(self) -> Tuple[str, ...]:
        """Job ids waiting to be re-adopted by a matching resubmission."""
        return tuple(sorted(self._resumable))

    # -- intake ------------------------------------------------------------
    def submit(self, problem: LifeProblem, *, job_id: Optional[str] = None,
               n_iters: Optional[int] = None, priority: Optional[int] = None,
               deadline: Optional[float] = None,
               format: Optional[str] = None,
               mesh: Optional[Tuple[int, int]] = None,
               tune: Optional[str] = None,
               compute_dtype: Optional[str] = None,
               w0: Optional[np.ndarray] = None) -> str:
        """Queue one solve; returns its job id.

        ``w0`` warm-starts the solver from the given weights instead of
        the all-ones default (shape ``(n_fibers,)``, finite,
        nonnegative) — the repeat-visit path for Phi-delta resubmission
        and virtual lesions (DESIGN.md §15.3).  It applies to *fresh*
        jobs only: on a checkpoint resume the restored state is the warm
        start, so passing ``w0`` alongside one is rejected rather than
        silently picking a winner.

        ``deadline`` is seconds from now (converted to an absolute monotonic
        time for EDF ordering).  If ``job_id`` names a checkpointed solve,
        the restored state is re-attached — after verifying the resubmitted
        data's digest matches the one recorded at checkpoint time.  On
        resume, arguments the caller passes explicitly win over the
        checkpointed values (extend a job with a larger ``n_iters``, bump
        its ``priority``, set a fresh ``deadline``); omitted ones are
        restored from the checkpoint, including the deadline's remaining
        budget.  The format, the mesh slice, and the compute dtype are the
        exceptions: the state's trajectory is only reproducible under the
        format, mesh topology, *and numerics* it ran on, so a conflicting
        explicit ``format``, ``mesh``, or ``compute_dtype`` is an error
        rather than a silent override.  ``tune`` may change freely on
        resume — tile choice affects speed, not the solution.

        ``mesh=(R, C)`` admits the job onto a device-mesh slice: its solve
        runs the sharded executor for its format (DESIGN.md §9)."""
        if job_id is None:
            taken = ({j.job_id for j in self.scheduler.jobs()}
                     | set(self._completed) | set(self._resumable))
            n = len(taken)
            while f"job-{n}" in taken:
                n += 1
            job_id = f"job-{n}"
        now = time.monotonic()
        job = Job(job_id=job_id, problem=problem,
                  n_iters=self.config.n_iters if n_iters is None else n_iters,
                  priority=0 if priority is None else priority,
                  deadline=None if deadline is None else now + deadline,
                  format=self.config.format if format is None else format,
                  mesh=None if mesh is None else tuple(mesh),
                  tune=tune, compute_dtype=compute_dtype, w0=w0,
                  submitted_at=now, dataset=dataset_key(problem))
        if job_id in self._resumable:
            if w0 is not None:
                raise ValueError(
                    f"resume of job {job_id!r} rejected: a checkpointed "
                    f"state exists and is the warm start; w0 would "
                    f"silently discard it")
            arrays, meta = self._resumable[job_id]
            if meta.get("dataset") != job.dataset:
                raise ValueError(
                    f"resume of job {job_id!r} rejected: resubmitted data "
                    f"digest {job.dataset} != checkpointed "
                    f"{meta.get('dataset')}")
            ck_format = str(meta.get("format", job.format))
            if format is not None and format != ck_format:
                raise ValueError(
                    f"resume of job {job_id!r} rejected: checkpointed state "
                    f"ran under format {ck_format!r}, resubmitted with "
                    f"{format!r}")
            ck_mesh = meta.get("mesh")
            ck_mesh = None if ck_mesh is None else tuple(int(x)
                                                         for x in ck_mesh)
            if mesh is not None and tuple(mesh) != ck_mesh:
                raise ValueError(
                    f"resume of job {job_id!r} rejected: checkpointed state "
                    f"ran on mesh {ck_mesh}, resubmitted with {tuple(mesh)}")
            ck_dtype = meta.get("compute_dtype")
            if (compute_dtype is not None and ck_dtype is not None
                    and compute_dtype != ck_dtype):
                raise ValueError(
                    f"resume of job {job_id!r} rejected: checkpointed state "
                    f"ran under compute_dtype {ck_dtype!r}, resubmitted "
                    f"with {compute_dtype!r}")
            # validation passed — adopt the state (the entry is consumed
            # only once scheduler.submit accepts the job: its own
            # validation, e.g. the restored mesh not fitting this host's
            # devices, must leave the checkpointed state re-adoptable)
            job.format = ck_format
            job.mesh = ck_mesh
            if compute_dtype is None and ck_dtype is not None:
                job.compute_dtype = str(ck_dtype)
            if tune is None and meta.get("tune") is not None:
                job.tune = str(meta["tune"])
            job.state = SbbnnlsState(w=jnp.asarray(arrays["w"]),
                                     it=jnp.asarray(arrays["it"]),
                                     loss=jnp.asarray(arrays["loss"]))
            job.done = int(meta["done"])
            # the resume leg restarts submitted_at; the time the job spent
            # in earlier incarnations is restored so latency is end-to-end
            job.prior_elapsed = float(meta.get("elapsed", 0.0) or 0.0)
            # explicit caller arguments win over checkpointed values
            if n_iters is None:
                job.n_iters = int(meta.get("n_iters", job.n_iters))
            if priority is None:
                job.priority = int(meta.get("priority", 0))
            if deadline is None and meta.get("deadline_remaining") is not None:
                job.deadline = now + float(meta["deadline_remaining"])
            if "losses" in arrays:
                job.losses = [np.asarray(arrays["losses"])]
            self._m_resumed.inc()
        self.scheduler.submit(job)
        self._resumable.pop(job_id, None)
        return job_id

    # -- driving -----------------------------------------------------------
    def step(self) -> List[Job]:
        """One scheduler tick + periodic checkpoint; returns the jobs that
        reached a terminal state (done or failed) this tick."""
        finished = self.scheduler.tick()
        self._tick += 1
        for job in finished:
            if job.status == "failed":
                self._failed[job.job_id] = job
                continue
            self._completed[job.job_id] = job
            if job.finished_at is not None:
                # end-to-end latency: legs run before a kill-and-resume are
                # restored into prior_elapsed, so a resumed job reports its
                # true submit→finish time, not just the final leg
                self._h_latency.observe(job.prior_elapsed
                                        + job.finished_at - job.submitted_at)
        if (self.ckpt_dir and self.checkpoint_every > 0
                and self._tick % self.checkpoint_every == 0):
            self.checkpoint()
        return finished

    def run(self, max_ticks: Optional[int] = None
            ) -> Dict[str, Tuple[jnp.ndarray, np.ndarray]]:
        """Drive until every job completed (or ``max_ticks`` elapsed);
        returns {job_id: (weights, loss trace)} for all completed jobs."""
        ticks = 0
        while self.scheduler.active():
            if max_ticks is not None and ticks >= max_ticks:
                break
            self.step()
            ticks += 1
        if self.ckpt_dir:
            self.checkpoint()                 # never exit with unsaved state
        return {jid: job.result() for jid, job in self._completed.items()}

    # -- durability --------------------------------------------------------
    def checkpoint(self) -> Optional[str]:
        """Snapshot every solver state: in-flight *and* completed (atomic,
        retained).  Completed jobs stay in the snapshot so a kill between a
        job finishing and the client reading its result loses nothing — a
        resubmission re-adopts the final state and completes instantly
        instead of re-running the whole solve."""
        if not self.ckpt_dir:
            return None
        with obs.span("service.checkpoint"):
            return self._checkpoint()

    def _checkpoint(self) -> Optional[str]:
        tree: Dict[str, Dict[str, np.ndarray]] = {}
        meta: Dict[str, dict] = {}
        now = time.monotonic()
        # failed jobs ride along with their last good state: resubmitting a
        # failed job's data re-adopts it and retries the remaining
        # iterations from where the solve was last healthy (DESIGN.md §13.3)
        for job in (self.scheduler.in_flight()
                    + list(self._completed.values())
                    + list(self._failed.values())):
            if job.state is None:
                continue                      # queued, never ran: nothing yet
            entry = {"w": np.asarray(job.state.w),
                     "it": np.asarray(job.state.it),
                     "loss": np.asarray(job.state.loss)}
            if job.losses:
                entry["losses"] = np.concatenate(job.losses)
            tree[job.job_id] = entry
            end = job.finished_at if job.finished_at is not None else now
            meta[job.job_id] = dict(
                done=job.done, n_iters=job.n_iters, priority=job.priority,
                format=job.format, dataset=job.dataset,
                mesh=None if job.mesh is None else list(job.mesh),
                tune=job.tune, compute_dtype=job.compute_dtype,
                # cumulative wall time across service incarnations, so a
                # resumed job's latency covers every leg (restored into
                # Job.prior_elapsed on resume)
                elapsed=job.prior_elapsed + max(0.0, end - job.submitted_at),
                # deadlines are monotonic-clock absolutes that don't survive
                # a restart; persist the remaining budget instead
                deadline_remaining=(None if job.deadline is None
                                    else job.deadline - now))
            if job.status == "failed" and job.error is not None:
                meta[job.job_id]["error"] = repr(job.error)
        # carry restored-but-unclaimed states forward: without this, a job
        # nobody has resubmitted yet would fall out of retention once other
        # jobs rotate `keep` fresh snapshots past its last one.  Deliberate
        # trade-off: abandoned tenants ride along in every snapshot (a few
        # arrays each) until operators clear the checkpoint dir — durability
        # over disk economy; revisit with a TTL if snapshots grow hot
        for job_id, (arrays, m) in self._resumable.items():
            if job_id not in tree:
                tree[job_id] = {k: np.asarray(v) for k, v in arrays.items()}
                meta[job_id] = m
        self._m_checkpoints.inc()
        self._m_ckpt_jobs.inc(float(len(tree)))
        return ckpt.save(self.ckpt_dir, self._tick, tree,
                         meta={"jobs": meta}, keep=self.keep)

    # -- introspection -----------------------------------------------------
    def job(self, job_id: str) -> Job:
        """The Job record whatever its state — queued, running, done,
        failed, or cancelled (the front line's status/result source)."""
        if job_id in self._completed:
            return self._completed[job_id]
        if job_id in self._failed:
            return self._failed[job_id]
        return self.scheduler.job(job_id)

    def result(self, job_id: str) -> Tuple[jnp.ndarray, np.ndarray]:
        """(weights, loss trace); raises
        :class:`~repro.serve.scheduler.JobFailedError` (chaining the
        captured executor exception) when the job failed."""
        return self.job(job_id).result()

    def status(self, job_id: str) -> str:
        return self.job(job_id).status

    def error(self, job_id: str) -> Optional[BaseException]:
        """The captured exception of a failed job (None otherwise)."""
        return self.job(job_id).error

    @property
    def failed_jobs(self) -> Tuple[str, ...]:
        return tuple(sorted(self._failed))

    def cancel(self, job_id: str) -> bool:
        """Cancel a queued or running job; False once it is terminal."""
        if job_id in self._completed or job_id in self._failed:
            return False
        return self.scheduler.cancel(job_id)

    @property
    def cache_stats(self):
        return self.scheduler.cache.stats

    def metrics_snapshot(self) -> dict:
        """The obs snapshot with the service's plan-cache stats mirrored in
        as authoritative gauges (``plan_cache.hits`` / ``.misses`` /
        ``.hit_rate`` — counted since the cache was built, including
        lookups made while obs was disabled).  This is the serving metric
        surface the ROADMAP names: queue depth, latency quantiles,
        completion counters, and plan-cache hit rate, one JSON-ready
        dict."""
        obs.record_cache_stats(self.scheduler.cache.stats)
        return obs.snapshot()
