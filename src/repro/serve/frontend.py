"""Async serving front line: submission, streaming results, backpressure.

:class:`LifeFrontend` is the traffic-facing layer over
:class:`~repro.serve.service.LifeService` (DESIGN.md §13).  The service
and its scheduler are deliberately single-threaded — engines, plan cache
and checkpointing all assume one driver — so the frontend gives them one:
a background *driver thread* owns the tick loop exclusively, and every
other thread talks to it through two small synchronized structures:

* the **admission queue** — a bounded deque of not-yet-submitted
  :class:`JobHandle` specs.  ``submit_async()`` appends under the
  frontend lock and returns immediately; the driver drains it into
  ``LifeService.submit`` between ticks.  The bound is the backpressure
  point (§13.2): when the queue is full the configured policy decides
  whether the caller blocks, is rejected with
  :class:`AdmissionQueueFull`, or a lower-priority pending job is shed
  to make room.
* the **command queue** — cancellation requests for jobs that already
  crossed into the service.  Cancelling a *pending* handle never touches
  the driver at all.

Results stream back through the handle: ``JobHandle.result(timeout)``
blocks on a ``threading.Event`` the driver sets at terminal state;
``JobHandle.events()`` yields per-slice progress events (iterations done,
latest loss) the driver publishes after every tick.  A failed job's
captured executor exception — the scheduler's failure-isolation machinery
guarantees one bad tenant fails alone (§13.3) — surfaces on the handle:
``result()`` raises :class:`~repro.serve.scheduler.JobFailedError`
chaining it, ``exception()`` returns it.

Shutdown (§13.4) is graceful by default: ``shutdown()`` (or leaving the
``with`` block) stops admission, drains every in-flight solve, writes a
final checkpoint, and joins the driver.  ``shutdown(drain=False)`` stops
after the current tick instead — in-flight states still hit the final
checkpoint, and handles that never completed resolve with
:class:`ShutdownError` rather than hanging their waiters.
"""
from __future__ import annotations

import collections
import threading
from typing import Deque, Dict, Iterator, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.life import LifeConfig
from repro.learn.refine import QUEUE as refine_queue
from repro.serve.scheduler import (JobCancelledError, JobFailedError,
                                   TERMINAL_STATUSES)
from repro.serve.service import LifeService

#: admission-queue-full policies (DESIGN.md §13.2)
BACKPRESSURE_POLICIES = ("block", "reject", "shed")

#: terminal handle states (superset of the scheduler's: admission-time
#: rejections and shutdown produce terminal handles the scheduler never saw)
_HANDLE_TERMINAL = TERMINAL_STATUSES + ("shed", "rejected")


class AdmissionQueueFull(RuntimeError):
    """The bounded admission queue rejected a submission (policy
    "reject", a shed that picked the submitting job itself as the
    lowest-priority victim, or a "block" that timed out)."""


class ShutdownError(RuntimeError):
    """The frontend shut down before this job reached a terminal state."""


class JobHandle:
    """Future-like handle for one async submission.

    Created by :meth:`LifeFrontend.submit_async`; resolved by the driver
    thread.  All methods are safe to call from any thread."""

    def __init__(self, frontend: "LifeFrontend", problem, kwargs: dict):
        self._frontend = frontend
        self._problem = problem
        self._kwargs = kwargs
        self.job_id: Optional[str] = kwargs.get("job_id")
        self.priority = int(kwargs.get("priority") or 0)
        self._status = "pending"          # pending until the driver admits
        self._result: Optional[Tuple[jnp.ndarray, np.ndarray]] = None
        self._error: Optional[BaseException] = None
        self._terminal = threading.Event()
        self._events: "collections.deque[dict]" = collections.deque()
        self._events_ready = threading.Condition(threading.Lock())
        self._last_done = -1

    # -- read side (any thread) --------------------------------------------
    def status(self) -> str:
        """pending | queued | running | done | failed | cancelled | shed |
        rejected ("pending" = still in the admission queue)."""
        return self._status

    def done(self) -> bool:
        """True once the job reached any terminal state."""
        return self._terminal.is_set()

    def result(self, timeout: Optional[float] = None
               ) -> Tuple[jnp.ndarray, np.ndarray]:
        """Block until terminal; returns (weights, loss trace).  Raises
        :class:`~repro.serve.scheduler.JobFailedError` (chaining the
        executor's exception) when the solve failed, TimeoutError when
        ``timeout`` elapses first."""
        if not self._terminal.wait(timeout):
            raise TimeoutError(
                f"job {self.job_id or '<pending>'} not finished "
                f"within {timeout}s")
        if self._error is not None:
            if isinstance(self._error, (JobFailedError, JobCancelledError,
                                        AdmissionQueueFull, ShutdownError)):
                raise self._error
            raise JobFailedError(self.job_id or "<pending>",
                                 self._error) from self._error
        assert self._result is not None
        return self._result

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        """Block until terminal; the failure (or None on success)."""
        if not self._terminal.wait(timeout):
            raise TimeoutError(
                f"job {self.job_id or '<pending>'} not finished "
                f"within {timeout}s")
        return self._error

    def events(self, timeout: Optional[float] = None) -> Iterator[dict]:
        """Yield progress events until the job is terminal.

        Each event is a dict: ``{"type": "progress", "done": k,
        "n_iters": n, "loss": latest}`` per served slice, closed by one
        ``{"type": <terminal status>}`` event.  ``timeout`` bounds the
        wait for *each* event (TimeoutError on expiry)."""
        while True:
            with self._events_ready:
                while not self._events:
                    if not self._events_ready.wait(timeout):
                        raise TimeoutError(
                            f"no event from job "
                            f"{self.job_id or '<pending>'} "
                            f"within {timeout}s")
                event = self._events.popleft()
            yield event
            if event["type"] != "progress":
                return

    def cancel(self) -> bool:
        """Request cancellation; True if the request was accepted (the
        job was still pending, queued, or running)."""
        return self._frontend._cancel(self)

    # -- write side (driver thread / admission path) -----------------------
    def _publish(self, event: dict) -> None:
        with self._events_ready:
            self._events.append(event)
            self._events_ready.notify_all()

    def _resolve(self, status: str,
                 result: Optional[Tuple[jnp.ndarray, np.ndarray]] = None,
                 error: Optional[BaseException] = None) -> None:
        self._status = status
        self._result = result
        self._error = error
        self._publish({"type": status})
        self._terminal.set()


class LifeFrontend:
    """Async, failure-isolated submission layer over one LifeService.

    ::

        with LifeFrontend(config, max_queue=64,
                          backpressure="block") as fe:
            h = fe.submit_async(problem, n_iters=500, priority=5)
            for ev in h.events():
                print(ev)                      # per-slice progress
            w, losses = h.result(timeout=600)
        # leaving the block drains, final-checkpoints, stops the driver

    Parameters
    ----------
    config / service_kwargs:
        Forwarded to :class:`LifeService` — or pass a prebuilt
        ``service=`` instead (the frontend takes exclusive ownership: no
        other thread may drive it once the frontend starts).
    max_queue:
        Bound of the admission queue (pending submissions the driver has
        not yet accepted).  Jobs already inside the service do not count:
        the scheduler's own queue is drained every tick by design.
    backpressure:
        "block" (default) — ``submit_async`` waits for space (honoring
        its ``timeout``); "reject" — raise :class:`AdmissionQueueFull`
        immediately; "shed" — evict the lowest-priority pending job to
        make room (the new job itself is rejected if nothing pending has
        lower priority).
    refine:
        True (default) — while the driver is otherwise idle (no pending
        submissions, no commands, no active jobs) it drains one task per
        tick from the learn subsystem's background-refinement queue
        (:data:`repro.learn.refine.QUEUE`), upgrading zero-measurement
        ``reason="predicted"`` plans to measured ones without ever
        competing with real work.  False disables the hook.
    """

    def __init__(self, config: Optional[LifeConfig] = None, *,
                 service: Optional[LifeService] = None,
                 max_queue: int = 64, backpressure: str = "block",
                 idle_wait: float = 0.002, start: bool = True,
                 refine: bool = True,
                 **service_kwargs):
        if backpressure not in BACKPRESSURE_POLICIES:
            raise ValueError(f"backpressure must be one of "
                             f"{BACKPRESSURE_POLICIES}, got {backpressure!r}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if service is not None and (config is not None or service_kwargs):
            raise ValueError("pass either a prebuilt service= or "
                             "config/service kwargs, not both")
        self.service = (service if service is not None
                        else LifeService(config, **service_kwargs))
        self.max_queue = max_queue
        self.backpressure = backpressure
        self._idle_wait = idle_wait
        self._refine = refine
        self._lock = threading.Lock()
        self._space = threading.Condition(self._lock)   # admission has room
        self._work = threading.Condition(self._lock)    # driver has work
        self._pending: Deque[JobHandle] = collections.deque()
        self._commands: List[Tuple[str, JobHandle]] = []
        self._live: Dict[str, JobHandle] = {}   # job_id -> handle (driver)
        self._closed = False                    # no further submissions
        self._drain = True                      # finish in-flight on stop
        self._driver: Optional[threading.Thread] = None
        # obs instruments (no-ops while disabled, DESIGN.md §12.2)
        self._g_admission = obs.gauge("serve.admission.depth")
        self._m_rejected = obs.counter("serve.admission.rejected")
        self._m_shed = obs.counter("serve.admission.shed")
        if start:
            self.start()

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        """Start the driver thread (idempotent)."""
        if self._driver is not None:
            return
        self._driver = threading.Thread(target=self._drive,
                                        name="life-frontend-driver",
                                        daemon=True)
        self._driver.start()

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop accepting work and stop the driver.

        ``drain=True`` (default) finishes every in-flight and pending
        job first; ``drain=False`` stops after the current tick and
        resolves unfinished handles with :class:`ShutdownError`.  Either
        way the service writes a final checkpoint before the driver
        exits, so ``drain=False`` loses no solver state — a restarted
        service re-adopts every interrupted job (§13.4)."""
        with self._lock:
            self._closed = True
            self._drain = drain
            self._work.notify_all()
            self._space.notify_all()      # unblock waiting submitters
        if self._driver is not None:
            self._driver.join(timeout)
            if self._driver.is_alive():
                raise TimeoutError(f"driver did not stop within {timeout}s")
            self._driver = None

    def __enter__(self) -> "LifeFrontend":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(drain=True)

    # -- intake (any thread) -----------------------------------------------
    def submit_async(self, problem, *, timeout: Optional[float] = None,
                     **submit_kwargs) -> JobHandle:
        """Queue one solve for async execution; returns its handle.

        Args:
            problem: the :class:`~repro.data.dmri.LifeProblem` to solve.
            timeout: bound on the wait for admission-queue space under
                the "block" backpressure policy.
            **submit_kwargs: mirror
                :meth:`~repro.serve.service.LifeService.submit` —
                job_id, n_iters, priority, deadline, format, mesh,
                tune, compute_dtype, and ``w0`` (warm-start weights for
                repeat-visit jobs, DESIGN.md §15.3).

        Returns:
            A :class:`JobHandle`.  Admission-time validation errors
            (unknown format, bad mesh, digest-mismatched resume, bad
            ``w0``) do not raise here — they resolve the handle as
            "rejected", like any other per-job failure.

        Raises:
            AdmissionQueueFull: under the "reject" policy, or when a
                "block" wait exceeds ``timeout``.
            RuntimeError: when the frontend is already shut down."""
        handle = JobHandle(self, problem, submit_kwargs)
        with self._lock:
            if self._closed:
                raise RuntimeError("frontend is shut down")
            if len(self._pending) >= self.max_queue:
                self._backpressure(handle, timeout)
                if handle.done():             # shed picked the newcomer
                    return handle
            self._pending.append(handle)
            self._g_admission.set(float(len(self._pending)))
            self._work.notify_all()
        return handle

    def _backpressure(self, handle: JobHandle,
                      timeout: Optional[float]) -> None:
        """Make room for ``handle`` per the configured policy (called
        under the lock with the admission queue full)."""
        if self.backpressure == "reject":
            self._m_rejected.inc()
            raise AdmissionQueueFull(
                f"admission queue full ({self.max_queue} pending)")
        if self.backpressure == "shed":
            victim = min(self._pending, key=lambda h: h.priority)
            if victim.priority >= handle.priority:
                # the newcomer is itself the lowest priority: shed it —
                # resolved on the handle, not raised, so open-loop
                # producers can keep submitting without try/except
                self._m_shed.inc()
                handle._resolve("shed", error=AdmissionQueueFull(
                    "shed: admission queue full of higher-priority work"))
                return
            self._pending.remove(victim)
            self._m_shed.inc()
            victim._resolve("shed", error=AdmissionQueueFull(
                f"shed by higher-priority arrival "
                f"(priority {handle.priority} > {victim.priority})"))
            return
        # "block": wait for the driver to drain below the bound
        if not self._space.wait_for(
                lambda: len(self._pending) < self.max_queue or self._closed,
                timeout=timeout):
            self._m_rejected.inc()
            raise AdmissionQueueFull(
                f"admission queue still full after {timeout}s")
        if self._closed:
            raise RuntimeError("frontend shut down while blocked on "
                               "admission")

    def _cancel(self, handle: JobHandle) -> bool:
        with self._lock:
            if handle.done():
                return False
            if handle._status == "pending":
                try:
                    self._pending.remove(handle)
                except ValueError:
                    pass                      # driver grabbed it just now
                else:
                    self._g_admission.set(float(len(self._pending)))
                    self._space.notify_all()
                    handle._resolve("cancelled",
                                    error=JobCancelledError(
                                        handle.job_id or "<pending>"))
                    return True
            self._commands.append(("cancel", handle))
            self._work.notify_all()
        return True

    # -- the driver thread -------------------------------------------------
    def _drive(self) -> None:
        while True:
            with self._lock:
                stop = self._closed and not (
                    self._drain and (self._pending or self._commands
                                     or self._live
                                     or self.service.scheduler.active()))
                if stop:
                    break
                if not (self._pending or self._commands
                        or self.service.scheduler.active()):
                    if not (self._refine and len(refine_queue)):
                        self._work.wait(self._idle_wait)
                        continue
                    # fall through (lock released below) to spend the idle
                    # tick on one background-refinement task
                    idle_refine = True
                else:
                    idle_refine = False
            if idle_refine:
                # outside the lock: a measured refinement must never block
                # submit_async/cancel; one task per tick keeps the driver
                # responsive — new work is re-checked before the next task
                refine_queue.run_one()
                continue
            self._admit()
            self._run_commands()
            if self.service.scheduler.active():
                self.service.step()
            self._sync()
        # final checkpoint: even a drain=False stop leaves every solver
        # state durable for the resume path
        self.service.checkpoint()
        if not self._drain:
            with self._lock:
                pending = list(self._pending)
                self._pending.clear()
                live = list(self._live.values())
                self._live.clear()
                self._g_admission.set(0.0)
            for h in pending + live:
                if not h.done():
                    h._resolve("failed", error=ShutdownError(
                        f"frontend shut down before job "
                        f"{h.job_id or '<pending>'} finished"))

    def _admit(self) -> None:
        while True:
            with self._lock:
                if not self._pending:
                    return
                handle = self._pending.popleft()
                self._g_admission.set(float(len(self._pending)))
                self._space.notify_all()
            try:
                jid = self.service.submit(handle._problem, **handle._kwargs)
            except Exception as exc:
                # submission-time validation failure: isolated to this
                # handle, admission keeps flowing
                handle._resolve("rejected", error=exc)
            else:
                handle.job_id = jid
                handle._status = self.service.status(jid)
                self._live[jid] = handle

    def _run_commands(self) -> None:
        with self._lock:
            commands, self._commands = self._commands, []
        for op, handle in commands:
            if op == "cancel" and handle.job_id is not None \
                    and not handle.done():
                self.service.cancel(handle.job_id)

    def _sync(self) -> None:
        """Publish progress and resolve terminal jobs after a tick."""
        for jid, handle in list(self._live.items()):
            job = self.service.job(jid)
            if job.done != handle._last_done and job.losses:
                handle._last_done = job.done
                handle._publish({"type": "progress", "done": job.done,
                                 "n_iters": job.n_iters,
                                 "loss": float(np.asarray(
                                     job.losses[-1]).reshape(-1)[-1])})
            if job.status not in TERMINAL_STATUSES:
                handle._status = job.status
                continue
            del self._live[jid]
            if job.status == "done":
                handle._resolve("done", result=job.result())
            elif job.status == "cancelled":
                handle._resolve("cancelled",
                                error=JobCancelledError(jid))
            else:
                assert job.error is not None
                handle._resolve("failed",
                                error=JobFailedError(jid, job.error))
