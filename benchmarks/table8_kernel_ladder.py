"""Paper Tables 6-8: the accelerator-kernel optimization ladder.

GPU version ladder (Ref-opt -> +restructure -> +partition -> GPU-opt) mapped
to this framework's executors:

  naive          scatter/gather translation (Ref-opt analogue)
  restructured   per-op output-side sorts (target-independent opts)
  segment        sorted segment reduction (sync-free partitioning)
  kernel         Pallas executor (interpret mode on CPU — wall time is NOT
                 meaningful; derived column reports the roofline-modeled TPU
                 time from the tile plan instead)

Derived: speedup vs naive (JAX rows) / modeled v5e microseconds (kernel row).
"""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, problem, roofline_fields, time_fn
from repro.core import spmv
from repro.core.inspector import auto_tile, plan_tiles
from repro.core.restructure import sort_by_host
from repro.kernels import ops as kops
from repro.roofline.analysis import HW


def _kernel_model_us(plan, n_theta_padded, d_bytes=4):
    """Roofline model of the DSC kernel on one v5e core: bytes streamed
    (coefficient tiles + output blocks) / HBM bw vs MXU time."""
    tiles = plan.n_tiles
    c, r = plan.c_tile, plan.row_tile
    bytes_in = tiles * c * (3 * 4 + d_bytes)              # idx + scaled
    bytes_out = tiles * r * n_theta_padded * d_bytes * 2  # rmw of blocks
    t_mem = (bytes_in + bytes_out) / HW["hbm_bw"]
    flops = tiles * (r * c * n_theta_padded * 2           # one-hot matmul
                     + c * n_theta_padded * 2)            # scale
    t_compute = flops / HW["peak_flops"]
    return max(t_mem, t_compute) * 1e6


def run():
    p = problem()
    w = jnp.ones((p.phi.n_fibers,), jnp.float32)
    y = p.b
    phi_v, _ = sort_by_host(p.phi, "voxel")
    phi_f, _ = sort_by_host(p.phi, "fiber")

    t0_dsc = time_fn(spmv.dsc_naive, p.phi, p.dictionary, w)
    t1_dsc = time_fn(spmv.dsc_atom_sorted, phi_v, p.dictionary, w)
    t2_dsc = time_fn(spmv.dsc, phi_v, p.dictionary, w)
    emit("table8.dsc.naive", t0_dsc, "1.00x")
    emit("table8.dsc.restructured", t1_dsc, f"{t0_dsc / t1_dsc:.2f}x")
    emit("table8.dsc.segment", t2_dsc, f"{t0_dsc / t2_dsc:.2f}x",
         **roofline_fields(lambda w_: spmv.dsc(phi_v, p.dictionary, w_),
                           t2_dsc, w))

    ct, rt = auto_tile(np.asarray(phi_v.voxels), p.phi.n_voxels)
    plan = plan_tiles(np.asarray(phi_v.voxels), p.phi.n_voxels,
                      c_tile=ct, row_tile=rt)
    mv = kops.make_dsc(phi_v, p.dictionary, plan, interpret=True)
    t3 = time_fn(mv, w, warmup=1, repeats=2)
    emit("table8.dsc.kernel-interpret", t3,
         f"modeled_v5e_us={_kernel_model_us(plan, 128):.1f}"
         f";occupancy={plan.occupancy():.2f}",
         **roofline_fields(mv, t3, w))

    t0_wc = time_fn(spmv.wc_naive, p.phi, p.dictionary, y)
    t1_wc = time_fn(spmv.wc_atom_sorted, phi_f, p.dictionary, y)
    t2_wc = time_fn(spmv.wc, phi_f, p.dictionary, y)
    emit("table8.wc.naive", t0_wc, "1.00x")
    emit("table8.wc.restructured", t1_wc, f"{t0_wc / t1_wc:.2f}x")
    emit("table8.wc.segment", t2_wc, f"{t0_wc / t2_wc:.2f}x",
         **roofline_fields(lambda y_: spmv.wc(phi_f, p.dictionary, y_),
                           t2_wc, y))
    ct, rt = auto_tile(np.asarray(phi_f.fibers), p.phi.n_fibers)
    wc_plan = plan_tiles(np.asarray(phi_f.fibers), p.phi.n_fibers,
                         c_tile=ct, row_tile=rt)
    rv = kops.make_wc(phi_f, p.dictionary, wc_plan, interpret=True)
    t4 = time_fn(rv, y, warmup=1, repeats=2)
    emit("table8.wc.kernel-interpret", t4,
         f"occupancy={wc_plan.occupancy():.2f}",
         **roofline_fields(rv, t4, y))


if __name__ == "__main__":
    run()
