"""Gate a benchmark JSON against a checked-in baseline.

    python benchmarks/check_regression.py baseline.json new.json --factor 2.0

Fails (exit 1) when any row named in the baseline is missing from the new
run (a gate must not pass by silently dropping coverage) or is more than
``--factor`` times slower after machine-speed normalization.

A baseline row may instead carry a ``max_value`` field: the new value must
stay at or below that absolute ceiling — no calibration scaling, no
factor.  This is for dimensionless invariant rows (byte ratios, counts)
where machine speed is irrelevant and the bound is a design claim, e.g.
``table12.resident.fcoo_over_sell`` pinning F-COO's one-copy residency
under 0.6x of SELL's two op-specific encodes.

``--metrics PATH`` additionally gates the observability snapshot written
by ``benchmarks/run.py --metrics`` (schema ``obs-1``): the plan cache's
warm path must be perfect — gauge ``plan_cache.warm.hit_rate`` == 1.0 over
a non-zero lookup count.  A warm rebuild that misses even once means plan
keys stopped being stable across processes, which silently turns every
serving bucket rebuild into a re-tune.  The snapshot also gates
``serve.jobs.failed == 0`` (table13 failure isolation) and
``select.coldstart.measurements == 0`` (table16: the learned cold-start
path answered a plan-cache miss without timing a single candidate).

Normalization: both payloads carry ``calibration_us`` — the median time of
a fixed interpret-mode kernel call on the machine that produced them.  The
baseline's times are rescaled by the calibration ratio before the factor
is applied; without this, a baseline captured on one CI machine generation
would gate pure hardware noise on the next.  The scale is clamped to
[1.0, 4.0]: a slower machine loosens the gate proportionally, but a faster
(or luckily-timed) calibration never *tightens* it — the gate's job is
catching real slowdowns, not manufacturing them from calibration noise.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))


def load(path):
    with open(path) as f:
        payload = json.load(f)
    rows = {r["name"]: r for r in payload["results"]}
    return payload, rows


def check_metrics(path) -> list:
    """Invariant checks over an obs snapshot; returns failure strings."""
    from repro.obs import snapshot_value
    with open(path) as f:
        snap = json.load(f)
    failures = []
    hit_rate = snapshot_value(snap, "gauges", "plan_cache.warm.hit_rate")
    lookups = snapshot_value(snap, "gauges", "plan_cache.warm.lookups")
    print(f"metrics: plan_cache.warm hit_rate={hit_rate} lookups={lookups}")
    if not lookups:
        failures.append("plan_cache.warm.lookups is zero/absent — the "
                        "warm-path probe did not run")
    if hit_rate != 1.0:
        failures.append(f"plan_cache.warm.hit_rate == {hit_rate}, "
                        f"expected 1.0 (warm rebuild must replay every "
                        f"plan from disk)")
    # table13's benign trace runs after its failure-injection scenario
    # resets the registry: any nonzero count here means failure isolation
    # misfired on healthy tenants (or the serving trace did not run at all)
    failed = snapshot_value(snap, "counters", "serve.jobs.failed")
    print(f"metrics: serve.jobs.failed={failed}")
    if failed is None:
        failures.append("serve.jobs.failed absent — the table13 serving "
                        "trace did not run")
    elif failed != 0.0:
        failures.append(f"serve.jobs.failed == {failed}, expected 0 on the "
                        f"benign table13 trace (a healthy tenant was "
                        f"condemned by failure isolation)")
    # table16's predicted cold start must not have timed anything: the
    # learn subsystem's whole contract is that a cache miss answered by the
    # predictor performs zero measurements (DESIGN.md §14)
    coldstart = snapshot_value(snap, "gauges", "select.coldstart.measurements")
    print(f"metrics: select.coldstart.measurements={coldstart}")
    if coldstart is None:
        failures.append("select.coldstart.measurements absent — the table16 "
                        "predicted cold start did not run")
    elif coldstart != 0.0:
        failures.append(f"select.coldstart.measurements == {coldstart}, "
                        f"expected 0 (the predicted cold-start path timed "
                        f"candidates instead of predicting)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("new")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="max allowed normalized slowdown (default 2.0)")
    ap.add_argument("--metrics", metavar="PATH", default=None,
                    help="also gate the obs snapshot at PATH "
                         "(warm plan-cache hit rate == 1.0)")
    args = ap.parse_args(argv)

    base_payload, base = load(args.baseline)
    new_payload, new = load(args.new)

    for p, tag in ((base_payload, "baseline"), (new_payload, "new")):
        print(f"{tag}: jax {p.get('jax_version')} {p.get('backend')}"
              f"x{p.get('device_count')} tables={p.get('tables')} "
              f"digest={p.get('config_digest')}")
    missing_tables = (set(base_payload.get("tables", []))
                      - set(new_payload.get("tables", [])))
    if missing_tables:
        print(f"FAIL: new run did not execute baseline table(s) "
              f"{sorted(missing_tables)} — results are not comparable")
        return 1

    scale = 1.0
    base_cal = base_payload.get("calibration_us")
    new_cal = new_payload.get("calibration_us")
    if base_cal and new_cal:
        scale = min(4.0, max(1.0, float(new_cal) / float(base_cal)))
    print(f"calibration: baseline={base_cal} new={new_cal} scale={scale:.3f}")

    failures = []
    print(f"{'name':40s} {'base_us':>10s} {'new_us':>10s} {'ratio':>7s}")
    for name, row in sorted(base.items()):
        base_us = float(row["us_per_call"])
        if name not in new:
            failures.append(f"missing row: {name}")
            print(f"{name:40s} {base_us:10.1f} {'MISSING':>10s}")
            continue
        new_us = float(new[name]["us_per_call"])
        if row.get("max_value") is not None:
            # absolute ceiling: a machine-independent invariant, gated
            # as-is (no calibration scaling, no factor)
            ceiling = float(row["max_value"])
            flag = ""
            if new_us > ceiling:
                failures.append(f"{name}: {new_us:.4f} exceeds absolute "
                                f"ceiling max_value={ceiling}")
                flag = "  << CEILING"
            print(f"{name:40s} {base_us:10.4f} {new_us:10.4f} "
                  f"{'<=' + format(ceiling, 'g'):>7s}{flag}")
            continue
        allowed = base_us * scale
        ratio = new_us / allowed if allowed > 0 else float("inf")
        flag = ""
        if ratio > args.factor:
            failures.append(f"{name}: {new_us:.1f}us vs allowed "
                            f"{allowed:.1f}us x {args.factor} "
                            f"(ratio {ratio:.2f})")
            flag = "  << REGRESSION"
        print(f"{name:40s} {base_us:10.1f} {new_us:10.1f} "
              f"{ratio:7.2f}{flag}")
    for name in sorted(set(new) - set(base)):
        print(f"{name:40s} {'-':>10s} "
              f"{float(new[name]['us_per_call']):10.1f}    new")

    if args.metrics:
        failures.extend(check_metrics(args.metrics))

    if failures:
        print(f"\nFAIL: {len(failures)} regression(s) vs {args.baseline}:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\nOK: {len(base)} rows within {args.factor}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
