"""Paper Table 4: partitioning x restructuring on the fully-optimized
executor, including the iteration-dependent effect of weight sparsity.

SBBNNLS makes w sparser over iterations; with weight compaction (the BLAS-
call-evasion analogue) DSC time drops as iterations progress — the paper's
Table 4 signature.  Derived: coefficients remaining after compaction.
"""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, problem, time_fn
from repro.core import spmv
from repro.core.life import LifeConfig, LifeEngine
from repro.core.restructure import compact_by_weight, sort_by_host


def run():
    p = problem()
    eng = LifeEngine(p, LifeConfig(executor="opt", n_iters=1))
    w = jnp.ones((p.phi.n_fibers,), jnp.float32)
    for iters in (1, 25, 50):
        w, _ = eng.run(n_iters=iters if iters == 1 else 25, w0=w)
        compacted = compact_by_weight(p.phi, np.asarray(w))
        phi_v, _ = sort_by_host(compacted, "voxel")
        phi_f, _ = sort_by_host(compacted, "fiber")
        t_dsc = time_fn(spmv.dsc, phi_v, p.dictionary, w)
        t_wc = time_fn(spmv.wc, phi_f, p.dictionary, p.b)
        emit(f"table4.dsc.opt.iter{iters}", t_dsc,
             f"nnz={compacted.n_coeffs}")
        emit(f"table4.wc.opt.iter{iters}", t_wc,
             f"nnz={compacted.n_coeffs}")


if __name__ == "__main__":
    run()
