"""Paper Table 10: end-to-end SBBNNLS across code versions.

Derived: speedup over the naive version (the paper reports 27.12x CPU-opt /
CPU-naive on 16 cores; on one CPU core the gap reflects the lowering quality
— scatter vs sorted segments — plus weight compaction).
"""
from benchmarks.common import emit, problem, time_fn
from repro.core.life import LifeConfig, LifeEngine


def run():
    p = problem()
    n_iters = 20
    times = {}
    for ex, extra in (("naive", {}), ("opt-paper", {}), ("opt", {}),
                      ("opt+compact", {"compact_every": 10}),
                      ("auto", {})):
        name = ex.split("+")[0] if "+" in ex else ex
        eng = LifeEngine(p, LifeConfig(executor=name, n_iters=n_iters,
                                       **extra))
        us = time_fn(lambda e=eng: e.run(), warmup=1, repeats=2)
        times[ex] = us
        note = f"{times['naive'] / us:.2f}x" if "naive" in times else "1.00x"
        if "compact" in ex:
            # each compaction epoch re-runs the inspector AND re-jits the
            # solver; at 20 bench iterations that cost dominates — it
            # amortizes over the paper's 500-iteration production runs
            note += ";includes 2 inspector+recompile cycles"
        emit(f"table10.{ex}", us, note)


if __name__ == "__main__":
    run()
