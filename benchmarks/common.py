"""Shared benchmark utilities: timing, CSV emission, JSON collection.

Every table prints ``name,us_per_call,derived`` rows (derived column holds
the table-specific metric: speedup, bytes, iterations/s, ...).  Rows are
also collected in :data:`RESULTS` so ``benchmarks/run.py --json`` can emit
the machine-readable trajectory CI gates on.

Timing protocol: ``warmup`` blocking calls (compile + cache warm), then the
**median** of ``repeats`` blocking calls — the median (not the mean) so one
scheduler hiccup can't poison a row the regression gate compares against.
``$REPRO_BENCH_WARMUP`` / ``$REPRO_BENCH_REPEATS`` override every call
site's own values, letting CI harden the gate lane (more repeats) without
touching per-table code.
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.obs import quantile

#: rows collected for --json: dicts of name / us_per_call / derived
RESULTS = []


def reset_results():
    RESULTS.clear()


def time_fn(fn, *args, warmup=2, repeats=5):
    """Median wall time of a blocking call, in microseconds."""
    warmup = int(os.environ.get("REPRO_BENCH_WARMUP", warmup))
    repeats = max(1, int(os.environ.get("REPRO_BENCH_REPEATS", repeats)))
    for _ in range(warmup):
        _block(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _block(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return quantile(times, 50.0)


def _block(out):
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return out


def emit(name, us, derived="", **fields):
    """Record one row.  ``fields`` ride only in the JSON payload (e.g. the
    roofline annotations below); the printed CSV stays three columns."""
    row = dict(name=str(name), us_per_call=float(us), derived=str(derived))
    row.update(fields)
    RESULTS.append(row)
    print(f"{name},{us:.1f},{derived}")


def roofline_fields(fn, us, *args):
    """Roofline annotation fields for a timed jittable call.

    Lowers ``fn(*args)`` and runs the trip-count-aware HLO cost model over
    the compiled text, turning the measured microseconds into an
    achieved-HBM-bandwidth fraction against the v5e roofline (analysis.HW),
    plus the full Roofline term breakdown.  Best-effort: returns ``{}``
    when the callable can't be lowered to costable HLO (interpret-mode
    Pallas bodies always can — the cost model reads the HLO custom-call
    wrapper's operands)."""
    from repro.roofline import hlo_cost
    from repro.roofline.analysis import HW, roofline
    try:
        txt = jax.jit(fn).lower(*args).compile().as_text()
        cost = hlo_cost.analyze(txt, n_chips=1)
    except Exception:
        return {}
    secs = us / 1e6
    achieved = cost.bytes_accessed / secs if secs > 0 else 0.0
    r = roofline(cost.flops, cost.bytes_accessed, 0.0, 1, cost.flops)
    return dict(bytes_accessed=cost.bytes_accessed,
                achieved_gbps=achieved / 1e9,
                roofline_frac=achieved / HW["hbm_bw"],
                roofline=r.as_dict())


def calibration_us():
    """Median time of a fixed Pallas-interpret SELL kernel call — the
    machine-speed yardstick recorded in the JSON payload.

    ``check_regression.py`` rescales a baseline captured on different
    hardware by the calibration ratio before applying its slowdown factor
    (an absolute 2x gate across unknown CI machine generations would
    otherwise be pure noise).  The yardstick is deliberately the same cost
    family as the gated rows — interpret-mode kernel dispatch — because a
    plain XLA matmul does not track it: machines with identical matmul
    throughput can differ 2x in dispatch overhead."""
    import jax.numpy as jnp
    from repro.kernels.dsc import dsc_sell_pallas
    atoms = jnp.zeros((64, 32), jnp.int32)
    scaled = jnp.ones((64, 32), jnp.float32)
    d = jnp.ones((32, 128), jnp.float32)
    f = jax.jit(lambda a, s: dsc_sell_pallas(a, s, d, row_tile=8,
                                             slot_tile=16, interpret=True))
    return time_fn(f, atoms, scaled, warmup=2, repeats=5)


def problem(scale="bench"):
    from repro.data.dmri import synth_connectome
    if scale == "bench":
        return synth_connectome(n_fibers=1024, n_theta=96, n_atoms=96,
                                grid=(20, 20, 20), algorithm="PROB", seed=5)
    return synth_connectome(n_fibers=128, n_theta=32, n_atoms=32,
                            grid=(10, 10, 10), seed=5)
