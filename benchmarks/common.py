"""Shared benchmark utilities: timing + CSV emission.

Every table prints ``name,us_per_call,derived`` rows (derived column holds
the table-specific metric: speedup, bytes, iterations/s, ...).
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax


def time_fn(fn, *args, warmup=2, repeats=5):
    """Median wall time of a blocking call, in microseconds."""
    for _ in range(warmup):
        _block(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _block(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def _block(out):
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return out


def emit(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")


def problem(scale="bench"):
    from repro.data.dmri import synth_connectome
    if scale == "bench":
        return synth_connectome(n_fibers=1024, n_theta=96, n_atoms=96,
                                grid=(20, 20, 20), algorithm="PROB", seed=5)
    return synth_connectome(n_fibers=128, n_theta=32, n_atoms=32,
                            grid=(10, 10, 10), seed=5)
