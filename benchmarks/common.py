"""Shared benchmark utilities: timing, CSV emission, JSON collection.

Every table prints ``name,us_per_call,derived`` rows (derived column holds
the table-specific metric: speedup, bytes, iterations/s, ...).  Rows are
also collected in :data:`RESULTS` so ``benchmarks/run.py --json`` can emit
the machine-readable trajectory CI gates on.

Timing protocol: ``warmup`` blocking calls (compile + cache warm), then the
**median** of ``repeats`` blocking calls — the median (not the mean) so one
scheduler hiccup can't poison a row the regression gate compares against.
``$REPRO_BENCH_WARMUP`` / ``$REPRO_BENCH_REPEATS`` override every call
site's own values, letting CI harden the gate lane (more repeats) without
touching per-table code.
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

#: rows collected for --json: dicts of name / us_per_call / derived
RESULTS = []


def reset_results():
    RESULTS.clear()


def time_fn(fn, *args, warmup=2, repeats=5):
    """Median wall time of a blocking call, in microseconds."""
    warmup = int(os.environ.get("REPRO_BENCH_WARMUP", warmup))
    repeats = max(1, int(os.environ.get("REPRO_BENCH_REPEATS", repeats)))
    for _ in range(warmup):
        _block(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _block(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def _block(out):
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return out


def emit(name, us, derived=""):
    RESULTS.append(dict(name=str(name), us_per_call=float(us),
                        derived=str(derived)))
    print(f"{name},{us:.1f},{derived}")


def calibration_us():
    """Median time of a fixed Pallas-interpret SELL kernel call — the
    machine-speed yardstick recorded in the JSON payload.

    ``check_regression.py`` rescales a baseline captured on different
    hardware by the calibration ratio before applying its slowdown factor
    (an absolute 2x gate across unknown CI machine generations would
    otherwise be pure noise).  The yardstick is deliberately the same cost
    family as the gated rows — interpret-mode kernel dispatch — because a
    plain XLA matmul does not track it: machines with identical matmul
    throughput can differ 2x in dispatch overhead."""
    import jax.numpy as jnp
    from repro.kernels.dsc import dsc_sell_pallas
    atoms = jnp.zeros((64, 32), jnp.int32)
    scaled = jnp.ones((64, 32), jnp.float32)
    d = jnp.ones((32, 128), jnp.float32)
    f = jax.jit(lambda a, s: dsc_sell_pallas(a, s, d, row_tile=8,
                                             slot_tile=16, interpret=True))
    return time_fn(f, atoms, scaled, warmup=2, repeats=5)


def problem(scale="bench"):
    from repro.data.dmri import synth_connectome
    if scale == "bench":
        return synth_connectome(n_fibers=1024, n_theta=96, n_atoms=96,
                                grid=(20, 20, 20), algorithm="PROB", seed=5)
    return synth_connectome(n_fibers=128, n_theta=32, n_atoms=32,
                            grid=(10, 10, 10), seed=5)
