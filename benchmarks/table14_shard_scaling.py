"""Beyond-paper: sharded-serving throughput vs device count (DESIGN.md §9).

Subjects/sec through the format-aware sharded executors (`shard` over inner
COO cells, `shard-sell` over per-cell SELL tiles) on 1/2/4/8 forced host
devices, one subprocess per topology (XLA_FLAGS must be set before jax
imports).  The container has one physical core, so wall times measure the
*schedule*; the derived column therefore also reports the per-cell padding
overhead — the quantity the equal-nnz partition and the per-cell layout
trade against each other.
"""
import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import sys; sys.path.insert(0, {src!r})
import time, json, dataclasses
import numpy as np, jax
from repro.data.dmri import synth_cohort
from repro.core.life import LifeConfig, LifeEngine

R, C = {rc}
cohort = synth_cohort(1, base_seed=7, n_fibers=256, n_theta=32, n_atoms=32,
                      grid=(12, 12, 12))
REPEATS = 3
out = {{}}
for name, fmt in (("shard", "coo"), ("shard-sell", "sell")):
    cfg = LifeConfig(executor=name, format=fmt, shard_rows=R, shard_cols=C,
                     n_iters=10, slot_tile=16, plan_cache_dir="")
    # one engine per topology: time the sharded *solve*, not per-engine
    # trace/compile + host encoding (those are amortized by the plan cache
    # and jit cache in a serving deployment)
    eng = LifeEngine(cohort[0], cfg)
    eng.run(2)                                  # compile both SpMV closures
    t0 = time.perf_counter()
    for _ in range(REPEATS):
        eng.run()                               # one full subject solve
    dt = time.perf_counter() - t0
    sp = eng.executor.plans["shard_dsc"]
    out[name] = dict(subjects_per_sec=REPEATS / dt,
                     padding_overhead=sp.padding_overhead)
print(json.dumps(out))
"""


def run():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    for n, rc in ((1, (1, 1)), (2, (2, 1)), (4, (2, 2)), (8, (4, 2))):
        code = _CODE.format(n=n, src=os.path.abspath(src), rc=rc)
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                              capture_output=True, text=True, env=env,
                              timeout=1200)
        if proc.returncode != 0:
            emit(f"table14.devices{n}", 0.0, "ERROR")
            continue
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        for name, r in rec.items():
            emit(f"table14.{name}.devices{n}",
                 1e6 / max(r["subjects_per_sec"], 1e-9),
                 f"subjects_per_sec={r['subjects_per_sec']:.3f};"
                 f"padding_overhead={r['padding_overhead']:.2f}")


if __name__ == "__main__":
    run()
