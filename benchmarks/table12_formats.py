"""Beyond-paper: per-format DSC/WC comparison (the formats/ subsystem).

One row per (format, op) with the padding-overhead and resident-bytes
accounting in the derived column (';'-separated so the CSV stays 3 columns)
— the audit trail for ``formats/select.py``: the final rows report the
selector's choice and the statistics it derived it from.

Formats are timed through the executors they actually run with off-kernel:
sorted-COO segment-sum ops, the SELL jnp reference (same dataflow as the
Pallas kernels without interpret-mode noise), and the ALTO-ordered scatter
ops over one linearized copy.
"""
import jax.numpy as jnp

from benchmarks.common import emit, problem, roofline_fields, time_fn
from repro.core import spmv
from repro.formats import AltoPhi, CooPhi, FcooPhi, SellPhi
from repro.formats import select as fsel
from repro.formats.fcoo import dsc_reference as fcoo_dsc
from repro.formats.fcoo import wc_reference as fcoo_wc
from repro.formats.sell import dsc_reference, wc_reference


def run():
    p = problem()
    d = p.dictionary
    w = jnp.ones((p.phi.n_fibers,), jnp.float32)
    y = p.b

    coo_dsc = CooPhi.encode(p.phi, op="dsc")
    coo_wc = CooPhi.encode(p.phi, op="wc")
    sell_dsc = SellPhi.encode(p.phi, op="dsc")
    sell_wc = SellPhi.encode(p.phi, op="wc")
    alto, _ = AltoPhi.encode(p.phi).sort()
    phi_lin = alto.decode()
    fc = FcooPhi.encode(p.phi)                 # ONE encode serves both ops

    rows = [
        ("coo", "dsc", lambda: spmv.dsc(coo_dsc.phi, d, w),
         coo_dsc.padding_overhead, coo_dsc.nbytes),
        ("coo", "wc", lambda: spmv.wc(coo_wc.phi, d, y),
         coo_wc.padding_overhead, coo_wc.nbytes),
        ("sell", "dsc", lambda: dsc_reference(sell_dsc, d, w),
         sell_dsc.padding_overhead, sell_dsc.nbytes),
        ("sell", "wc", lambda: wc_reference(sell_wc, d, y),
         sell_wc.padding_overhead, sell_wc.nbytes),
        ("alto", "dsc", lambda: spmv.dsc_naive(phi_lin, d, w),
         alto.padding_overhead, alto.nbytes),
        ("alto", "wc", lambda: spmv.wc_naive(phi_lin, d, y),
         alto.padding_overhead, alto.nbytes),
        ("fcoo", "dsc", lambda: fcoo_dsc(fc, d, w),
         fc.padding_overhead, fc.nbytes),
        ("fcoo", "wc", lambda: fcoo_wc(fc, d, y),
         fc.padding_overhead, fc.nbytes),
    ]
    for fmt, op, fn, overhead, nbytes in rows:
        us = time_fn(fn)
        emit(f"table12.{op}.{fmt}", us,
             f"pad={overhead:.2f}x;mbytes={nbytes / 1e6:.2f}",
             **roofline_fields(fn, us))

    # the F-COO residency claim (Liu et al. 1705.09905): one linearized
    # copy serving both ops vs SELL's two op-specific encodes.  The row's
    # value is the byte ratio itself (dimensionless, not microseconds) so
    # the regression gate can pin it under an absolute ``max_value``.
    sell_total = sell_dsc.nbytes + sell_wc.nbytes
    emit("table12.resident.fcoo_over_sell", fc.nbytes / sell_total,
         f"fcoo_mb={fc.nbytes / 1e6:.2f};sell_mb={sell_total / 1e6:.2f}")

    plan = fsel.choose_format(p.phi, d)
    emit("table12.selected", 0.0,
         f"{plan.format};{plan.reason}")
    for k in ("dsc.sell_overhead", "wc.sell_overhead",
              "dsc.run_mean", "wc.run_mean"):
        emit(f"table12.stat.{k}", 0.0, f"{plan.stats.get(k, float('nan')):.3f}")


if __name__ == "__main__":
    run()
