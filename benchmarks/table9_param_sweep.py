"""Paper Table 9: execution time vs LiFE parameters (fibers, tractography).

Sweeps fiber count and tractography algorithm on the optimized executor;
derived column: Phi nnz (the paper's "Phi size" column analogue) and the
per-iteration SBBNNLS time.
"""
from benchmarks.common import emit, time_fn
from repro.core.life import LifeConfig, LifeEngine
from repro.data.dmri import TRACTOGRAPHY, synth_connectome


def run():
    for algo in sorted(TRACTOGRAPHY):
        p = synth_connectome(n_fibers=512, n_theta=96, n_atoms=96,
                             grid=(16, 16, 16), algorithm=algo, seed=6)
        eng = LifeEngine(p, LifeConfig(executor="opt", n_iters=1))
        us = time_fn(lambda: eng.run(n_iters=2), warmup=1, repeats=2) / 2
        emit(f"table9.algo.{algo}", us, f"nnz={p.phi.n_coeffs}")

    for nf in (256, 512, 1024, 2048):
        p = synth_connectome(n_fibers=nf, n_theta=96, n_atoms=96,
                             grid=(16, 16, 16), algorithm="PROB", seed=7)
        eng = LifeEngine(p, LifeConfig(executor="opt", n_iters=1))
        us = time_fn(lambda: eng.run(n_iters=2), warmup=1, repeats=2) / 2
        emit(f"table9.fibers.{nf}", us, f"nnz={p.phi.n_coeffs}")


if __name__ == "__main__":
    run()
