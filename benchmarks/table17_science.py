"""Beyond-paper Table 17: science workloads — warm vs cold solves.

Prices the connectome-pruning workload layer (DESIGN.md §15) the stack
exists to serve.  The headline comparison: a virtual-lesion re-solve
warm-started from the previous converged weights vs the same lesioned
problem solved cold, both run to the same convergence criterion —

* ``table17.lesion.cold`` / ``table17.lesion.warm`` — wall time of the
  two re-solves, iterations in the derived column.
* ``table17.lesion.warm_over_cold_iters`` — the iteration ratio as the
  row value.  The checked-in baseline pins it with ``max_value: 1.0``,
  making "a warm start never takes more iterations than a cold start" a
  machine-independent CI invariant (counts, not microseconds).
* ``table17.serve.cold`` / ``table17.serve.warm`` — the same pair as
  end-to-end latency through the async serving front line: the warm job
  is a repeat-visit ``w0`` resubmission of the lesioned problem, so it
  also exercises warm plan-cache hits on the re-bucketed engine build.
* ``table17.crossval`` — wall time of a k-fold cross-validated RMSE,
  held-out error in the derived column.
* ``table17.multires.direct`` / ``.coarse2fine`` — full-resolution cold
  solve vs the coarse-to-fine schedule that warm-starts the fine level
  from a coarsened solve.

Solves are single-shot (``time.perf_counter``): iterations-to-
convergence is the quantity under test, and a warmed-up rerun would hit
the very plan caches whose first-visit cost belongs in the end-to-end
number.
"""
import time

from benchmarks.common import emit
from repro.core.life import LifeConfig, LifeEngine
from repro.data.dmri import fiber_bundles, synth_connectome
from repro.science import (crossval_rmse, lesion_problem, multires_solve,
                           prune_connectome, solve_to_convergence,
                           virtual_lesion, warm_start_weights)

SPEC = dict(n_fibers=256, n_theta=32, n_atoms=32, grid=(12, 12, 12),
            algorithm="PROB", noise=0.02, seed=171)

RTOL, CHUNK, MAX_ITERS = 1e-5, 8, 400


def _solve(problem, cfg, w0=None):
    t0 = time.perf_counter()
    res = solve_to_convergence(LifeEngine(problem, cfg), w0=w0, rtol=RTOL,
                               chunk=CHUNK, max_iters=MAX_ITERS)
    return res, (time.perf_counter() - t0) * 1e6


def run():
    import tempfile

    problem = synth_connectome(**SPEC)
    bundle = fiber_bundles(problem, bundle_size=12, seed=172)[0]
    with tempfile.TemporaryDirectory() as cache_dir:
        cfg = LifeConfig(executor="opt", plan_cache_dir=cache_dir)

        # --- full solve + pruning (the baseline science artifact) --------
        full, full_us = _solve(problem, cfg)
        pruned = prune_connectome(problem, full.w, threshold=1e-3)
        emit("table17.solve.full", full_us,
             f"iters={full.iters};kept={pruned.n_kept}/"
             f"{pruned.n_fibers_total}")

        # --- virtual lesion: warm vs cold re-solve -----------------------
        lesioned = lesion_problem(problem, bundle)
        cold, cold_us = _solve(lesioned, cfg)
        warm, warm_us = _solve(lesioned, cfg,
                               w0=warm_start_weights(full.w, bundle))
        report = virtual_lesion(problem, bundle, cfg, w_full=full.w,
                                rtol=RTOL, chunk=CHUNK, max_iters=MAX_ITERS)
        emit("table17.lesion.cold", cold_us, f"iters={cold.iters}")
        emit("table17.lesion.warm", warm_us,
             f"iters={warm.iters};"
             f"iter_speedup={cold.iters / max(1, warm.iters):.2f};"
             f"evidence={report.evidence:+.5f}")
        # the iteration ratio as the row value: the baseline's
        # max_value: 1.0 ceiling gates warm <= cold machine-independently
        emit("table17.lesion.warm_over_cold_iters",
             warm.iters / max(1, cold.iters),
             "invariant: warm start never needs more iterations",
             max_value=1.0)

        # --- the same pair through the serving front line ----------------
        from repro.serve.frontend import LifeFrontend
        with LifeFrontend(LifeConfig(executor="opt",
                                     plan_cache_dir=cache_dir),
                          refine=False) as fe:
            t0 = time.perf_counter()
            fe.submit_async(lesioned, n_iters=cold.iters).result(timeout=600)
            serve_cold_us = (time.perf_counter() - t0) * 1e6
            t0 = time.perf_counter()
            fe.submit_async(lesioned, n_iters=warm.iters,
                            w0=warm_start_weights(full.w, bundle)
                            ).result(timeout=600)
            serve_warm_us = (time.perf_counter() - t0) * 1e6
        emit("table17.serve.cold", serve_cold_us, f"n_iters={cold.iters}")
        emit("table17.serve.warm", serve_warm_us,
             f"n_iters={warm.iters};"
             f"speedup={serve_cold_us / max(serve_warm_us, 1e-9):.2f}")

        # --- k-fold cross-validated RMSE ---------------------------------
        t0 = time.perf_counter()
        cv = crossval_rmse(problem, cfg, k=3, seed=173, n_iters=40)
        emit("table17.crossval", (time.perf_counter() - t0) * 1e6,
             f"k=3;rmse={cv.mean_rmse:.5f};null={cv.null_rmse:.5f};"
             f"ratio={cv.relative_rmse:.3f}")

        # --- coarse-to-fine multi-resolution -----------------------------
        emit("table17.multires.direct", full_us, f"iters={full.iters}")
        t0 = time.perf_counter()
        mr = multires_solve(problem, cfg, factors=(2,), rtol=RTOL,
                            chunk=CHUNK, max_iters=MAX_ITERS)
        mr_us = (time.perf_counter() - t0) * 1e6
        fine_iters = mr.levels[-1]["iters"]
        emit("table17.multires.coarse2fine", mr_us,
             f"levels={'+'.join(str(lv['iters']) for lv in mr.levels)};"
             f"fine_iters={fine_iters};full_iters={full.iters}")


if __name__ == "__main__":
    run()
