"""Beyond-paper Table 15: tuned vs frozen kernel launch parameters.

The paper's Table 9 sweeps LiFE parameters per platform by hand; this table
is the same sweep executed by the tune subsystem's search space (DESIGN.md
§10): for each shape, every `(row_tile, slot_tile)` candidate from
``repro/tune/space.py`` is bound to a real `kernel-sell` engine and its
SELL DSC kernel is timed under one shared protocol; the table reports the
frozen-constant configuration (the space's first candidate, by
construction) against the measured winner.  Because the winner is the
argmin over a candidate set that contains the default — from the *same*
measurements being reported — the derived `speedup` column is >= 1.0 on
every shape by construction, not by luck: exactly the invariant CI's
bench-smoke lane archives in BENCH_15.json.

(The engine-level `tune="full"` path optimizes the weighted DSC+WC
iteration mix and is regression-tested in tests/test_tune.py; this table
isolates the DSC axis the paper's kernel discussion centers on.)
"""
import dataclasses

import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.life import LifeConfig, LifeEngine
from repro.data.dmri import synth_connectome
from repro.tune.space import search_space

SHAPES = (
    dict(tag="prob-128", n_fibers=128, n_theta=32, n_atoms=32,
         grid=(8, 8, 8), algorithm="PROB", seed=151),
    dict(tag="det-160", n_fibers=160, n_theta=32, n_atoms=32,
         grid=(8, 8, 8), algorithm="DET", seed=152),
    dict(tag="prob-224", n_fibers=224, n_theta=48, n_atoms=48,
         grid=(10, 10, 10), algorithm="PROB", seed=153),
)


def run():
    for spec in SHAPES:
        spec = dict(spec)
        tag = spec.pop("tag")
        p = synth_connectome(**spec)
        base = LifeConfig(executor="opt", format="sell", n_iters=1,
                          plan_cache_dir="")
        w = jnp.ones((p.phi.n_fibers,), p.dictionary.dtype)

        measured = []
        for cand in search_space("kernel-sell", base):
            cfg = dataclasses.replace(base, **cand["params"])
            eng = LifeEngine(p, cfg)
            measured.append((time_fn(eng.matvec, w), cand["params"]))
        us_def, params_def = measured[0]     # space always leads with the
        us_best, params_best = min(measured, key=lambda t: t[0])  # defaults

        fmt = lambda ps: ";".join(f"{k}={v}" for k, v in sorted(ps.items()))
        emit(f"table15.default.{tag}", us_def,
             f"nnz={p.phi.n_coeffs};{fmt(params_def)}")
        emit(f"table15.tuned.{tag}", us_best,
             f"nnz={p.phi.n_coeffs};{fmt(params_best)};"
             f"speedup={us_def / max(us_best, 1e-9):.2f}")


if __name__ == "__main__":
    run()
