"""Benchmark harness: one module per paper table.

Prints ``name,us_per_call,derived`` CSV.  Run:
    PYTHONPATH=src python -m benchmarks.run [table ...]
"""
import sys
import time

from benchmarks import (table2_restructuring, table3_partitioning,
                        table4_opt_combos, table5_scaling,
                        table8_kernel_ladder, table9_param_sweep,
                        table10_end2end, table11_batched, table12_formats,
                        table13_service, table14_shard_scaling)

TABLES = {
    "table2": table2_restructuring,
    "table3": table3_partitioning,
    "table4": table4_opt_combos,
    "table5": table5_scaling,
    "table8": table8_kernel_ladder,   # covers paper tables 6-8
    "table9": table9_param_sweep,
    "table10": table10_end2end,
    "table11": table11_batched,       # beyond-paper: multi-subject batching
    "table12": table12_formats,       # beyond-paper: Phi format comparison
    "table13": table13_service,       # beyond-paper: serving under open-loop load
    "table14": table14_shard_scaling, # beyond-paper: sharded subjects/sec scaling
}


def main() -> None:
    wanted = sys.argv[1:] or list(TABLES)
    print("name,us_per_call,derived")
    for name in wanted:
        t0 = time.time()
        TABLES[name].run()
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
