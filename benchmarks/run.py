"""Benchmark harness: one module per paper table.

Prints ``name,us_per_call,derived`` CSV; ``--json PATH`` additionally
writes the machine-readable payload CI's bench-smoke lane gates on
(see benchmarks/check_regression.py):

    PYTHONPATH=src python -m benchmarks.run [--json out.json]
        [--metrics metrics.json] [table ...]

JSON schema (version 1): environment fields (jax version, backend, device
count), a ``config_digest`` identifying the run configuration, a
``calibration_us`` machine-speed yardstick, the ``results`` rows — exactly
the CSV rows as objects (plus optional per-row annotation fields, e.g. the
roofline fields on kernel rows) — and an ``obs_snapshot`` of the
observability registry at end of run.

The harness runs with observability enabled (``repro.obs``), so the
instrumented production stack (plan cache, tuner, engines, serving)
populates the registry as tables execute.  ``--metrics PATH`` writes that
snapshot (plus the recorded span trace in Chrome-trace form) standalone —
the METRICS_CI.json artifact CI uploads and gates with
``check_regression.py --metrics``.  Note tables that reset the registry
for their own bookkeeping (table13 resets per arrival rate) bound what the
end-of-run snapshot accumulates; the gated warm-cache gauges are set after
every table has run.
"""
import argparse
import hashlib
import json
import os
import sys
import time

import jax

from benchmarks import common
from repro import obs
from benchmarks import (table2_restructuring, table3_partitioning,
                        table4_opt_combos, table5_scaling,
                        table8_kernel_ladder, table9_param_sweep,
                        table10_end2end, table11_batched, table12_formats,
                        table13_service, table14_shard_scaling,
                        table15_tuning, table16_coldstart, table17_science)

TABLES = {
    "table2": table2_restructuring,
    "table3": table3_partitioning,
    "table4": table4_opt_combos,
    "table5": table5_scaling,
    "table8": table8_kernel_ladder,   # covers paper tables 6-8
    "table9": table9_param_sweep,
    "table10": table10_end2end,
    "table11": table11_batched,       # beyond-paper: multi-subject batching
    "table12": table12_formats,       # beyond-paper: Phi format comparison
    "table13": table13_service,       # beyond-paper: serving under open-loop load
    "table14": table14_shard_scaling, # beyond-paper: sharded subjects/sec scaling
    "table15": table15_tuning,        # beyond-paper: tuned vs frozen kernel params
    "table16": table16_coldstart,     # beyond-paper: learned zero-measurement cold start
    "table17": table17_science,       # beyond-paper: warm-started science workloads
}

SCHEMA_VERSION = 1


def config_digest(wanted) -> str:
    """Informational identity of the run configuration (not its
    measurements): the table set, the software/platform, and the
    timing-protocol env overrides.  Shown by check_regression.py so a
    surprising gate result can be traced to a configuration difference at
    a glance; the gate's own comparability check is the ``tables`` field
    (baseline tables must all be present in the new run)."""
    h = hashlib.sha256()
    h.update(("|".join(sorted(wanted))).encode())
    h.update(jax.__version__.encode())
    h.update(jax.default_backend().encode())
    h.update(str(len(jax.devices())).encode())
    for var in ("REPRO_BENCH_WARMUP", "REPRO_BENCH_REPEATS"):
        h.update(f"{var}={os.environ.get(var, '')}".encode())
    return h.hexdigest()[:16]


def warm_cache_probe() -> None:
    """Exercise the plan cache's warm path and pin the result in gauges.

    Builds the Pallas-kernel engine twice against one fresh on-disk cache:
    the first build misses and persists its tile plans, the second — read
    through a brand-new PlanCache handle so no in-memory state helps —
    must replay every plan from disk.  Gauges ``plan_cache.warm.hit_rate``
    (CI gates this == 1.0 via ``check_regression.py --metrics``) and
    ``plan_cache.warm.lookups`` record the outcome."""
    import tempfile

    from repro.core.life import LifeConfig, LifeEngine
    from repro.core.plan_cache import PlanCache

    p = common.problem(scale="small")
    with tempfile.TemporaryDirectory() as d:
        cfg = LifeConfig(executor="kernel", plan_cache_dir=d)
        LifeEngine(p, cfg)                       # cold build: miss + persist
        warm = PlanCache(d)
        LifeEngine(p, cfg, warm)                 # warm build: hits only
        obs.gauge("plan_cache.warm.hit_rate").set(warm.stats.hit_rate)
        obs.gauge("plan_cache.warm.lookups").set(float(warm.stats.lookups))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Run benchmark tables; CSV to stdout, optional JSON.")
    ap.add_argument("tables", nargs="*", metavar="table",
                    help=f"subset to run (default: all of {sorted(TABLES)})")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write machine-readable results to PATH")
    ap.add_argument("--metrics", metavar="PATH", default=None,
                    help="write the observability snapshot + span trace "
                         "to PATH (the METRICS_CI.json artifact)")
    args = ap.parse_args(argv)
    unknown = [t for t in args.tables if t not in TABLES]
    if unknown:
        ap.error(f"unknown tables {unknown}; choose from {sorted(TABLES)}")
    wanted = args.tables or list(TABLES)

    common.reset_results()
    obs.enable()
    obs.reset()
    print("name,us_per_call,derived")
    for name in wanted:
        t0 = time.time()
        TABLES[name].run()
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)

    warm_cache_probe()
    # re-pin table16's zero-measurement invariant gauge after every table:
    # table13 resets the registry per arrival-rate scenario, which would
    # otherwise wipe a gauge set mid-run (no-op when table16 did not run)
    table16_coldstart.set_gauges()
    snap = obs.snapshot()

    if args.json:
        payload = dict(
            schema=SCHEMA_VERSION,
            jax_version=jax.__version__,
            backend=jax.default_backend(),
            device_count=len(jax.devices()),
            tables=sorted(wanted),
            config_digest=config_digest(wanted),
            calibration_us=common.calibration_us(),
            results=common.RESULTS,
            obs_snapshot=snap,
        )
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {len(common.RESULTS)} rows to {args.json}",
              file=sys.stderr)

    if args.metrics:
        metrics = dict(snap, trace_events=obs.TRACER.export_chrome())
        with open(args.metrics, "w") as f:
            json.dump(metrics, f, indent=2)
            f.write("\n")
        print(f"# wrote observability snapshot to {args.metrics}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
