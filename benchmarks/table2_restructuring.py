"""Paper Table 2: naive-executor SpMV under each data-restructuring choice.

The paper measures CPU-naive DSC/WC with atom- vs voxel-sorted Phi; here the
naive executor is the scatter/gather formulation and restructuring changes
the access locality the same way (XLA's scatter is sensitive to sortedness).
Derived column: speedup over the unsorted baseline.
"""
import numpy as np

from benchmarks.common import emit, problem, time_fn
from repro.core import spmv
from repro.core.restructure import sort_by_host

import jax.numpy as jnp


def run():
    p = problem()
    w = jnp.ones((p.phi.n_fibers,), jnp.float32)
    y = p.b
    base_dsc = time_fn(spmv.dsc_naive, p.phi, p.dictionary, w)
    base_wc = time_fn(spmv.wc_naive, p.phi, p.dictionary, y)
    emit("table2.dsc.unsorted", base_dsc, "1.00x")
    emit("table2.wc.unsorted", base_wc, "1.00x")
    for dim in ("atom", "voxel", "fiber"):
        phi_s, _ = sort_by_host(p.phi, dim)
        t_dsc = time_fn(spmv.dsc_naive, phi_s, p.dictionary, w)
        t_wc = time_fn(spmv.wc_naive, phi_s, p.dictionary, y)
        emit(f"table2.dsc.{dim}-sorted", t_dsc, f"{base_dsc / t_dsc:.2f}x")
        emit(f"table2.wc.{dim}-sorted", t_wc, f"{base_wc / t_wc:.2f}x")


if __name__ == "__main__":
    run()
