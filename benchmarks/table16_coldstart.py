"""Beyond-paper Table 16: learned zero-measurement cold start.

The paper's runtime selection times three runs per candidate; at serving
scale that sweep is exactly the cold-start cost every unseen dataset pays
before its first plan exists.  This table prices the alternative the learn
subsystem ships (DESIGN.md §14): a fleet of training datasets is solved
once with measured selection (``format="auto"``, ``tune="full"``) into one
plan cache, ``train_predictor`` fits the model beside it, and an *unseen*
dataset is then cold-started twice —

* ``table16.coldstart.measured`` — fresh cache, ``predict="off"``,
  ``tune="full"``: time-to-first-plan includes the full measurement sweep.
* ``table16.coldstart.predicted`` — warm-trained cache, ``tune="cached"``:
  the predictor answers both the format and the tile-parameter miss from
  ``phi_stats`` features alone.
* ``table16.coldstart.measurements`` — the number of ``time_call``
  invocations the predicted build performed.  The value is a count, not a
  time; the checked-in baseline pins it with ``max_value: 0`` (and
  ``check_regression.py --metrics`` gates the matching
  ``select.coldstart.measurements`` gauge), making "zero measurements on
  the predicted path" a CI invariant rather than a doc claim.

Build times are single-shot (``time.perf_counter`` around the engine
constructor): a cold start happens once per dataset by definition, and a
warmup call would populate the very caches whose absence is being priced.
"""
import time

from benchmarks.common import emit
from repro import obs
from repro.core.life import LifeConfig, LifeEngine
from repro.core.plan_cache import PlanCache
from repro.data.dmri import synth_connectome
from repro.learn import train_predictor
from repro.tune import search as tsearch

#: training fleet: small shapes spanning both tractography generators so
#: the harvest sees more than one run-length profile
TRAIN_SPECS = (
    dict(n_fibers=96, n_theta=24, n_atoms=24, grid=(8, 8, 8),
         algorithm="PROB", seed=161),
    dict(n_fibers=128, n_theta=24, n_atoms=24, grid=(8, 8, 8),
         algorithm="DET", seed=162),
    dict(n_fibers=160, n_theta=32, n_atoms=32, grid=(10, 10, 10),
         algorithm="PROB", seed=163),
    dict(n_fibers=128, n_theta=32, n_atoms=32, grid=(10, 10, 10),
         algorithm="DET", seed=164),
)

#: the unseen dataset both cold starts are priced on
UNSEEN_SPEC = dict(n_fibers=192, n_theta=32, n_atoms=32, grid=(9, 9, 9),
                   algorithm="PROB", seed=169)

#: measurement count of the last predicted cold start (None until run());
#: benchmarks/run.py re-exports it as the gauge after all tables finish,
#: out of reach of table13's per-scenario registry resets
LAST_PREDICTED_MEASUREMENTS = None


def _cfg(cache_dir, **kw):
    # compute_dtype="auto" makes the storage dtype a searched axis for
    # every executor — so training harvests reason="search" TunePlans (and
    # the predicted cold start exercises the tune predictor) even when the
    # chosen format maps to an executor without tile axes
    base = dict(executor="opt", format="auto", n_iters=1, tune_budget=4,
                compute_dtype="auto", plan_cache_dir=cache_dir)
    base.update(kw)
    return LifeConfig(**base)


def _build_seconds(problem, config) -> float:
    t0 = time.perf_counter()
    LifeEngine(problem, config)
    return time.perf_counter() - t0


def set_gauges() -> None:
    """Pin the predicted path's measurement count as a gauge (idempotent;
    called by run.py after every table so table13's resets can't wipe it)."""
    if LAST_PREDICTED_MEASUREMENTS is not None:
        obs.gauge("select.coldstart.measurements").set(
            float(LAST_PREDICTED_MEASUREMENTS))


def run():
    global LAST_PREDICTED_MEASUREMENTS
    import tempfile

    unseen = synth_connectome(**UNSEEN_SPEC)
    with tempfile.TemporaryDirectory() as train_dir, \
            tempfile.TemporaryDirectory() as fresh_dir:
        # --- train: measured selection over the fleet fills one cache ----
        t0 = time.perf_counter()
        for spec in TRAIN_SPECS:
            LifeEngine(synth_connectome(**spec),
                       _cfg(train_dir, tune="full", predict="off"))
        train_s = time.perf_counter() - t0
        predictor = train_predictor(PlanCache(train_dir))
        assert predictor is not None, "training cache yielded no examples"
        emit("table16.train", train_s * 1e6,
             f"datasets={len(TRAIN_SPECS)};"
             f"fmt_examples={predictor.n_format_examples};"
             f"tune_examples={predictor.n_tune_examples}")

        # --- measured cold start: the sweep the paper pays ---------------
        n0 = tsearch.measurement_count()
        measured_s = _build_seconds(
            unseen, _cfg(fresh_dir, tune="full", predict="off"))
        measured_n = tsearch.measurement_count() - n0
        emit("table16.coldstart.measured", measured_s * 1e6,
             f"measurements={measured_n}")

        # --- predicted cold start: zero measurements ---------------------
        n0 = tsearch.measurement_count()
        predicted_s = _build_seconds(unseen, _cfg(train_dir, tune="cached"))
        predicted_n = tsearch.measurement_count() - n0
        LAST_PREDICTED_MEASUREMENTS = predicted_n
        set_gauges()
        emit("table16.coldstart.predicted", predicted_s * 1e6,
             f"speedup={measured_s / max(predicted_s, 1e-9):.2f}")
        # a count dressed as the row value so the baseline's max_value: 0
        # ceiling gates it machine-independently
        emit("table16.coldstart.measurements", float(predicted_n),
             "invariant: predicted path measures nothing", max_value=0)


if __name__ == "__main__":
    run()
