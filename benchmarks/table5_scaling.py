"""Paper Table 5: scaling with worker count (threads -> mesh devices).

Runs the 2-D shard_map SBBNNLS on 1/2/4/8 host devices in subprocesses
(XLA_FLAGS per process).  The container has one physical core, so wall times
measure the *schedule* (no real parallel speedup is possible); the derived
column therefore reports the per-device coefficient share — the quantity the
paper's sync-free mapping balances — alongside the time.
"""
import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import sys; sys.path.insert(0, {src!r})
import time, json
import numpy as np, jax, jax.numpy as jnp
from repro.data.dmri import synth_connectome
from repro.distributed import life_shard as LS

p = synth_connectome(n_fibers=1024, n_theta=96, n_atoms=96,
                     grid=(20, 20, 20), algorithm="PROB", seed=5)
R, C = {rc}
from repro import compat
mesh = compat.make_mesh((R, C), ("data", "model"))
shards = LS.build_life_shards(p.phi, 96, R=R, C=C)
step = LS.make_sharded_step(mesh, dict(nv_local=shards.nv_local,
                                       nf_local=shards.nf_local, n_theta=96))
args = LS.sharded_state(mesh, shards, p)
jstep = jax.jit(step)
w = args["w"]
with mesh:
    for it in range(3):   # warmup/compile
        w, loss = jstep(args["da"],args["dv"],args["df"],args["dw"],
                        args["wa"],args["wv"],args["wf"],args["ww"],
                        args["d"], args["b"], w, jnp.asarray(it, jnp.int32))
    loss.block_until_ready()
    t0 = time.perf_counter()
    for it in range(10):
        w, loss = jstep(args["da"],args["dv"],args["df"],args["dw"],
                        args["wa"],args["wv"],args["wf"],args["ww"],
                        args["d"], args["b"], w, jnp.asarray(it, jnp.int32))
    loss.block_until_ready()
    dt = (time.perf_counter() - t0) / 10
print(json.dumps(dict(us=dt*1e6, nnz_cell=int(shards.dsc_values.shape[-1]))))
"""


def run():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    for n, rc in ((1, (1, 1)), (2, (2, 1)), (4, (2, 2)), (8, (4, 2))):
        code = _CODE.format(n=n, src=os.path.abspath(src), rc=rc)
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                              capture_output=True, text=True, env=env,
                              timeout=600)
        if proc.returncode != 0:
            emit(f"table5.devices{n}", 0.0, "ERROR")
            continue
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        emit(f"table5.devices{n}", rec["us"],
             f"nnz_per_cell={rec['nnz_cell']}")


if __name__ == "__main__":
    run()
