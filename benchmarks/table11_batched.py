"""Beyond-paper: multi-subject batched LiFE throughput (subjects/sec).

Compares serving a cohort sequentially (one LifeEngine per subject, the
pre-batching deployment model) against one BatchedLifeEngine running the
vmapped SBBNNLS for the whole cohort.  The derived column reports
subjects/sec and the batched speedup; the last row reports the plan-cache
effect on construction (second engine build on the same dataset).
"""
import time

import numpy as np

from benchmarks.common import emit
from repro.core.batched import BatchedLifeEngine
from repro.core.life import LifeConfig, LifeEngine
from repro.data.dmri import synth_cohort

N_ITERS = 30


def _bench(fn, warmup: int = 1, repeats: int = 3) -> float:
    """Median wall seconds of fn() (fn blocks internally)."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def run():
    cohort = synth_cohort(8, base_seed=40, n_fibers=256, n_theta=64,
                          n_atoms=64, grid=(14, 14, 14))
    cfg = LifeConfig(executor="opt", n_iters=N_ITERS, plan_cache_dir="")

    for s in (1, 2, 4, 8):
        subjects = cohort[:s]

        engines = [LifeEngine(p, cfg) for p in subjects]
        t_seq = _bench(lambda: [e.run() for e in engines])
        emit(f"table11.sequential.s{s}", t_seq * 1e6 / s,
             f"{s / t_seq:.2f}subj/s")

        beng = BatchedLifeEngine(subjects, cfg)
        t_bat = _bench(lambda: beng.run())
        emit(f"table11.batched.s{s}", t_bat * 1e6 / s,
             f"{s / t_bat:.2f}subj/s;speedup={t_seq / t_bat:.2f}x")

    # plan-cache amortization: kernel-engine construction, cold vs warm
    import tempfile
    kcfg = LifeConfig(executor="kernel", n_iters=N_ITERS, c_tile=128,
                      row_tile=8, plan_cache_dir=tempfile.mkdtemp())
    t0 = time.perf_counter()
    cold = LifeEngine(cohort[0], kcfg)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = LifeEngine(cohort[0], kcfg)
    t_warm = time.perf_counter() - t0
    emit("table11.plancache.cold", t_cold * 1e6,
         f"misses={cold.cache_stats.misses}")
    emit("table11.plancache.warm", t_warm * 1e6,
         f"hits={warm.cache_stats.hits};speedup={t_cold / max(t_warm, 1e-9):.1f}x")


if __name__ == "__main__":
    run()
