"""Beyond-paper: serving throughput + latency under an open-loop trace.

Simulates the production deployment (DESIGN.md §8): subjects arrive as a
Poisson process — open-loop, so arrivals do not wait for the service — with
mixed formats and priorities, and the LifeService micro-batches, time-slices
and completes them.  Reported per arrival rate:

  * subjects/sec (completed jobs / wall time of the whole trace)
  * p50 / p95 job latency (completion wall time - arrival wall time)

The contrast with table11 (closed-loop, one pre-formed cohort) is the point:
continuous batching keeps throughput near the batched optimum while bounding
the latency an individual late arrival pays.
"""
import time

import numpy as np

from benchmarks.common import emit
from repro.core.life import LifeConfig
from repro.data.dmri import synth_cohort
from repro.serve import LifeService

N_ITERS = 30
N_JOBS = 8
SLICE = 10


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs), q))


def run_trace(cohort, rate_per_s: float, seed: int = 0):
    """Open-loop arrival trace: submit job i at its pre-drawn arrival time
    regardless of service progress; tick the scheduler in between."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_per_s, size=len(cohort))
    arrivals = np.cumsum(gaps)                    # seconds from t0
    # mixed tenancy: every third job asks for SELL (solo bucket), one in
    # four is high priority
    specs = [("sell" if i % 3 == 2 else "coo", 5 if i % 4 == 0 else 0)
             for i in range(len(cohort))]

    svc = LifeService(LifeConfig(executor="opt", n_iters=N_ITERS,
                                 plan_cache_dir=""), slice_iters=SLICE)
    t0 = time.perf_counter()
    submitted = 0
    finish_at = {}
    arrive_at = {}
    while submitted < len(cohort) or svc.scheduler.active():
        now = time.perf_counter() - t0
        while submitted < len(cohort) and arrivals[submitted] <= now:
            fmt, pri = specs[submitted]
            jid = svc.submit(cohort[submitted], job_id=f"s{submitted}",
                             n_iters=N_ITERS, format=fmt, priority=pri)
            arrive_at[jid] = now
            submitted += 1
        if svc.scheduler.active():
            for job in svc.step():
                finish_at[job.job_id] = time.perf_counter() - t0
        elif submitted < len(cohort):
            time.sleep(max(0.0, min(0.001, arrivals[submitted] - now)))
    wall = time.perf_counter() - t0
    lats = [finish_at[j] - arrive_at[j] for j in finish_at]
    return wall, lats


def run():
    cohort = synth_cohort(N_JOBS, base_seed=50, n_fibers=256, n_theta=64,
                          n_atoms=64, grid=(14, 14, 14))
    for rate in (2.0, 8.0, 32.0):
        wall, lats = run_trace(cohort, rate)
        emit(f"table13.service.rate{rate:g}",
             1e6 * float(np.mean(lats)),
             f"{len(lats) / wall:.2f}subj/s;"
             f"p50={_percentile(lats, 50) * 1e3:.0f}ms;"
             f"p95={_percentile(lats, 95) * 1e3:.0f}ms")


if __name__ == "__main__":
    run()
