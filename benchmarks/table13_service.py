"""Beyond-paper: async serving throughput + latency under an open-loop trace.

Simulates the production deployment (DESIGN.md §8, §13): subjects arrive
as a Poisson process — open-loop, so arrivals do not wait for the service —
with mixed formats and priorities, submitted through the async front line
(:class:`repro.serve.LifeFrontend`): ``submit_async`` returns a handle, the
frontend's driver thread owns the tick loop, and the producer only blocks
when the bounded admission queue backpressures it.

Before the benign rates, a failure-isolation scenario (§13.3) runs one
always-raising tenant against a deliberately tiny admission queue: every
healthy job must complete, only the poisoned job may fail, and its
exception must surface on its handle — the wedge-on-error regression gate,
exercised at benchmark scale rather than test scale.

The table is also the observability layer's end-to-end exercise: every
reported number is read back from the ``repro.obs`` registry the serving
stack instruments (DESIGN.md §12), not from ad-hoc bookkeeping in this
file.  Per arrival rate:

  * subjects/sec        counter ``serve.jobs.completed`` / trace wall time
  * p50 / p95 latency   histogram ``serve.job.latency.seconds`` (measured
                        from service admission; admission-queue wait under
                        backpressure is bounded by the driver's drain rate)
  * queue depth         histogram ``serve.queue.depth`` (mean/max)
  * plan-cache hit rate gauge ``plan_cache.hit_rate`` (via
                        ``LifeService.metrics_snapshot()``)

Rates run against one shared on-disk plan cache, so ``format="auto"``
bucket builds re-resolve their FormatPlan from it — the failure scenario
and the first rate seed the cache, later rates replay it warm.
``obs.reset()`` between rates zeroes the registry in place (held
instrument handles stay live), giving each rate fresh numbers without
rebuilding the stack.  The benign rates run *after* the failure scenario's
reset, so the end-of-run snapshot CI gates (METRICS_CI.json) must show
``serve.jobs.failed == 0`` — checked both here and by
``check_regression.py --metrics``.

The contrast with table11 (closed-loop, one pre-formed cohort) is the point:
continuous batching keeps throughput near the batched optimum while bounding
the latency an individual late arrival pays.
"""
import dataclasses
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro import obs
from repro.core.life import LifeConfig
from repro.data.dmri import synth_cohort
from repro.serve import JobFailedError, LifeFrontend

N_ITERS = 30
N_JOBS = 8
SLICE = 10


def _frontend(plan_dir: str, **kw) -> LifeFrontend:
    return LifeFrontend(LifeConfig(executor="opt", n_iters=N_ITERS,
                                   plan_cache_dir=plan_dir),
                        slice_iters=SLICE, **kw)


def run_async_trace(cohort, rate_per_s: float, plan_dir: str, seed: int = 0):
    """Open-loop arrival trace through ``submit_async``: the producer
    sleeps to each pre-drawn arrival time and submits; the frontend's
    driver thread micro-batches and time-slices concurrently.

    Returns (frontend, wall_seconds); completion counts and latencies are
    read from the obs registry, which the scheduler and service populate.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_per_s, size=len(cohort))
    arrivals = np.cumsum(gaps)                    # seconds from t0
    # mixed tenancy: every third job asks for SELL (solo bucket), the rest
    # run format selection ("auto", FormatPlan-cached); one in four is
    # high priority
    specs = [("sell" if i % 3 == 2 else "auto", 5 if i % 4 == 0 else 0)
             for i in range(len(cohort))]

    fe = _frontend(plan_dir, max_queue=len(cohort), backpressure="block")
    handles = []
    t0 = time.perf_counter()
    for i, problem in enumerate(cohort):
        now = time.perf_counter() - t0
        if arrivals[i] > now:
            time.sleep(arrivals[i] - now)
        fmt, pri = specs[i]
        handles.append(fe.submit_async(problem, job_id=f"s{i}",
                                       n_iters=N_ITERS, format=fmt,
                                       priority=pri, timeout=600))
    for h in handles:
        h.result(timeout=600)
    wall = time.perf_counter() - t0
    fe.shutdown()
    return fe, wall


def failure_isolation_scenario(cohort, plan_dir: str) -> None:
    """One always-raising tenant + a saturated two-slot admission queue:
    the §13.3 acceptance scenario at benchmark scale.  Every healthy job
    completes through ``submit_async`` (no wedge), the poisoned job's
    exception surfaces on its handle, and the extended counter algebra
    settles exactly."""
    obs.reset()
    bad_problem = dataclasses.replace(cohort[0],
                                      b=np.asarray(cohort[0].b)[:-3])
    fe = _frontend(plan_dir, max_queue=2, backpressure="block")
    t0 = time.perf_counter()
    bad = fe.submit_async(bad_problem, job_id="bad", n_iters=N_ITERS,
                          format="auto", timeout=600)
    handles = [fe.submit_async(p, job_id=f"h{i}", n_iters=N_ITERS,
                               format="auto", timeout=600)
               for i, p in enumerate(cohort)]
    for h in handles:
        h.result(timeout=600)
    err = bad.exception(timeout=600)
    assert isinstance(err, JobFailedError), \
        f"poisoned tenant resolved {bad.status()!r}, expected failed"
    wall = time.perf_counter() - t0
    fe.shutdown()
    admitted = obs.value("serve.jobs.admitted")
    completed = obs.value("serve.jobs.completed")
    failed = obs.value("serve.jobs.failed")
    assert (admitted, completed, failed) == (len(cohort) + 1.0,
                                             float(len(cohort)), 1.0), \
        (f"counter algebra broke: admitted={admitted} "
         f"completed={completed} failed={failed}")
    emit("table13.service.failure_isolation",
         1e6 * wall / len(cohort),
         f"{len(cohort)}ok;1failed;queue<=2",
         healthy_completed=completed, failed=failed,
         admission_shed=obs.value("serve.admission.shed"),
         admission_rejected=obs.value("serve.admission.rejected"))


def run():
    cohort = synth_cohort(N_JOBS, base_seed=50, n_fibers=256, n_theta=64,
                          n_atoms=64, grid=(14, 14, 14))
    was_enabled = obs.enabled()
    obs.enable()
    try:
        with tempfile.TemporaryDirectory() as plan_dir:
            # the wedge-on-error regression gate runs first; the benign
            # rates below reset the registry, so the snapshot CI gates
            # ends with serve.jobs.failed == 0
            failure_isolation_scenario(cohort, plan_dir)
            for rate in (2.0, 8.0, 32.0):
                obs.reset()
                fe, wall = run_async_trace(cohort, rate, plan_dir)
                fe.service.metrics_snapshot()  # mirrors cache stats to gauges
                lat = obs.histogram("serve.job.latency.seconds")
                depth = obs.histogram("serve.queue.depth")
                completed = obs.value("serve.jobs.completed")
                hit_rate = obs.value("plan_cache.hit_rate")
                p50 = lat.quantile(50.0)
                p95 = lat.quantile(95.0)
                assert completed == obs.value("serve.jobs.admitted"), \
                    "trace drained, yet admitted != completed"
                assert obs.value("serve.jobs.failed") == 0.0, \
                    "benign trace failed jobs — failure isolation misfired"
                emit(f"table13.service.rate{rate:g}",
                     1e6 * lat.mean,
                     f"{completed / wall:.2f}subj/s;"
                     f"p50={p50 * 1e3:.0f}ms;"
                     f"p95={p95 * 1e3:.0f}ms",
                     subjects_per_s=completed / wall,
                     p50_ms=p50 * 1e3, p95_ms=p95 * 1e3,
                     queue_depth_mean=depth.mean,
                     queue_depth_max=depth.max,
                     preemptions=obs.value("serve.preemptions"),
                     plan_cache_hit_rate=hit_rate)
    finally:
        # restore the ambient switch state; the last rate's metrics stay in
        # the registry for run.py's end-of-run snapshot
        if not was_enabled:
            obs.disable()


if __name__ == "__main__":
    run()
