"""Beyond-paper: serving throughput + latency under an open-loop trace.

Simulates the production deployment (DESIGN.md §8): subjects arrive as a
Poisson process — open-loop, so arrivals do not wait for the service — with
mixed formats and priorities, and the LifeService micro-batches, time-slices
and completes them.

The table is also the observability layer's end-to-end exercise: every
reported number is read back from the ``repro.obs`` registry the serving
stack instruments (DESIGN.md §12), not from ad-hoc bookkeeping in this
file.  Per arrival rate:

  * subjects/sec        counter ``serve.jobs.completed`` / trace wall time
  * p50 / p95 latency   histogram ``serve.job.latency.seconds``
  * queue depth         histogram ``serve.queue.depth`` (mean/max)
  * plan-cache hit rate gauge ``plan_cache.hit_rate`` (via
                        ``LifeService.metrics_snapshot()``)

Rates run against one shared on-disk plan cache, so ``format="auto"``
bucket builds re-resolve their FormatPlan from it — the first rate seeds
the cache, later rates replay it warm.  ``obs.reset()`` between rates
zeroes the registry in place (held instrument handles stay live), giving
each rate fresh numbers without rebuilding the stack.

The contrast with table11 (closed-loop, one pre-formed cohort) is the point:
continuous batching keeps throughput near the batched optimum while bounding
the latency an individual late arrival pays.
"""
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro import obs
from repro.core.life import LifeConfig
from repro.data.dmri import synth_cohort
from repro.serve import LifeService

N_ITERS = 30
N_JOBS = 8
SLICE = 10


def run_trace(cohort, rate_per_s: float, plan_dir: str, seed: int = 0):
    """Open-loop arrival trace: submit job i at its pre-drawn arrival time
    regardless of service progress; tick the scheduler in between.

    Returns (service, wall_seconds); completion counts and latencies are
    read from the obs registry, which the scheduler and service populate.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_per_s, size=len(cohort))
    arrivals = np.cumsum(gaps)                    # seconds from t0
    # mixed tenancy: every third job asks for SELL (solo bucket), the rest
    # run format selection ("auto", FormatPlan-cached); one in four is
    # high priority
    specs = [("sell" if i % 3 == 2 else "auto", 5 if i % 4 == 0 else 0)
             for i in range(len(cohort))]

    svc = LifeService(LifeConfig(executor="opt", n_iters=N_ITERS,
                                 plan_cache_dir=plan_dir), slice_iters=SLICE)
    t0 = time.perf_counter()
    submitted = 0
    while submitted < len(cohort) or svc.scheduler.active():
        now = time.perf_counter() - t0
        while submitted < len(cohort) and arrivals[submitted] <= now:
            fmt, pri = specs[submitted]
            svc.submit(cohort[submitted], job_id=f"s{submitted}",
                       n_iters=N_ITERS, format=fmt, priority=pri)
            submitted += 1
        if svc.scheduler.active():
            svc.step()
        elif submitted < len(cohort):
            time.sleep(max(0.0, min(0.001, arrivals[submitted] - now)))
    return svc, time.perf_counter() - t0


def run():
    cohort = synth_cohort(N_JOBS, base_seed=50, n_fibers=256, n_theta=64,
                          n_atoms=64, grid=(14, 14, 14))
    was_enabled = obs.enabled()
    obs.enable()
    try:
        with tempfile.TemporaryDirectory() as plan_dir:
            for rate in (2.0, 8.0, 32.0):
                obs.reset()
                svc, wall = run_trace(cohort, rate, plan_dir)
                svc.metrics_snapshot()        # mirrors cache stats to gauges
                lat = obs.histogram("serve.job.latency.seconds")
                depth = obs.histogram("serve.queue.depth")
                completed = obs.value("serve.jobs.completed")
                hit_rate = obs.value("plan_cache.hit_rate")
                p50 = lat.quantile(50.0)
                p95 = lat.quantile(95.0)
                assert completed == obs.value("serve.jobs.admitted"), \
                    "trace drained, yet admitted != completed"
                emit(f"table13.service.rate{rate:g}",
                     1e6 * lat.mean,
                     f"{completed / wall:.2f}subj/s;"
                     f"p50={p50 * 1e3:.0f}ms;"
                     f"p95={p95 * 1e3:.0f}ms",
                     subjects_per_s=completed / wall,
                     p50_ms=p50 * 1e3, p95_ms=p95 * 1e3,
                     queue_depth_mean=depth.mean,
                     queue_depth_max=depth.max,
                     preemptions=obs.value("serve.preemptions"),
                     plan_cache_hit_rate=hit_rate)
    finally:
        # restore the ambient switch state; the last rate's metrics stay in
        # the registry for run.py's end-of-run snapshot
        if not was_enabled:
            obs.disable()


if __name__ == "__main__":
    run()
