"""Paper Table 3: computation-partitioning x restructuring combinations on
the parallel-naive executor.

The paper's thread-partitioning choices map to distinct XLA lowerings:

  coeff+<sort>   scatter-add over coefficients (atomics analogue)
  voxel+voxel    sorted-segment reduction keyed by the output dim (the
                 sync-free mapping: one sub-vector -> one reducer)
  fiber+fiber    same for WC

Derived: speedup over the worst combo for the same op.
"""
import jax.numpy as jnp

from benchmarks.common import emit, problem, time_fn
from repro.core import spmv
from repro.core.restructure import sort_by_host


def run():
    p = problem()
    w = jnp.ones((p.phi.n_fibers,), jnp.float32)
    y = p.b
    phi_v, _ = sort_by_host(p.phi, "voxel")
    phi_a, _ = sort_by_host(p.phi, "atom")
    phi_f, _ = sort_by_host(p.phi, "fiber")

    dsc = {
        "coeff+voxel": lambda: spmv.dsc_atom_sorted(phi_v, p.dictionary, w),
        "coeff+atom": lambda: spmv.dsc_atom_sorted(phi_a, p.dictionary, w),
        "voxel+voxel": lambda: spmv.dsc(phi_v, p.dictionary, w),
    }
    wc = {
        "coeff+voxel": lambda: spmv.wc_atom_sorted(phi_v, p.dictionary, y),
        "coeff+atom": lambda: spmv.wc_atom_sorted(phi_a, p.dictionary, y),
        "fiber+fiber": lambda: spmv.wc(phi_f, p.dictionary, y),
    }
    for op, combos in (("dsc", dsc), ("wc", wc)):
        times = {name: time_fn(fn) for name, fn in combos.items()}
        worst = max(times.values())
        for name, t in times.items():
            emit(f"table3.{op}.{name}", t, f"{worst / t:.2f}x")


if __name__ == "__main__":
    run()
