"""End-to-end behaviour tests for the paper's system.

Covers the two top-level user journeys:
  1. LiFE connectome pruning: synthetic dMRI -> STD encoding -> restructuring
     autotune -> SBBNNLS -> pruned connectome that explains the signal.
  2. LM training: config -> init -> train loop with checkpoint/restart; loss
     decreases deterministically across the restart.
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as CK
from repro.configs.base import get_config, reduced
from repro.core.life import LifeConfig, LifeEngine
from repro.data.dmri import synth_connectome
from repro.data.tokens import DataConfig, synth_batch_for
from repro.launch import steps as ST
from repro.optim.adamw import OptConfig


def test_life_end_to_end_pruning():
    problem = synth_connectome(n_fibers=96, n_theta=24, n_atoms=32,
                               grid=(12, 12, 12), seed=11, noise=0.02)
    eng = LifeEngine(problem, LifeConfig(executor="auto", n_iters=80,
                                         compact_every=40))
    w, losses = eng.run()
    assert losses[-1] < losses[0] * 5e-2   # converges to noise floor
    stats = eng.prune_stats(w)
    assert stats["recall"] > 0.9
    assert stats["kept"] < stats["total"]          # it actually pruned
    # the pruned connectome still explains the signal
    assert eng.loss(w) <= losses[-1] * 1.5


def test_lm_train_loop_with_restart():
    cfg = dataclasses.replace(reduced(get_config("qwen1.5-4b")), remat=False)
    opt = OptConfig(lr=3e-3, warmup_steps=2, decay_steps=100)
    data = DataConfig(seed=1, seq_len=64, global_batch=4)
    step_fn = jax.jit(ST.make_train_step(cfg, opt))
    params, opt_state = ST.init_all(cfg, opt, jax.random.PRNGKey(0))

    losses = []
    for s in range(6):
        batch = synth_batch_for(cfg, data, s)
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))

    ckdir = tempfile.mkdtemp()
    CK.save(ckdir, 6, {"params": params, "opt": opt_state})

    # crash + restart: restore and continue with the deterministic pipeline
    step0, flat, _ = CK.restore(ckdir)
    tree = CK.unflatten_like(
        jax.eval_shape(lambda: {"params": params, "opt": opt_state}), flat)
    params2 = jax.tree.map(jnp.asarray, tree["params"])
    opt2 = jax.tree.map(jnp.asarray, tree["opt"])
    for s in range(step0, step0 + 4):
        batch = synth_batch_for(cfg, data, s)
        params2, opt2, m = step_fn(params2, opt2, batch)
        losses.append(float(m["loss"]))

    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_serve_path_batched_decode():
    """Prefill a batch of prompts, decode 8 greedy tokens."""
    from repro.models import transformer as T
    cfg = reduced(get_config("stablelm-12b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S_pre, S_max = 4, 16, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_pre)), jnp.int32)
    logits, cache = T.prefill(cfg, params, {"tokens": toks})
    for kn in ("k", "v"):
        kv = cache[kn]
        cache[kn] = jnp.pad(
            kv, ((0, 0), (0, 0), (0, S_max - kv.shape[2]), (0, 0), (0, 0)))
    decode = jax.jit(lambda p, b: T.decode_step(cfg, p, b))
    idx = jnp.asarray(S_pre, jnp.int32)
    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for _ in range(8):
        logits, cache = decode(params, dict(tokens=tok, cache=cache,
                                            cache_index=idx))
        cache.pop("index")
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(tok))
        idx = idx + 1
    out = np.concatenate(out_tokens, axis=1)
    assert out.shape == (B, 8)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
