"""Flash attention (custom VJP) vs dense reference — fwd and grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention
from repro.models.layers import dense_attention


@pytest.mark.parametrize("B,S,KV,G,hd,chunk", [
    (2, 64, 2, 1, 8, 16),
    (1, 128, 1, 4, 16, 32),     # MQA
    (2, 256, 4, 2, 16, 64),     # GQA
    (1, 96, 3, 1, 8, 32),       # S not a power of two
])
def test_forward_matches_dense(rng, B, S, KV, G, hd, chunk):
    H = KV * G
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    ref = dense_attention(q, k, v, causal=True)
    out = flash_attention(q.reshape(B, S, KV, G, hd), k, v, chunk)
    np.testing.assert_allclose(np.asarray(out.reshape(B, S, H, hd)),
                               np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("chunk", [16, 64])
def test_grads_match_dense(rng, chunk):
    B, S, KV, G, hd = 2, 128, 2, 3, 16
    H = KV * G
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)

    def loss_flash(q, k, v):
        o = flash_attention(q.reshape(B, S, KV, G, hd), k, v, chunk)
        return jnp.sum(jnp.sin(o))

    def loss_dense(q, k, v):
        o = dense_attention(q, k, v, causal=True).reshape(B, S, KV, G, hd)
        return jnp.sum(jnp.sin(o))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4, err_msg=f"d{name}")


def test_numerically_stable_large_logits(rng):
    """Online softmax must survive large score magnitudes."""
    B, S, KV, G, hd = 1, 64, 1, 1, 8
    q = jnp.asarray(rng.normal(size=(B, S, 1, hd)) * 30, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)) * 30, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    out = flash_attention(q.reshape(B, S, KV, G, hd), k, v, 16)
    assert np.isfinite(np.asarray(out)).all()
