"""Multi-device integration tests.

Run in subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps its single real device.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow        # 8-device subprocesses, fresh compiles

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=REPO_SRC)
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_sharded_sbbnnls_matches_single_device():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.data.dmri import synth_connectome
        from repro.core.life import LifeEngine, LifeConfig
        from repro.distributed import life_shard as LS

        p = synth_connectome(n_fibers=96, n_theta=16, n_atoms=24,
                             grid=(10,10,10), seed=3)
        from repro import compat
        mesh = compat.make_mesh((4, 2), ("data", "model"))
        shards = LS.build_life_shards(p.phi, 16, R=4, C=2)
        step = LS.make_sharded_step(mesh, dict(nv_local=shards.nv_local,
                                               nf_local=shards.nf_local,
                                               n_theta=16))
        args = LS.sharded_state(mesh, shards, p)
        jstep = jax.jit(step)
        w = args["w"]
        with mesh:
            for it in range(10):
                w, loss = jstep(args["da"],args["dv"],args["df"],args["dw"],
                                args["wa"],args["wv"],args["wf"],args["ww"],
                                args["d"], args["b"], w,
                                jnp.asarray(it, jnp.int32))
        w_full = LS.unshard_w(shards, np.asarray(w))
        eng = LifeEngine(p, LifeConfig(executor="opt", n_iters=10))
        w_ref, _ = eng.run()
        np.testing.assert_allclose(w_full, np.asarray(w_ref),
                                   rtol=1e-3, atol=1e-4)
        print("MATCH")
    """)
    assert "MATCH" in out


def test_train_step_on_mesh_and_elastic_restart():
    """Train 3 steps on a (4,2) mesh, checkpoint, restore onto a (2,4) mesh
    (elastic resize), continue — loss trajectory must continue finitely and
    params must be bit-identical after reshard."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp, tempfile, dataclasses
        from repro.configs.base import get_config, reduced
        from repro.distributed import sharding as SH, hints
        from repro.launch import steps as ST
        from repro.checkpoint import manager as CK
        from repro.data.tokens import DataConfig, synth_batch_for
        from repro.optim.adamw import OptConfig

        cfg = dataclasses.replace(reduced(get_config("deepseek-7b")),
                                  remat=False)
        opt = OptConfig(lr=3e-3)          # 8 total steps must visibly descend
        data = DataConfig(seed=0, seq_len=32, global_batch=8)

        from repro import compat
        def build(mesh_shape):
            mesh = compat.make_mesh(mesh_shape, ("data", "model"))
            hints.activate(mesh)
            pspecs = lambda tree: SH.logical_to_shardings(
                mesh, SH.param_specs(cfg, mesh, tree))
            return mesh, pspecs

        mesh, mk = build((4, 2))
        params, opt_state = ST.init_all(cfg, opt, jax.random.PRNGKey(0))
        step_fn = jax.jit(ST.make_train_step(cfg, opt))
        losses = []
        with mesh:
            psh = mk(params)
            params = CK.place(params, psh)
            for s in range(3):
                batch = synth_batch_for(cfg, data, s)
                params, opt_state, m = step_fn(params, opt_state, batch)
                losses.append(float(m["loss"]))
        ckdir = tempfile.mkdtemp()
        CK.save(ckdir, 3, {"params": params, "opt": opt_state})

        # elastic restart on a different mesh
        mesh2, mk2 = build((2, 4))
        _, flat, _ = CK.restore(ckdir)
        template = jax.eval_shape(lambda: {"params": params, "opt": opt_state})
        host_tree = CK.unflatten_like(template, flat)
        with mesh2:
            psh2 = mk2(host_tree["params"])
            params2 = CK.place(host_tree["params"], psh2)
            opt2 = jax.tree.map(jnp.asarray, host_tree["opt"])
            # bit-identical across the reshard
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            for s in range(3, 8):
                batch = synth_batch_for(cfg, data, s)
                params2, opt2, m = step_fn(params2, opt2, batch)
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0]
        print("ELASTIC_OK", losses)
    """)
    assert "ELASTIC_OK" in out


def test_moe_ep_train_step_on_mesh():
    out = _run("""
        import numpy as np, jax, dataclasses
        from repro.configs.base import get_config, reduced
        from repro.distributed import sharding as SH, hints
        from repro.launch import steps as ST
        from repro.data.tokens import DataConfig, synth_batch_for
        from repro.optim.adamw import OptConfig

        cfg = dataclasses.replace(reduced(get_config("phi3.5-moe-42b-a6.6b")),
                                  remat=False)
        opt = OptConfig(lr=1e-3)
        from repro import compat
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        hints.activate(mesh)
        params, opt_state = ST.init_all(cfg, opt, jax.random.PRNGKey(0))
        step_fn = jax.jit(ST.make_train_step(cfg, opt))
        data = DataConfig(seed=0, seq_len=32, global_batch=4)
        with mesh:
            psh = SH.logical_to_shardings(mesh, SH.param_specs(cfg, mesh, params))
            from repro.checkpoint import manager as CK
            params = CK.place(params, psh)
            for s in range(2):
                batch = synth_batch_for(cfg, data, s)
                params, opt_state, m = step_fn(params, opt_state, batch)
                assert np.isfinite(float(m["loss"]))
        print("MOE_EP_OK")
    """)
    assert "MOE_EP_OK" in out


def test_batched_engine_mesh_placement_matches_unplaced():
    """BatchedLifeEngine under a (4, 2) mesh — subjects over `data`, stacked
    Phi slots over `model` — reproduces the unplaced cohort solve."""
    out = _run("""
        import dataclasses
        import numpy as np, jax
        assert len(jax.devices()) == 8
        from repro.core.batched import BatchedLifeEngine
        from repro.core.life import LifeConfig
        from repro.data.dmri import synth_cohort
        cohort = synth_cohort(4, base_seed=10, n_fibers=64, n_theta=16,
                              n_atoms=24, grid=(10, 10, 10))
        base = LifeConfig(executor="opt", n_iters=10, plan_cache_dir="")
        W0, L0 = BatchedLifeEngine(cohort, base).run()
        eng = BatchedLifeEngine(
            cohort, dataclasses.replace(base, shard_rows=4, shard_cols=2))
        assert eng.mesh is not None
        sh = eng.phi_dsc.values.sharding
        assert "data" in str(sh.spec) and "model" in str(sh.spec), sh
        W1, L1 = eng.run()
        np.testing.assert_allclose(np.asarray(W1), np.asarray(W0),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(L1, L0, rtol=1e-4)
        print("BATCH_MESH_OK")
    """)
    assert "BATCH_MESH_OK" in out
