"""Persistent plan cache: round-trips, content addressing, engine wiring."""
import dataclasses

import numpy as np
import pytest

from repro.core import plan_cache as PC
from repro.core.inspector import plan_tiles
from repro.core.life import LifeConfig, LifeEngine
from repro.core.plan_cache import PlanCache, spmv_plan_key, tile_plan_key
from repro.core.restructure import SpmvPlan


def _ids(n=300, rows=40, seed=0):
    return np.sort(np.random.default_rng(seed).integers(0, rows, n)), rows


def test_tile_plan_roundtrip(tmp_path):
    ids, rows = _ids()
    plan = plan_tiles(ids, rows, c_tile=32, row_tile=8)
    cache = PlanCache(str(tmp_path))
    key = tile_plan_key(ids, rows, c_tile=32, row_tile=8)
    assert cache.get_tile_plan(key) is None          # cold
    cache.put_tile_plan(key, plan)
    got = cache.get_tile_plan(key)
    assert got is not None
    np.testing.assert_array_equal(got.sel, plan.sel)
    np.testing.assert_array_equal(got.row_block, plan.row_block)
    np.testing.assert_array_equal(got.local_row, plan.local_row)
    assert (got.n_tiles, got.c_tile, got.row_tile, got.n_rows_padded) == \
        (plan.n_tiles, plan.c_tile, plan.row_tile, plan.n_rows_padded)
    assert cache.stats.hits == 1 and cache.stats.misses == 1


def test_spmv_plan_roundtrip(tmp_path):
    cache = PlanCache(str(tmp_path))
    plan = SpmvPlan(op="dsc", restructure="voxel", partition="voxel",
                    order=np.arange(17, dtype=np.int64)[::-1].copy())
    key = spmv_plan_key("dsc", *(np.arange(5),) * 3)
    cache.put_spmv_plan(key, plan)
    got = cache.get_spmv_plan(key)
    assert (got.op, got.restructure, got.partition) == \
        ("dsc", "voxel", "voxel")
    np.testing.assert_array_equal(got.order, plan.order)


def test_key_is_content_addressed():
    ids, rows = _ids()
    base = tile_plan_key(ids, rows, c_tile=32, row_tile=8)
    # same content, different buffer -> same key
    assert tile_plan_key(ids.copy(), rows, c_tile=32, row_tile=8) == base
    # any input change -> different key
    assert tile_plan_key(ids, rows + 1, c_tile=32, row_tile=8) != base
    assert tile_plan_key(ids, rows, c_tile=64, row_tile=8) != base
    assert tile_plan_key(ids, rows, c_tile=32, row_tile=4) != base
    bumped = ids.copy()
    bumped[0] = min(bumped[0] + 1, rows - 1)
    if not np.array_equal(bumped, ids):
        assert tile_plan_key(bumped, rows, c_tile=32, row_tile=8) != base


def test_disabled_cache_never_touches_disk(tmp_path):
    cache = PlanCache("")
    assert not cache.enabled
    ids, rows = _ids()
    plan = plan_tiles(ids, rows, c_tile=32, row_tile=8)
    key = tile_plan_key(ids, rows, c_tile=32, row_tile=8)
    cache.put_tile_plan(key, plan)
    assert cache.get_tile_plan(key) is None
    assert list(tmp_path.iterdir()) == []


def test_corrupt_entry_degrades_to_miss(tmp_path):
    cache = PlanCache(str(tmp_path))
    ids, rows = _ids()
    key = tile_plan_key(ids, rows, c_tile=32, row_tile=8)
    (tmp_path / (key + ".npz")).write_bytes(b"not an npz")
    assert cache.get_tile_plan(key) is None


def test_cache_hit_skips_plan_tiles(tmp_path, tiny_problem, monkeypatch):
    """Second kernel-engine construction must not call plan_tiles at all."""
    cfg = LifeConfig(executor="kernel", n_iters=2, c_tile=64, row_tile=8,
                     plan_cache_dir=str(tmp_path))
    eng1 = LifeEngine(tiny_problem, cfg)
    assert eng1.cache_stats.misses == 2 and eng1.cache_stats.hits == 0

    def boom(*a, **k):
        raise AssertionError("plan_tiles called despite cache hit")

    from repro.core import registry
    monkeypatch.setattr(registry, "plan_tiles", boom)
    eng2 = LifeEngine(tiny_problem, cfg)
    assert eng2.cache_stats.hits == 2 and eng2.cache_stats.misses == 0
    # and the cached plans still produce the same results
    import jax.numpy as jnp
    w = jnp.ones((tiny_problem.phi.n_fibers,), jnp.float32)
    np.testing.assert_allclose(np.asarray(eng1.matvec(w)),
                               np.asarray(eng2.matvec(w)),
                               rtol=1e-6, atol=1e-6)


def test_second_planning_time_drops(tmp_path):
    """The amortization claim: a warm plan lookup beats re-running the
    O(Nc) host tiling loop.  Sized so the margin is decisive (200k coeffs:
    the python loop takes orders of magnitude longer than one np.load)."""
    import time
    from repro.core.registry import planned_tiles
    ids = np.sort(np.random.default_rng(1).integers(0, 5000, 200_000))
    cache = PlanCache(str(tmp_path))
    t0 = time.perf_counter()
    cold = planned_tiles(ids, 5000, c_tile=128, row_tile=8, cache=cache)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = planned_tiles(ids, 5000, c_tile=128, row_tile=8, cache=cache)
    t_warm = time.perf_counter() - t0
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    assert t_warm < t_cold
    np.testing.assert_array_equal(warm.sel, cold.sel)
    np.testing.assert_array_equal(warm.row_block, cold.row_block)


def test_size_cap_prunes_oldest(tmp_path):
    """Writes past max_bytes evict oldest entries; newest always survives."""
    import os
    import time
    cache = PlanCache(str(tmp_path), max_bytes=6000)
    keys = []
    for i in range(12):
        ids = np.sort(np.random.default_rng(i).integers(0, 40, 300))
        plan = plan_tiles(ids, 40, c_tile=32, row_tile=8)
        key = tile_plan_key(ids, 40, c_tile=32, row_tile=8)
        cache.put_tile_plan(key, plan)
        keys.append(key)
        os.utime(tmp_path / (key + ".npz"),
                 (time.time() - 100 + i, time.time() - 100 + i))
    files = list(tmp_path.glob("*.npz"))
    total = sum(f.stat().st_size for f in files)
    assert total <= 6000
    assert len(files) < 12                        # something was evicted
    assert cache.get_tile_plan(keys[-1]) is not None   # newest survives
    assert cache.get_tile_plan(keys[0]) is None        # oldest evicted


def test_cap_below_one_entry_keeps_newest(tmp_path):
    """A cap smaller than a single entry must not disable the cache."""
    cache = PlanCache(str(tmp_path), max_bytes=1)
    ids, rows = _ids()
    key = tile_plan_key(ids, rows, c_tile=32, row_tile=8)
    cache.put_tile_plan(key, plan_tiles(ids, rows, c_tile=32, row_tile=8))
    assert cache.get_tile_plan(key) is not None


def test_no_cap_keeps_everything(tmp_path):
    cache = PlanCache(str(tmp_path))              # max_bytes=None
    for i in range(5):
        ids = np.sort(np.random.default_rng(100 + i).integers(0, 40, 300))
        cache.put_tile_plan(tile_plan_key(ids, 40, c_tile=32, row_tile=8),
                            plan_tiles(ids, 40, c_tile=32, row_tile=8))
    assert len(list(tmp_path.glob("*.npz"))) == 5


def test_max_bytes_env_default(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLAN_CACHE_MAX_BYTES", "12345")
    assert PlanCache(str(tmp_path)).max_bytes == 12345
    monkeypatch.setenv("REPRO_PLAN_CACHE_MAX_BYTES", "not-an-int")
    assert PlanCache(str(tmp_path)).max_bytes is None


def test_compaction_changes_key_and_misses(tmp_path, tiny_problem):
    """Compacted phi has different index content -> clean cache miss."""
    from repro.core.restructure import compact_by_weight
    import jax.numpy as jnp
    cfg = LifeConfig(executor="kernel", n_iters=2, c_tile=64, row_tile=8,
                     plan_cache_dir=str(tmp_path))
    eng = LifeEngine(tiny_problem, cfg)
    w = np.zeros(tiny_problem.phi.n_fibers, np.float32)
    w[: len(w) // 2] = 1.0
    compacted = compact_by_weight(tiny_problem.phi, jnp.asarray(w))
    assert compacted.n_coeffs < tiny_problem.phi.n_coeffs
    problem2 = dataclasses.replace(tiny_problem, phi=compacted)
    eng2 = LifeEngine(problem2, cfg)
    assert eng2.cache_stats.misses == 2        # no false sharing


# ----------------------------------------------------------------------------
# ShardPlan: partition cuts keyed by mesh topology (DESIGN.md §9)
# ----------------------------------------------------------------------------

def test_shard_plan_roundtrip_and_warm_hit(tmp_path, tiny_problem,
                                           monkeypatch):
    """A warm cache hit rebuilds the partition without re-partitioning:
    the second partition_cuts never calls shard_boundaries."""
    from repro.formats import shard as FS
    cache = PlanCache(str(tmp_path))
    plan = FS.partition_cuts(tiny_problem.phi, 3, 2, cache=cache)
    assert cache.stats.misses == 1
    calls = []
    orig = FS.shard_boundaries
    monkeypatch.setattr(FS, "shard_boundaries",
                        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])
    warm = FS.partition_cuts(tiny_problem.phi, 3, 2, cache=cache)
    assert cache.stats.hits == 1 and calls == []
    assert (warm.R, warm.C) == (plan.R, plan.C)
    np.testing.assert_array_equal(warm.voxel_cuts, plan.voxel_cuts)
    np.testing.assert_array_equal(warm.fiber_cuts, plan.fiber_cuts)


def test_shard_plan_key_includes_mesh_and_devices():
    """Regression (ISSUE 4): a sharded plan written on one topology must
    miss cleanly on another — the key covers the mesh shape, the device
    count, and the inner cell format."""
    from repro.core.plan_cache import shard_plan_key
    ids = (np.arange(10), np.arange(10) % 4, np.arange(10) % 3)
    base = dict(sizes=(8, 4, 3), R=4, C=2, cell_format="coo", n_devices=8)
    key = shard_plan_key(*ids, **base)
    assert shard_plan_key(*ids, **base) == key                   # stable
    for change in (dict(R=2), dict(C=1), dict(n_devices=1),
                   dict(cell_format="sell"), dict(sizes=(8, 4, 4))):
        assert shard_plan_key(*ids, **{**base, **change}) != key, change


def test_shard_plan_mesh_shape_mismatch_is_clean_miss(tmp_path,
                                                      tiny_problem):
    """Full-stack: the same dataset partitioned for a different mesh shape
    misses (and re-partitions) instead of loading the wrong cuts."""
    from repro.formats.shard import partition_cuts
    cache = PlanCache(str(tmp_path))
    partition_cuts(tiny_problem.phi, 4, 2, cache=cache)
    assert (cache.stats.hits, cache.stats.misses) == (0, 1)
    plan = partition_cuts(tiny_problem.phi, 2, 1, cache=cache)
    assert (cache.stats.hits, cache.stats.misses) == (0, 2)
    assert (plan.R, plan.C) == (2, 1)
    # and the original topology still hits its own entry
    partition_cuts(tiny_problem.phi, 4, 2, cache=cache)
    assert cache.stats.hits == 1
