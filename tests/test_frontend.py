"""Async front line: handles, streaming, backpressure, failure isolation.

Covers the DESIGN.md §13 contracts end to end: ``submit_async`` results
match the synchronous service bit-for-bit, progress streams through the
handle, each backpressure policy does what it says at the bound, a
poisoned tenant plus a saturated admission queue never wedges the healthy
jobs, and the scheduler's extended counter algebra holds in the final
snapshot.
"""
import dataclasses
import os
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core.life import LifeConfig
from repro.serve import (AdmissionQueueFull, JobCancelledError,
                         JobFailedError, LifeFrontend, LifeService,
                         ShutdownError)

TEST_SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))


def _cfg(**kw):
    kw.setdefault("executor", "opt")
    kw.setdefault("n_iters", 12)
    kw.setdefault("plan_cache_dir", "")
    return LifeConfig(**kw)


def _poison(problem):
    """Geometry-preserving corruption: a truncated signal keeps the bucket
    key (which has no ``b`` component) so the job lands in the same
    micro-batch as healthy same-acquisition tenants — and fails there."""
    return dataclasses.replace(problem, b=np.asarray(problem.b)[:-3])


# ----------------------------------------------------------------------------
# async results == sync results
# ----------------------------------------------------------------------------

def test_submit_async_matches_sync_service(tiny_cohort):
    """The frontend is a transport, not a solver: handles resolve to the
    exact arrays the synchronous service produces for the same batch."""
    ref = LifeService(_cfg(), slice_iters=5)
    ids = [ref.submit(p, n_iters=12, format="coo") for p in tiny_cohort]
    expected = ref.run()

    fe = LifeFrontend(_cfg(), slice_iters=5, start=False)
    handles = [fe.submit_async(p, n_iters=12, format="coo")
               for p in tiny_cohort]                # all admitted together
    with fe:
        for h, jid in zip(handles, ids):
            w, losses = h.result(timeout=300)
            w_ref, l_ref = expected[jid]
            np.testing.assert_array_equal(np.asarray(w), np.asarray(w_ref))
            np.testing.assert_array_equal(losses, l_ref)
            assert h.done() and h.status() == "done"


def test_events_stream_per_slice_progress(tiny_problem):
    fe = LifeFrontend(_cfg(), slice_iters=4, start=False)
    h = fe.submit_async(tiny_problem, n_iters=12, format="coo")
    with fe:
        events = list(h.events(timeout=300))
    assert events[-1] == {"type": "done"}
    progress = events[:-1]
    assert progress and all(e["type"] == "progress" for e in progress)
    done = [e["done"] for e in progress]
    assert done == sorted(done) and done[-1] == 12
    assert all(e["n_iters"] == 12 for e in progress)
    assert all(np.isfinite(e["loss"]) for e in progress)


def test_validation_error_resolves_handle_not_raises(tiny_problem):
    """Admission-time validation failures are per-job outcomes, not
    exceptions on the submitting thread — admission keeps flowing."""
    with LifeFrontend(_cfg(), slice_iters=4) as fe:
        good = fe.submit_async(tiny_problem, n_iters=4, format="coo")
        bad = fe.submit_async(tiny_problem, n_iters=4, format="csr")
        assert isinstance(bad.exception(timeout=60), ValueError)
        assert bad.status() == "rejected"
        with pytest.raises(JobFailedError):
            bad.result(timeout=60)
        w, losses = good.result(timeout=300)
        assert losses.shape == (4,)


# ----------------------------------------------------------------------------
# backpressure policies at the admission bound
# ----------------------------------------------------------------------------

def test_backpressure_reject_raises_at_bound(tiny_cohort):
    obs.enable()
    fe = LifeFrontend(_cfg(), slice_iters=8, max_queue=2,
                      backpressure="reject", start=False)
    a = fe.submit_async(tiny_cohort[0], n_iters=4, format="coo")
    b = fe.submit_async(tiny_cohort[1], n_iters=4, format="coo")
    with pytest.raises(AdmissionQueueFull):
        fe.submit_async(tiny_cohort[2], n_iters=4, format="coo")
    assert obs.value("serve.admission.rejected") == 1.0
    with fe:                                        # drain the admitted two
        pass
    assert a.status() == "done" and b.status() == "done"
    assert obs.value("serve.jobs.completed") == 2.0


def test_backpressure_shed_evicts_lowest_priority(tiny_cohort):
    obs.enable()
    fe = LifeFrontend(_cfg(), slice_iters=8, max_queue=2,
                      backpressure="shed", start=False)
    lo = fe.submit_async(tiny_cohort[0], n_iters=4, priority=0, format="coo")
    mid = fe.submit_async(tiny_cohort[1], n_iters=4, priority=3, format="coo")
    hi = fe.submit_async(tiny_cohort[2], n_iters=4, priority=5, format="coo")
    assert lo.done() and lo.status() == "shed"
    with pytest.raises(AdmissionQueueFull):
        lo.result()
    # a newcomer that is itself the lowest priority sheds itself — resolved
    # on the handle, never raised at the producer
    newcomer = fe.submit_async(tiny_cohort[0], n_iters=4, priority=1,
                               format="coo")
    assert newcomer.status() == "shed"
    assert obs.value("serve.admission.shed") == 2.0
    with fe:
        pass
    assert mid.status() == "done" and hi.status() == "done"


def test_backpressure_block_times_out_without_driver(tiny_cohort):
    fe = LifeFrontend(_cfg(), slice_iters=8, max_queue=1,
                      backpressure="block", start=False)
    first = fe.submit_async(tiny_cohort[0], n_iters=4, format="coo")
    with pytest.raises(AdmissionQueueFull):
        fe.submit_async(tiny_cohort[1], n_iters=4, format="coo",
                        timeout=0.05)
    with fe:
        first.result(timeout=300)


def test_backpressure_block_waits_for_space(tiny_cohort):
    """With the driver live, producers that outpace it block at the bound
    and every submission still completes."""
    with LifeFrontend(_cfg(), slice_iters=8, max_queue=1) as fe:
        handles = [fe.submit_async(p, n_iters=4, format="coo", timeout=120)
                   for p in tiny_cohort]
        for h in handles:
            w, losses = h.result(timeout=300)
            assert losses.shape == (4,)


def test_blocked_submitter_released_on_shutdown(tiny_cohort):
    fe = LifeFrontend(_cfg(), max_queue=1, backpressure="block", start=False)
    fe.submit_async(tiny_cohort[0], n_iters=4, format="coo")
    errs = []

    def blocked():
        try:
            fe.submit_async(tiny_cohort[1], n_iters=4, format="coo")
        except Exception as exc:
            errs.append(exc)

    t = threading.Thread(target=blocked)
    t.start()
    time.sleep(0.05)                    # let it reach the wait (either side
    fe.shutdown()                       # of the race raises RuntimeError)
    t.join(30)
    assert not t.is_alive()
    assert len(errs) == 1 and isinstance(errs[0], RuntimeError)


# ----------------------------------------------------------------------------
# cancellation
# ----------------------------------------------------------------------------

def test_cancel_pending_and_running(tiny_cohort):
    fe = LifeFrontend(_cfg(), slice_iters=2, start=False)
    running = fe.submit_async(tiny_cohort[0], n_iters=200, format="coo")
    pending = fe.submit_async(tiny_cohort[1], n_iters=200, format="sell")
    assert pending.cancel()             # never reached the service
    assert pending.status() == "cancelled"
    with pytest.raises(JobCancelledError):
        pending.result()
    with fe:
        assert running.cancel()
        with pytest.raises(JobCancelledError):
            running.result(timeout=300)
    assert running.status() == "cancelled"
    assert not running.cancel()         # terminal: nothing to cancel


# ----------------------------------------------------------------------------
# the ISSUE acceptance scenario: poisoned tenant + saturated queue
# ----------------------------------------------------------------------------

def test_acceptance_poisoned_tenant_full_queue_no_wedge(tiny_cohort):
    """One always-raising tenant and a full admission queue: every healthy
    job completes through ``submit_async`` (no wedge, bound respected), the
    failed job's exception surfaces on its handle, and the extended counter
    algebra holds in the obs snapshot."""
    from repro.obs import snapshot_value

    obs.enable()
    fe = LifeFrontend(_cfg(), slice_iters=3, max_queue=2,
                      backpressure="block")
    bad = fe.submit_async(_poison(tiny_cohort[0]), job_id="bad", n_iters=6,
                          format="coo", timeout=120)
    fmts = ["coo", "sell", "fcoo"]
    healthy = [fe.submit_async(tiny_cohort[i % len(tiny_cohort)],
                               job_id=f"h{i}", n_iters=6,
                               format=fmts[i % len(fmts)], timeout=120)
               for i in range(6)]
    for h in healthy:
        w, losses = h.result(timeout=600)
        assert losses.shape == (6,) and h.status() == "done"
    err = bad.exception(timeout=600)
    assert isinstance(err, JobFailedError) and err.job_id == "bad"
    assert isinstance(err.error, Exception)      # the executor's exception
    with pytest.raises(JobFailedError):
        bad.result()
    fe.shutdown()

    snap = fe.service.metrics_snapshot()
    admitted = snapshot_value(snap, "counters", "serve.jobs.admitted")
    completed = snapshot_value(snap, "counters", "serve.jobs.completed")
    failed = snapshot_value(snap, "counters", "serve.jobs.failed")
    cancelled = snapshot_value(snap, "counters", "serve.jobs.cancelled")
    queued = snapshot_value(snap, "gauges", "serve.queue.depth")
    running = snapshot_value(snap, "gauges", "serve.jobs.running")
    assert (admitted, failed) == (7.0, 1.0)
    assert admitted == completed + failed + cancelled + queued + running
    assert snapshot_value(snap, "gauges", "serve.admission.depth") == 0.0


def test_async_stress_randomized_interleavings(tiny_cohort):
    """Concurrent producers racing a bounded queue, poisoned tenants mixed
    in: every handle reaches a terminal state, only poisoned jobs fail, and
    the counter algebra settles exactly."""
    obs.enable()
    rng = np.random.default_rng(200 + TEST_SEED)
    specs = []
    for i in range(9):
        poisoned = i in (2, 5)
        p = tiny_cohort[int(rng.integers(len(tiny_cohort)))]
        specs.append((f"s{i}", _poison(p) if poisoned else p, poisoned,
                      int(rng.integers(3, 9)),
                      ["coo", "auto", "sell"][int(rng.integers(3))],
                      int(rng.integers(0, 3))))
    fe = LifeFrontend(_cfg(), slice_iters=3, max_queue=3,
                      backpressure="block")
    handles = {}

    def producer(chunk):
        for jid, p, _, n, fmt, pri in chunk:
            handles[jid] = fe.submit_async(p, job_id=jid, n_iters=n,
                                           format=fmt, priority=pri,
                                           timeout=300)

    threads = [threading.Thread(target=producer, args=(specs[i::3],))
               for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(300)
    assert not any(t.is_alive() for t in threads)
    for jid, _, poisoned, n, _, _ in specs:
        h = handles[jid]
        if poisoned:
            assert isinstance(h.exception(timeout=600), JobFailedError)
            assert h.status() == "failed"
        else:
            w, losses = h.result(timeout=600)
            assert losses.shape == (n,)
    fe.shutdown()
    admitted = obs.value("serve.jobs.admitted")
    completed = obs.value("serve.jobs.completed")
    failed = obs.value("serve.jobs.failed")
    cancelled = obs.value("serve.jobs.cancelled")
    queued = obs.value("serve.queue.depth")
    running = obs.value("serve.jobs.running")
    assert (admitted, failed) == (9.0, 2.0)
    assert admitted == completed + failed + cancelled + queued + running


# ----------------------------------------------------------------------------
# shutdown semantics
# ----------------------------------------------------------------------------

def test_shutdown_without_drain_checkpoints_for_resume(tiny_problem,
                                                       tmp_path):
    """``shutdown(drain=False)`` stops mid-solve but loses nothing: waiters
    get ShutdownError instead of hanging, the final checkpoint lands, and a
    restarted service re-adopts the interrupted job."""
    ck = str(tmp_path / "svc")
    fe = LifeFrontend(_cfg(n_iters=64), ckpt_dir=ck, checkpoint_every=0,
                      slice_iters=2, start=False)
    orig_step = fe.service.step

    def slow_step():                    # keep the solve running long enough
        time.sleep(0.05)                # for shutdown to land mid-flight
        return orig_step()

    fe.service.step = slow_step
    h = fe.submit_async(tiny_problem, job_id="t", n_iters=64, format="coo")
    fe.start()
    assert next(h.events(timeout=300))["type"] == "progress"
    fe.shutdown(drain=False, timeout=60)
    assert isinstance(h.exception(), ShutdownError)
    assert h.status() == "failed"

    svc = LifeService(_cfg(n_iters=64), ckpt_dir=ck)
    assert svc.resumable_jobs == ("t",)
    svc.submit(tiny_problem, job_id="t")
    job = svc.scheduler.job("t")
    assert 0 < job.done < 64            # adopted mid-flight
    _, losses = svc.run()["t"]
    assert losses.shape == (64,)
