"""Science-workload layer: pruning, crossval, lesions, warm starts (§15)."""
import os

import numpy as np
import pytest

TEST_SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))

from repro.core.life import LifeConfig, LifeEngine
from repro.data.dmri import (coarsen_problem, fiber_bundles,
                             synth_connectome)
from repro.science import (crossval_rmse, heldout_rmse, kfold_voxel_folds,
                           lesion_problem, multires_solve, prune_connectome,
                           restrict_to_voxels, resubmit_delta,
                           solve_to_convergence, virtual_lesion,
                           warm_start_weights, weight_summary)

CFG = LifeConfig(executor="opt")


@pytest.fixture(scope="module")
def problem():
    return synth_connectome(n_fibers=96, n_theta=16, n_atoms=24,
                            grid=(10, 10, 10), seed=3 + TEST_SEED,
                            noise=0.02)


@pytest.fixture(scope="module")
def converged(problem):
    return solve_to_convergence(LifeEngine(problem, CFG), rtol=1e-5,
                                chunk=8, max_iters=300)


# -- pruning ---------------------------------------------------------------

def test_prune_support_and_compaction(problem, converged):
    pr = prune_connectome(problem, converged.w, threshold=1e-3)
    w = converged.w
    expect = np.nonzero(w > 1e-3)[0]
    structural = np.unique(np.asarray(problem.phi.fibers))
    expect = np.intersect1d(expect, structural)
    assert np.array_equal(pr.support, expect)
    assert 0 < pr.n_kept < pr.n_fibers_total
    # compacted Phi holds exactly the surviving fibers' coefficients
    assert set(np.unique(np.asarray(pr.phi.fibers))) <= set(pr.support)
    fib = np.asarray(problem.phi.fibers)
    assert pr.phi.n_coeffs == int(np.isin(fib, pr.support).sum())
    # fiber id space unchanged: weight vectors stay shape-compatible
    assert pr.phi.n_fibers == problem.phi.n_fibers
    assert pr.weight_of(int(pr.support[0])) == pytest.approx(
        float(w[pr.support[0]]))
    off = np.setdiff1d(np.arange(problem.phi.n_fibers), pr.support)
    assert pr.weight_of(int(off[0])) == 0.0
    s = weight_summary(w, 1e-3)
    assert s["kept"] == float(pr.n_kept)
    assert s["w_min"] > 1e-3


def test_prune_support_identical_across_formats(problem):
    """Same seed through coo/sell/fcoo -> bit-identical pruned support."""
    supports = {}
    for fmt, executor in (("coo", "opt"), ("sell", "kernel-sell"),
                          ("fcoo", "kernel-fcoo")):
        cfg = LifeConfig(executor=executor, format=fmt, n_iters=40)
        w, _ = LifeEngine(problem, cfg).run()
        supports[fmt] = prune_connectome(problem, w, 1e-3).support
    assert np.array_equal(supports["coo"], supports["sell"])
    assert np.array_equal(supports["coo"], supports["fcoo"])


# -- cross-validation ------------------------------------------------------

@pytest.mark.parametrize("k", [2, 3, 7])
def test_kfold_disjoint_and_covering(k):
    n = 211
    folds = kfold_voxel_folds(n, k, seed=TEST_SEED)
    assert len(folds) == k
    cat = np.concatenate(folds)
    assert cat.size == n                       # covering, no duplicates
    assert np.array_equal(np.sort(cat), np.arange(n))
    sizes = [f.size for f in folds]
    assert max(sizes) - min(sizes) <= 1


def test_kfold_validation():
    with pytest.raises(ValueError):
        kfold_voxel_folds(10, 1)
    with pytest.raises(ValueError):
        kfold_voxel_folds(10, 11)


def test_restrict_to_voxels_consistency(problem):
    """Restricted prediction rows == the same rows of the full prediction."""
    from repro.core import spmv
    vox = np.arange(0, problem.phi.n_voxels, 7)
    sub = restrict_to_voxels(problem, vox)
    assert sub.phi.n_voxels == vox.size
    assert sub.b.shape[0] == vox.size
    full = np.asarray(spmv.dsc_naive(problem.phi, problem.dictionary,
                                     problem.w_true))
    part = np.asarray(spmv.dsc_naive(sub.phi, sub.dictionary, sub.w_true))
    np.testing.assert_allclose(part, full[vox], rtol=1e-5, atol=1e-6)
    with pytest.raises(ValueError):
        restrict_to_voxels(problem, [])
    with pytest.raises(ValueError):
        restrict_to_voxels(problem, [problem.phi.n_voxels])


def test_crossval_beats_null(problem):
    cv = crossval_rmse(problem, CFG, k=3, seed=TEST_SEED, n_iters=40)
    assert len(cv.fold_rmse) == 3
    assert cv.mean_rmse < cv.null_rmse
    assert 0.0 < cv.relative_rmse < 1.0
    assert "crossval" in cv.describe()


# -- virtual lesions -------------------------------------------------------

@pytest.fixture(scope="module")
def bundle(problem):
    return fiber_bundles(problem, bundle_size=6, n_bundles=1,
                         seed=TEST_SEED)[0]


def test_fiber_bundles_disjoint_structural(problem):
    bundles = fiber_bundles(problem, bundle_size=5, n_bundles=3, seed=2)
    structural = set(np.unique(np.asarray(problem.phi.fibers)).tolist())
    seen = set()
    for b in bundles:
        assert b.size == 5
        ids = set(b.tolist())
        assert ids <= structural
        assert not ids & seen
        seen |= ids


def test_lesion_problem_keeps_fiber_space(problem, bundle):
    les = lesion_problem(problem, bundle)
    assert les.phi.n_fibers == problem.phi.n_fibers
    assert not np.isin(np.asarray(les.phi.fibers), bundle).any()
    assert np.all(np.asarray(les.w_true)[bundle] == 0.0)
    with pytest.raises(ValueError):
        lesion_problem(problem, [])
    with pytest.raises(ValueError):
        lesion_problem(problem, [problem.phi.n_fibers])


def test_lesioned_fibers_exactly_zero_in_pruned(problem, bundle, converged):
    """Lesioned fibers end with exactly zero weight in the pruned result."""
    rep = virtual_lesion(problem, bundle, CFG, w_full=converged.w,
                         rtol=1e-5, chunk=8, max_iters=300)
    # zero warm start + zero column => the weight never moves off zero
    assert np.all(rep.w_lesioned[bundle] == 0.0)
    les = lesion_problem(problem, bundle)
    pr = prune_connectome(les, rep.w_lesioned, threshold=1e-3)
    assert not np.isin(bundle, pr.support).any()
    for f in bundle:
        assert pr.weight_of(int(f)) == 0.0
    assert "evidence" in rep.describe()


def test_warm_start_matches_cold_fixed_point(problem, bundle, converged):
    """After a Phi delta, warm and cold solves reach the same fixed point
    (tolerance-bounded) and the warm start takes no more iterations."""
    les = lesion_problem(problem, bundle)
    cold = solve_to_convergence(LifeEngine(les, CFG), rtol=1e-5,
                                chunk=8, max_iters=300)
    warm = solve_to_convergence(
        LifeEngine(les, CFG), w0=warm_start_weights(converged.w, bundle),
        rtol=1e-5, chunk=8, max_iters=300)
    assert warm.converged and cold.converged
    assert warm.iters <= cold.iters
    assert heldout_rmse(les, warm.w) == pytest.approx(
        heldout_rmse(les, cold.w), rel=1e-2)
    # same support at a threshold comfortably above solver noise
    assert np.array_equal(prune_connectome(les, warm.w, 1e-2).support,
                          prune_connectome(les, cold.w, 1e-2).support)


def test_virtual_lesion_from_checkpoint(problem, bundle, tmp_path):
    from repro.core.life import LifeConfig as LC
    from repro.serve.service import LifeService
    svc = LifeService(LC(executor="opt"), ckpt_dir=str(tmp_path / "ck"))
    svc.submit(problem, job_id="subject", n_iters=48)
    svc.run()
    rep = virtual_lesion(problem, bundle, CFG, ckpt_dir=str(tmp_path / "ck"),
                         job_id="subject", rtol=1e-4, chunk=8, max_iters=200)
    assert rep.iters_full == 0            # warm start came from the snapshot
    assert rep.iters_warm > 0
    with pytest.raises(KeyError):
        virtual_lesion(problem, bundle, CFG,
                       ckpt_dir=str(tmp_path / "ck"), job_id="nope")


# -- multi-resolution ------------------------------------------------------

def test_coarsen_problem_signal_sums(problem):
    c = coarsen_problem(problem, 2)
    gx, gy, gz = problem.grid
    assert c.grid == (5, 5, 5)
    assert c.phi.n_voxels == 125
    assert c.phi.n_fibers == problem.phi.n_fibers
    # coarse row = sum of its children's rows
    b = np.asarray(problem.b)
    got = np.asarray(c.b)
    vox = np.arange(gx * gy * gz)
    x, rem = vox // (gy * gz), vox % (gy * gz)
    y, z = rem // gz, rem % gz
    cid = ((x // 2) * 5 + (y // 2)) * 5 + (z // 2)
    expect = np.zeros_like(got)
    np.add.at(expect, cid, b)
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-5)
    assert coarsen_problem(problem, 1) is problem
    with pytest.raises(ValueError):
        coarsen_problem(problem, 0)
    sub = restrict_to_voxels(problem, [0, 1])      # grid=None
    with pytest.raises(ValueError):
        coarsen_problem(sub, 2)


def test_multires_resume_skips_completed_levels(problem, tmp_path):
    ck = str(tmp_path / "mr")
    mr = multires_solve(problem, CFG, factors=(2,), rtol=1e-4, chunk=8,
                        max_iters=200, ckpt_dir=ck)
    assert mr.resumed_at == 0
    assert len(mr.levels) == 2 and all(lv["iters"] > 0 for lv in mr.levels)
    again = multires_solve(problem, CFG, factors=(2,), rtol=1e-4, chunk=8,
                           max_iters=200, ckpt_dir=ck)
    assert again.resumed_at == 2          # everything came from checkpoints
    assert again.total_iters == 0
    np.testing.assert_allclose(again.final.w, mr.final.w)
    with pytest.raises(ValueError):
        multires_solve(problem, CFG, factors=(2, 4))
    with pytest.raises(ValueError):
        multires_solve(problem, CFG, factors=(1,))


# -- served warm starts ----------------------------------------------------

def test_service_w0_warm_start(problem, converged):
    from repro.serve.service import LifeService
    svc = LifeService(LifeConfig(executor="opt"))
    svc.submit(problem, job_id="cold", n_iters=16)
    svc.submit(problem, job_id="warm", n_iters=16, w0=converged.w)
    res = svc.run()
    _, cold_losses = res["cold"]
    _, warm_losses = res["warm"]
    assert warm_losses[0] < cold_losses[0]
    with pytest.raises(ValueError):
        svc.submit(problem, job_id="bad-shape", w0=np.ones(3))
    with pytest.raises(ValueError):
        svc.submit(problem, job_id="bad-sign",
                   w0=-np.ones(problem.phi.n_fibers))


def test_resubmit_delta_through_frontend(problem, bundle, converged):
    from repro.serve.frontend import LifeFrontend
    les = lesion_problem(problem, bundle)
    with LifeFrontend(LifeConfig(executor="opt"), refine=False) as fe:
        h = resubmit_delta(fe, les, converged.w, lesioned=bundle, n_iters=16)
        w, losses = h.result(timeout=120)
        assert np.all(np.asarray(w)[bundle] == 0.0)
        cold = fe.submit_async(les, n_iters=16)
        _, cold_losses = cold.result(timeout=120)
    assert losses[0] < cold_losses[0]
    with pytest.raises(ValueError):
        resubmit_delta(fe, les, np.ones(3))


def test_example_smoke(monkeypatch, capsys):
    """examples/prune_connectome.py runs end to end on a small problem."""
    import importlib.util
    path = os.path.join(os.path.dirname(__file__), "..", "examples",
                        "prune_connectome.py")
    spec = importlib.util.spec_from_file_location("prune_connectome", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    monkeypatch.setattr("sys.argv", ["prune_connectome.py", "64"])
    mod.main()
    out = capsys.readouterr().out
    assert "pruned connectome" in out
    assert "evidence" in out
    assert "done." in out


def test_resume_rejects_w0(problem, tmp_path):
    from repro.serve.service import LifeService
    ck = str(tmp_path / "ck")
    svc = LifeService(LifeConfig(executor="opt"), ckpt_dir=ck)
    svc.submit(problem, job_id="s", n_iters=16)
    svc.run()
    svc2 = LifeService(LifeConfig(executor="opt"), ckpt_dir=ck)
    assert "s" in svc2.resumable_jobs
    with pytest.raises(ValueError, match="warm start"):
        svc2.submit(problem, job_id="s",
                    w0=np.ones(problem.phi.n_fibers))
