"""Observability layer: overhead contract, quantiles, invariants, traces.

Four contracts from DESIGN.md §12:

* disabled instruments are allocation-free no-ops (tracemalloc-pinned);
* the shared :func:`repro.obs.quantile` — and the histogram reservoir
  below its cap — match ``np.percentile`` exactly (hypothesis);
* the scheduler's counter algebra holds at every tick of a randomized
  trace: ``admitted == completed + queued + running``;
* span nesting round-trips through the flat Chrome-trace export by
  interval containment.
"""
import os
import tracemalloc

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import obs
from repro.obs.metrics import MetricsRegistry, quantile
from repro.obs.trace import _NOOP_SPAN, Tracer

TEST_SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))


# ----------------------------------------------------------------------------
# overhead contract
# ----------------------------------------------------------------------------

def _hot_loop(c, g, h, t, n=500):
    for _ in range(n):
        c.inc()
        g.set(3.0)
        h.observe(1.5)
        with t.span("hot"):
            pass


def test_disabled_instruments_allocate_nothing():
    """With the switch off, held instruments and span() must not allocate:
    tracemalloc attributes zero new bytes to the obs module sources.

    A genuine disabled-path allocation reproduces on every attempt; a
    full-suite process carries background allocation noise (jax worker
    threads, arena reuse), so the check retries a few times and passes on
    the first clean measurement."""
    import gc

    from repro.obs import metrics as metrics_mod
    from repro.obs import trace as trace_mod

    reg = MetricsRegistry()
    t = Tracer()
    c = reg.counter("x.count")
    g = reg.gauge("x.gauge")
    h = reg.histogram("x.hist")
    assert not obs.enabled()

    filters = [tracemalloc.Filter(True, metrics_mod.__file__),
               tracemalloc.Filter(True, trace_mod.__file__)]
    grew = None
    for _ in range(3):
        gc.collect()
        tracemalloc.start()
        try:
            _hot_loop(c, g, h, t)             # warm any lazy caches
            before = tracemalloc.take_snapshot()
            _hot_loop(c, g, h, t)
            after = tracemalloc.take_snapshot()
        finally:
            tracemalloc.stop()
        diff = after.filter_traces(filters).compare_to(
            before.filter_traces(filters), "lineno")
        grew = [s for s in diff if s.size_diff > 0]
        if not grew:
            break
    assert not grew, f"disabled path allocated: {grew}"
    # and nothing was recorded
    assert c.value == 0.0 and g.value == 0.0 and h.count == 0
    assert t.roots == []


def test_disabled_span_is_shared_noop():
    t = Tracer()
    s = t.span("anything", {"ignored": 1})
    assert s is _NOOP_SPAN
    with s as inner:
        inner.set_attr("k", "v")              # must be inert, not raise
    assert t.roots == []


# ----------------------------------------------------------------------------
# quantiles vs numpy
# ----------------------------------------------------------------------------

@st.composite
def float_samples(draw):
    n = draw(st.integers(1, 200))
    lo = draw(st.floats(-1e6, 1e6))
    spread = draw(st.floats(0.0, 1e6))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return (lo + spread * rng.random(n)).tolist()


@settings(max_examples=40, deadline=None)
@given(float_samples(), st.floats(0.0, 100.0))
def test_quantile_matches_numpy(xs, q):
    assert quantile(xs, q) == pytest.approx(
        float(np.percentile(xs, q)), rel=1e-9, abs=1e-6)


@settings(max_examples=15, deadline=None)
@given(float_samples())
def test_histogram_exact_below_reservoir_cap(xs):
    obs.enable()
    reg = MetricsRegistry()
    h = reg.histogram("h", max_samples=4096)
    for x in xs:
        h.observe(x)
    assert h.count == len(xs)
    assert h.sum == pytest.approx(sum(xs))
    assert h.min == min(xs) and h.max == max(xs)
    for q in (0.0, 25.0, 50.0, 95.0, 100.0):
        assert h.quantile(q) == pytest.approx(
            float(np.percentile(xs, q)), rel=1e-9, abs=1e-6)


def test_histogram_reservoir_is_deterministic_and_bounded():
    obs.enable()
    xs = np.random.default_rng(TEST_SEED).random(5000).tolist()

    def fill():
        h = MetricsRegistry().histogram("h.bounded", max_samples=256)
        for x in xs:
            h.observe(x)
        return h

    h1, h2 = fill(), fill()
    assert len(h1._samples) == 256 and h1.count == 5000
    # same name + same stream -> identical reservoir (repeatable quantiles)
    assert h1._samples == h2._samples
    # the estimate still lands near the true distribution
    assert h1.quantile(50.0) == pytest.approx(
        float(np.percentile(xs, 50.0)), abs=0.1)


def test_quantile_rejects_out_of_range():
    with pytest.raises(ValueError):
        quantile([1.0], 101.0)


# ----------------------------------------------------------------------------
# registry semantics
# ----------------------------------------------------------------------------

def test_reset_preserves_instrument_identity():
    """reset() zeroes in place: a handle cached before the reset keeps
    recording into the same instrument afterwards (what lets the serving
    stack survive table13's per-rate resets)."""
    obs.enable()
    reg = MetricsRegistry()
    c = reg.counter("kept", role="x")
    c.inc(5.0)
    reg.reset()
    assert c.value == 0.0
    c.inc(2.0)
    assert reg.counter("kept", role="x") is c
    assert reg.value("kept", role="x") == 2.0


def test_total_sums_matching_labels():
    obs.enable()
    reg = MetricsRegistry()
    reg.counter("lk", kind="tile", outcome="hit").inc(3.0)
    reg.counter("lk", kind="tune", outcome="hit").inc(2.0)
    reg.counter("lk", kind="tile", outcome="miss").inc(7.0)
    assert reg.total("lk", outcome="hit") == 5.0
    assert reg.total("lk") == 12.0
    assert reg.total("other") == 0.0


def test_snapshot_shape_and_reader():
    obs.enable()
    reg = MetricsRegistry()
    reg.counter("c", a="1").inc(4.0)
    reg.gauge("g").set(2.5)
    h = reg.histogram("h")
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["schema"] == "obs-1"
    from repro.obs import snapshot_value
    assert snapshot_value(snap, "counters", "c", {"a": 1}) == 4.0
    assert snapshot_value(snap, "gauges", "g") == 2.5
    assert snapshot_value(snap, "gauges", "missing") is None
    (he,) = snap["histograms"]
    assert he["count"] == 3 and he["quantiles"]["p50"] == 2.0
    # JSON-serializable end to end (no NaN/Inf for non-empty histograms)
    import json
    json.dumps(snap, allow_nan=False)


# ----------------------------------------------------------------------------
# span tracing
# ----------------------------------------------------------------------------

def _rebuild_by_containment(events):
    """Reconstruct the span tree from flat Chrome complete events."""
    nodes = [dict(e, children=[]) for e in
             sorted(events, key=lambda e: (e["ts"], -e["dur"]))]
    roots, stack = [], []
    for n in nodes:
        while stack and not (stack[-1]["ts"] <= n["ts"] and
                             n["ts"] + n["dur"] <= stack[-1]["ts"]
                             + stack[-1]["dur"]):
            stack.pop()
        (stack[-1]["children"] if stack else roots).append(n)
        stack.append(n)
    return roots


def _names(tree):
    return [(n["name"], _names(n["children"])) for n in tree]


def test_span_nesting_roundtrips_through_chrome_export():
    obs.enable()
    t = Tracer()
    with t.span("root", {"k": 1}):
        with t.span("child-a"):
            with t.span("leaf"):
                pass
        with t.span("child-b"):
            pass
    with t.span("root2"):
        pass

    tree = t.export()
    assert _names_from_dicts(tree) == [
        ("root", [("child-a", [("leaf", [])]), ("child-b", [])]),
        ("root2", []),
    ]
    assert tree[0]["attrs"] == {"k": 1}
    assert all(n["dur_us"] >= 0 for n in tree)

    rebuilt = _rebuild_by_containment(t.export_chrome())
    assert _names(rebuilt) == _names_from_dicts(tree)

    import json
    payload = json.loads(t.to_chrome_json())
    assert {e["ph"] for e in payload["traceEvents"]} == {"X"}


def _names_from_dicts(tree):
    return [(n["name"], _names_from_dicts(n["children"])) for n in tree]


def test_span_attrs_and_monotonic_durations():
    obs.enable()
    t = Tracer()
    with t.span("op") as sp:
        sp.set_attr("bytes", 128)
    (root,) = t.export()
    assert root["attrs"]["bytes"] == 128
    assert root["dur_us"] >= 0.0


def test_tracer_bounds_recorded_spans():
    obs.enable()
    t = Tracer(max_spans=3)
    for _ in range(5):
        with t.span("s"):
            pass
    assert len(t.roots) == 3 and t.dropped == 2
    t.reset()
    assert t.roots == [] and t.dropped == 0


# ----------------------------------------------------------------------------
# scheduler counter invariant over randomized traces
# ----------------------------------------------------------------------------

def test_scheduler_counters_hold_over_random_traces(tiny_cohort):
    """At every observable point of a randomized submit/tick interleaving:
    admitted == completed + queued + running (DESIGN.md §12.2)."""
    from repro.core.life import LifeConfig
    from repro.serve import LifeService

    obs.enable()
    rng = np.random.default_rng(100 + TEST_SEED)
    for trial in range(3):
        obs.reset()
        svc = LifeService(LifeConfig(executor="opt", n_iters=8,
                                     plan_cache_dir=""), slice_iters=3)
        pending = [(p, ["coo", "auto", "sell", "fcoo"][rng.integers(4)],
                    int(rng.integers(0, 3)), int(rng.integers(4, 12)))
                   for p in tiny_cohort]

        def check():
            admitted = obs.value("serve.jobs.admitted")
            completed = obs.value("serve.jobs.completed")
            queued = obs.value("serve.queue.depth")
            running = obs.value("serve.jobs.running")
            assert admitted == completed + queued + running, (
                f"trial {trial}: admitted={admitted} != "
                f"completed={completed} + queued={queued} + "
                f"running={running}")

        i = 0
        while pending or svc.scheduler.active():
            if pending and (not svc.scheduler.active()
                            or rng.random() < 0.5):
                p, fmt, pri, n = pending.pop()
                svc.submit(p, job_id=f"t{trial}-j{i}", n_iters=n,
                           format=fmt, priority=pri)
                i += 1
            else:
                svc.step()
            check()
        assert obs.value("serve.jobs.admitted") == len(tiny_cohort)
        assert obs.value("serve.jobs.completed") == len(tiny_cohort)
        assert obs.histogram("serve.queue.depth").count > 0
        assert obs.histogram("serve.slice.seconds").count > 0


def test_extended_counter_algebra_with_failures_and_cancels(tiny_cohort):
    """The §13 extension of the invariant above, under randomized
    submit/tick interleavings with poisoned tenants and a cancellation:
    admitted == completed + failed + cancelled + queued + running."""
    import dataclasses

    from repro.core.life import LifeConfig
    from repro.serve import LifeService

    obs.enable()
    rng = np.random.default_rng(300 + TEST_SEED)
    svc = LifeService(LifeConfig(executor="opt", n_iters=8,
                                 plan_cache_dir=""), slice_iters=3)
    pending = [(tiny_cohort[0], "h0", 40), (tiny_cohort[1], "h1", 6),
               (tiny_cohort[2], "h2", 6),
               (dataclasses.replace(tiny_cohort[0],
                                    b=np.asarray(tiny_cohort[0].b)[:-3]),
                "p0", 6),
               (dataclasses.replace(tiny_cohort[1],
                                    b=np.asarray(tiny_cohort[1].b)[:-3]),
                "p1", 6)]
    rng.shuffle(pending)

    def check():
        admitted = obs.value("serve.jobs.admitted")
        completed = obs.value("serve.jobs.completed")
        failed = obs.value("serve.jobs.failed")
        cancelled = obs.value("serve.jobs.cancelled")
        queued = obs.value("serve.queue.depth")
        running = obs.value("serve.jobs.running")
        assert admitted == (completed + failed + cancelled
                            + queued + running), (
            f"admitted={admitted} != completed={completed} + "
            f"failed={failed} + cancelled={cancelled} + "
            f"queued={queued} + running={running}")

    submitted = set()
    cancelled_h0 = False
    tried_cancel = False
    steps = 0
    while pending or svc.scheduler.active():
        if pending and (not svc.scheduler.active() or rng.random() < 0.5):
            p, jid, n = pending.pop()
            svc.submit(p, job_id=jid, n_iters=n, format="coo")
            submitted.add(jid)
        else:
            svc.step()
            steps += 1
            if not tried_cancel and steps >= 3 and "h0" in submitted:
                tried_cancel = True             # mid-flight cancellation
                cancelled_h0 = svc.cancel("h0")
                check()
        check()
    assert obs.value("serve.jobs.admitted") == 5.0
    assert obs.value("serve.jobs.failed") == 2.0
    assert obs.value("serve.jobs.cancelled") == float(cancelled_h0)
    assert svc.failed_jobs == ("p0", "p1")


def test_service_latency_and_snapshot_surface(tiny_cohort):
    """submit->finish latency lands in the histogram and
    metrics_snapshot() mirrors the plan-cache stats into gauges."""
    from repro.core.life import LifeConfig
    from repro.serve import LifeService

    obs.enable()
    svc = LifeService(LifeConfig(executor="opt", n_iters=6,
                                 plan_cache_dir=""), slice_iters=3)
    for i, p in enumerate(tiny_cohort):
        svc.submit(p, job_id=f"j{i}", n_iters=6, format="coo")
    svc.run()
    lat = obs.histogram("serve.job.latency.seconds")
    assert lat.count == len(tiny_cohort)
    assert lat.min >= 0.0
    snap = svc.metrics_snapshot()
    from repro.obs import snapshot_value
    assert snapshot_value(snap, "gauges", "plan_cache.hit_rate") is not None
    assert snap["spans"]["recorded"] > 0


# ----------------------------------------------------------------------------
# plan cache + engine surfacing
# ----------------------------------------------------------------------------

def test_plan_cache_lookup_counters_by_kind(tiny_problem, tmp_path):
    """Engine builds drive the labeled lookup counters: a cold kernel build
    misses tile plans, a warm rebuild hits every one."""
    from repro.core.life import LifeConfig, LifeEngine
    from repro.core.plan_cache import PlanCache

    obs.enable()
    cfg = LifeConfig(executor="kernel", plan_cache_dir=str(tmp_path))
    LifeEngine(tiny_problem, cfg)
    misses = obs.total("plan_cache.lookups", kind="tile", outcome="miss")
    assert misses > 0
    obs.reset()
    warm = PlanCache(str(tmp_path))
    eng = LifeEngine(tiny_problem, cfg, warm)
    assert obs.total("plan_cache.lookups", outcome="miss") == 0.0
    assert obs.total("plan_cache.lookups", kind="tile",
                     outcome="hit") == misses
    assert eng.cache_stats.hit_rate == 1.0
    obs.record_cache_stats(eng.cache_stats)
    assert obs.value("plan_cache.hit_rate") == 1.0


def test_cache_stats_hit_rate_property():
    from repro.core.plan_cache import CacheStats
    s = CacheStats()
    assert s.hit_rate == 0.0 and s.lookups == 0
    s.record(True, kind="tile")
    s.record(False, kind="tile")
    assert s.lookups == 2 and s.hit_rate == 0.5


def test_engine_step_populates_histogram_and_roofline(tiny_problem):
    from repro.core.life import LifeConfig, LifeEngine

    obs.enable()
    eng = LifeEngine(tiny_problem, LifeConfig(executor="opt", n_iters=4,
                                              plan_cache_dir=""))
    state = eng.init_state()
    eng.step(state, 4)
    h = obs.histogram("engine.step.seconds", executor="opt")
    assert h.count == 1
    assert obs.value("engine.roofline.fraction",
                     executor="opt", format="coo") > 0.0
    (root,) = [s for s in obs.TRACER.export() if s["name"] == "engine.step"]
    assert root["attrs"]["k"] == 4
    assert "roofline_fraction" in root["attrs"]


def test_disabled_stack_records_nothing(tiny_problem):
    """The instrumented production stack writes nothing while disabled."""
    from repro.core.life import LifeConfig, LifeEngine

    assert not obs.enabled()
    eng = LifeEngine(tiny_problem, LifeConfig(executor="opt", n_iters=4,
                                              plan_cache_dir=""))
    state = eng.init_state()
    eng.step(state, 4)
    snap = obs.snapshot()
    assert all(c["value"] == 0.0 for c in snap["counters"])
    assert all(h["count"] == 0 for h in snap["histograms"])
    assert snap["spans"]["recorded"] == 0
