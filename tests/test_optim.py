"""Optimizers: AdamW reference agreement, Adafactor descent, clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import (OptConfig, apply_updates, clip_by_global_norm,
                               init_opt_state, schedule)


def _adamw_reference(w, g, mu, nu, step, cfg):
    mu = cfg.b1 * mu + (1 - cfg.b1) * g
    nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
    mu_hat = mu / (1 - cfg.b1 ** step)
    nu_hat = nu / (1 - cfg.b2 ** step)
    upd = mu_hat / (np.sqrt(nu_hat) + cfg.eps) + cfg.weight_decay * w
    lr = float(schedule(cfg, jnp.asarray(step)))
    return w - lr * upd, mu, nu


def test_adamw_matches_reference(rng):
    cfg = OptConfig(lr=1e-2, grad_clip=1e9, warmup_steps=1, decay_steps=100)
    w = {"a": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)}
    state = init_opt_state(cfg, w)
    w_np = np.asarray(w["a"], np.float64)
    mu = np.zeros_like(w_np)
    nu = np.zeros_like(w_np)
    cur = w
    for step in range(1, 4):
        g = {"a": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)}
        cur, state, _ = apply_updates(cfg, cur, g, state)
        w_np, mu, nu = _adamw_reference(w_np, np.asarray(g["a"], np.float64),
                                        mu, nu, step, cfg)
        np.testing.assert_allclose(np.asarray(cur["a"]), w_np,
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_quadratic_descent(rng, kind):
    cfg = OptConfig(kind=kind, lr=0.05, weight_decay=0.0, warmup_steps=1,
                    decay_steps=10_000)
    target = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    state = init_opt_state(cfg, params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = apply_updates(cfg, params, g, state)
    assert float(loss(params)) < 0.2 * l0


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert np.isclose(float(norm), np.sqrt(10 * 9 + 10 * 16))
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    assert np.isclose(total, 1.0, rtol=1e-5)


def test_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, decay_steps=100, min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert np.isclose(float(schedule(cfg, jnp.asarray(10))), 1.0)
    assert float(schedule(cfg, jnp.asarray(1000))) >= 0.099
