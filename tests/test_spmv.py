"""SpMV executors vs the dense oracle + hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import spmv
from repro.core.restructure import sort_by_host
from repro.core.std import PhiTensor, make_dictionary, materialize_dense


def _rand_w(rng, n):
    return jnp.asarray(rng.uniform(size=n), jnp.float32)


def _rand_y(rng, nv, nt):
    return jnp.asarray(rng.normal(size=(nv, nt)), jnp.float32)


def test_dsc_naive_matches_dense(tiny_problem, tiny_dense, rng):
    w = _rand_w(rng, tiny_problem.phi.n_fibers)
    got = spmv.dsc_naive(tiny_problem.phi, tiny_problem.dictionary, w)
    want = (tiny_dense @ w).reshape(tiny_problem.phi.n_voxels, -1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-5)


def test_wc_naive_matches_dense(tiny_problem, tiny_dense, rng):
    y = _rand_y(rng, tiny_problem.phi.n_voxels, 16)
    got = spmv.wc_naive(tiny_problem.phi, tiny_problem.dictionary, y)
    want = tiny_dense.T @ y.reshape(-1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("dim,fn", [
    ("voxel", spmv.dsc), ("atom", spmv.dsc_atom_sorted)])
def test_dsc_restructured_matches_naive(tiny_problem, rng, dim, fn):
    w = _rand_w(rng, tiny_problem.phi.n_fibers)
    phi_s, _ = sort_by_host(tiny_problem.phi, dim)
    got = fn(phi_s, tiny_problem.dictionary, w)
    want = spmv.dsc_naive(tiny_problem.phi, tiny_problem.dictionary, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dim,fn", [
    ("fiber", spmv.wc), ("atom", spmv.wc_atom_sorted)])
def test_wc_restructured_matches_naive(tiny_problem, rng, dim, fn):
    y = _rand_y(rng, tiny_problem.phi.n_voxels, 16)
    phi_s, _ = sort_by_host(tiny_problem.phi, dim)
    got = fn(phi_s, tiny_problem.dictionary, y)
    want = spmv.wc_naive(tiny_problem.phi, tiny_problem.dictionary, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------------
# Property tests: random COO tensors
# ----------------------------------------------------------------------------

@st.composite
def coo(draw):
    nc = draw(st.integers(1, 200))
    na = draw(st.integers(1, 8))
    nv = draw(st.integers(1, 30))
    nf = draw(st.integers(1, 20))
    seed = draw(st.integers(0, 2**31 - 1))
    r = np.random.default_rng(seed)
    return PhiTensor(
        atoms=jnp.asarray(r.integers(0, na, nc), jnp.int32),
        voxels=jnp.asarray(r.integers(0, nv, nc), jnp.int32),
        fibers=jnp.asarray(r.integers(0, nf, nc), jnp.int32),
        values=jnp.asarray(r.normal(size=nc), jnp.float32),
        n_atoms=na, n_voxels=nv, n_fibers=nf), seed


@settings(max_examples=25, deadline=None)
@given(coo())
def test_property_dsc_equals_dense(case):
    phi, seed = case
    r = np.random.default_rng(seed + 1)
    d = make_dictionary(phi.n_atoms, 8)
    w = jnp.asarray(r.uniform(size=phi.n_fibers), jnp.float32)
    m = materialize_dense(phi, d)
    got = spmv.dsc_naive(phi, d, w)
    want = (m @ w).reshape(phi.n_voxels, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(coo())
def test_property_wc_adjoint_of_dsc(case):
    """<Mw, y> == <w, M^T y>: DSC and WC are exact adjoints."""
    phi, seed = case
    r = np.random.default_rng(seed + 2)
    d = make_dictionary(phi.n_atoms, 8)
    w = jnp.asarray(r.normal(size=phi.n_fibers), jnp.float32)
    y = jnp.asarray(r.normal(size=(phi.n_voxels, 8)), jnp.float32)
    lhs = jnp.vdot(spmv.dsc_naive(phi, d, w), y)
    rhs = jnp.vdot(w, spmv.wc_naive(phi, d, y))
    np.testing.assert_allclose(float(lhs), float(rhs), rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(coo())
def test_property_sort_invariance(case):
    """Restructuring (any sort) never changes either SpMV result."""
    phi, seed = case
    r = np.random.default_rng(seed + 3)
    d = make_dictionary(phi.n_atoms, 8)
    w = jnp.asarray(r.uniform(size=phi.n_fibers), jnp.float32)
    base = spmv.dsc_naive(phi, d, w)
    for dim in ("atom", "voxel", "fiber"):
        phi_s, _ = sort_by_host(phi, dim)
        np.testing.assert_allclose(
            np.asarray(spmv.dsc_naive(phi_s, d, w)), np.asarray(base),
            rtol=1e-4, atol=1e-5)
