"""F-COO linearization + segment-scan primitive: property suite
(DESIGN.md §11; structure mirrors test_shard_format.py).

Property tests run through the hypothesis stub when the real package is
missing (tests/_hypothesis_stub.py), so they execute everywhere.  The
pure-jnp references exercise the layout's semantics; the Pallas kernel
pair itself is additionally held to the dense oracle by the conformance
matrix (test_conformance.py) the moment ``kernel-fcoo`` registers.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.std import PhiTensor
from repro.formats import canonical_triples
from repro.formats.fcoo import (FcooPhi, chunk_segment_map, dsc_reference,
                                wc_reference)


@st.composite
def small_phi(draw):
    nc = draw(st.integers(1, 400))
    nv = draw(st.integers(1, 40))
    nf = draw(st.integers(1, 24))
    na = draw(st.integers(1, 8))
    skewed = draw(st.booleans())
    seed = draw(st.integers(0, 2**31 - 1))
    r = np.random.default_rng(seed)
    voxels = r.integers(0, nv, nc)
    fibers = r.integers(0, nf, nc)
    if skewed:
        # concentrate most coefficients on one id per mode — long runs
        # spanning several chunks, the chunk-boundary combine's hard case
        voxels[: (6 * nc) // 10] = int(r.integers(0, nv))
        fibers[: (6 * nc) // 10] = int(r.integers(0, nf))
    return PhiTensor(
        atoms=jnp.asarray(r.integers(0, na, nc), jnp.int32),
        voxels=jnp.asarray(voxels, jnp.int32),
        fibers=jnp.asarray(fibers, jnp.int32),
        values=jnp.asarray(r.normal(size=nc).astype(np.float32)),
        n_atoms=na, n_voxels=nv, n_fibers=nf)


def _assert_same_multiset(a: PhiTensor, b: PhiTensor):
    for x, y in zip(canonical_triples(a), canonical_triples(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_same_multiset_unordered(a: PhiTensor, b: PhiTensor):
    """Multiset equality with values in the sort key — canonical_triples
    alone leaves duplicate triples in input-relative order, which a
    shuffled input legitimately changes."""
    def key(p):
        at = np.asarray(p.atoms, np.int64)
        v = np.asarray(p.voxels, np.int64)
        f = np.asarray(p.fibers, np.int64)
        vals = np.asarray(p.values)
        order = np.lexsort((vals, f, v, at))
        return at[order], v[order], f[order], vals[order]
    for x, y in zip(key(a), key(b)):
        np.testing.assert_array_equal(x, y)


def _np_dsc(fc: FcooPhi, d: np.ndarray, w: np.ndarray) -> np.ndarray:
    """float64 scatter-add DSC over the linearized stream (jax runs fp32
    here, so exactness claims go through numpy)."""
    scaled = w[fc.fibers] * fc.values.astype(np.float64)
    y = np.zeros((fc.n_voxels, d.shape[1]))
    np.add.at(y, fc.voxels, d[fc.atoms] * scaled[:, None])
    return y


def _np_wc(fc: FcooPhi, d: np.ndarray, y: np.ndarray) -> np.ndarray:
    dots = (d[fc.atoms] * y[fc.voxels]).sum(-1) * fc.values.astype(np.float64)
    w = np.zeros(fc.n_fibers)
    np.add.at(w, fc.fibers, dots)
    return w


# ----------------------------------------------------------------------------
# the segment-scan primitive (host side of the kernels' one-hot reduction)
# ----------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 32), st.integers(0, 2**31 - 1),
       st.sampled_from([4, 8, 16]), st.booleans())
def test_chunk_segment_map_invariants(n_chunks, c_tile, seed, seg_tile,
                                      sort_ids):
    r = np.random.default_rng(seed)
    n_rows = int(r.integers(1, 50))
    ids = r.integers(0, n_rows, n_chunks * c_tile)
    if sort_ids:
        ids = np.sort(ids)                # sortedness is NOT required
    seg_rows, ranks, k = chunk_segment_map(ids, c_tile, seg_tile, n_rows)
    assert k % seg_tile == 0 and seg_rows.shape == (n_chunks, k)
    ranks2 = ranks.reshape(n_chunks, c_tile)
    ids2 = ids.reshape(n_chunks, c_tile)
    # ranks: chunk-local prefix sum of the segment flags
    assert (ranks2[:, 0] == 0).all()
    flags = (ids2[:, 1:] != ids2[:, :-1]).astype(np.int32)
    np.testing.assert_array_equal(np.diff(ranks2, axis=1), flags)
    # every slot's segment names exactly its own output row
    np.testing.assert_array_equal(
        seg_rows[np.repeat(np.arange(n_chunks), c_tile), ranks], ids)
    # entries past a chunk's last segment hold the dummy row
    for t in range(n_chunks):
        assert (seg_rows[t, ranks2[t, -1] + 1:] == n_rows).all()


def test_chunk_segment_map_rejects_ragged_stream():
    with pytest.raises(ValueError, match="c_tile"):
        chunk_segment_map(np.zeros(10, np.int64), 4, 8, 3)


def test_chunk_segment_map_empty_stream():
    seg_rows, ranks, k = chunk_segment_map(np.zeros(0, np.int64), 4, 8, 3)
    assert seg_rows.shape == (0, 8) and ranks.size == 0 and k == 8


def test_segment_scan_matches_scatter_sum():
    """The chunked one-hot segment reduction + seg_rows scatter (exactly
    the kernel dataflow, in numpy) equals a direct scatter-add — including
    runs that span chunk boundaries."""
    r = np.random.default_rng(7)
    n_rows, c_tile, seg_tile = 9, 8, 4
    ids = np.sort(r.integers(0, n_rows, 40))
    vals = r.normal(size=40)
    seg_rows, ranks, k = chunk_segment_map(ids, c_tile, seg_tile, n_rows)
    out = np.zeros(n_rows + 1)
    for t in range(ids.size // c_tile):
        sl = slice(t * c_tile, (t + 1) * c_tile)
        onehot = (np.arange(k)[:, None] == ranks[sl][None, :])
        np.add.at(out, seg_rows[t], onehot @ vals[sl])
    want = np.zeros(n_rows + 1)
    np.add.at(want, ids, vals)
    np.testing.assert_allclose(out[:n_rows], want[:n_rows], rtol=1e-12)


# ----------------------------------------------------------------------------
# format properties
# ----------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(small_phi(), st.sampled_from([16, 64]), st.sampled_from([8, 16]))
def test_roundtrip_exact(phi, c_tile, seg_tile):
    fc = FcooPhi.encode(phi, c_tile=c_tile, seg_tile=seg_tile)
    assert fc.n_coeffs == phi.n_coeffs
    assert fc.atoms.size % c_tile == 0
    _assert_same_multiset(phi, fc.decode())


@settings(max_examples=15, deadline=None)
@given(small_phi(), st.integers(0, 2**31 - 1))
def test_permutation_invariance_of_input_order(phi, seed):
    """Encoding any permutation of the input triples yields the same
    results — the linearization is a total order over the triples.  With
    duplicate triples the within-segment summation *order* may differ, so
    results are compared to fp tolerance; layouts of deduplicated streams
    are compared bit-exactly below."""
    r = np.random.default_rng(seed)
    perm = r.permutation(phi.n_coeffs)
    shuffled = phi.take(jnp.asarray(perm))
    a = FcooPhi.encode(phi, c_tile=32, seg_tile=8)
    b = FcooPhi.encode(shuffled, c_tile=32, seg_tile=8)
    _assert_same_multiset_unordered(a.decode(), b.decode())
    d = jnp.asarray(r.normal(size=(phi.n_atoms, 6)).astype(np.float32))
    w = jnp.asarray(r.uniform(0, 1, phi.n_fibers).astype(np.float32))
    y = jnp.asarray(r.normal(size=(phi.n_voxels, 6)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(dsc_reference(a, d, w)),
                               np.asarray(dsc_reference(b, d, w)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(wc_reference(a, d, y)),
                               np.asarray(wc_reference(b, d, y)),
                               rtol=1e-5, atol=1e-6)


def test_permutation_invariance_bitwise_on_unique_triples():
    """With all-distinct triples the layout itself (every resident array)
    is identical under any input permutation."""
    r = np.random.default_rng(3)
    nv, nf, na = 7, 5, 4
    trip = np.array([(v, f, a) for v in range(nv) for f in range(nf)
                     for a in range(na)], np.int64)
    trip = trip[r.permutation(len(trip))[:60]]
    phi = PhiTensor(
        atoms=jnp.asarray(trip[:, 2], jnp.int32),
        voxels=jnp.asarray(trip[:, 0], jnp.int32),
        fibers=jnp.asarray(trip[:, 1], jnp.int32),
        values=jnp.asarray(r.normal(size=60).astype(np.float32)),
        n_atoms=na, n_voxels=nv, n_fibers=nf)
    a = FcooPhi.encode(phi, c_tile=16, seg_tile=8)
    b = FcooPhi.encode(phi.take(jnp.asarray(r.permutation(60))),
                       c_tile=16, seg_tile=8)
    for fld in ("atoms", "voxels", "fibers", "values", "wc_perm",
                "dsc_ranks", "wc_ranks", "seg_rows_dsc", "seg_rows_wc"):
        np.testing.assert_array_equal(getattr(a, fld), getattr(b, fld),
                                      err_msg=fld)


@settings(max_examples=15, deadline=None)
@given(small_phi(), st.integers(1, 30), st.integers(0, 2**31 - 1))
def test_duplicate_indices_accumulate(phi, n_dup, seed):
    """Repeating existing triples with extra values accumulates (never
    overwrites) — equal to the dense operator of the concatenated tensor.
    The layout semantics are checked in float64 numpy (exact to
    summation-order noise ~1e-12); the jnp references confirm at fp32."""
    r = np.random.default_rng(seed)
    pick = r.integers(0, phi.n_coeffs, n_dup)
    aug = PhiTensor(
        atoms=jnp.concatenate([phi.atoms, phi.atoms[pick]]),
        voxels=jnp.concatenate([phi.voxels, phi.voxels[pick]]),
        fibers=jnp.concatenate([phi.fibers, phi.fibers[pick]]),
        values=jnp.concatenate(
            [phi.values, jnp.asarray(r.normal(size=n_dup), phi.values.dtype)]),
        n_atoms=phi.n_atoms, n_voxels=phi.n_voxels, n_fibers=phi.n_fibers)
    fc = FcooPhi.encode(aug, c_tile=32, seg_tile=8)
    d64 = r.normal(size=(phi.n_atoms, 6))
    w64 = r.uniform(0, 1, phi.n_fibers)
    m = np.zeros((phi.n_voxels * 6, phi.n_fibers))
    for a, v, f, val in zip(np.asarray(aug.atoms), np.asarray(aug.voxels),
                            np.asarray(aug.fibers),
                            np.asarray(aug.values, np.float64)):
        m[v * 6:(v + 1) * 6, f] += d64[a] * val
    np.testing.assert_allclose(_np_dsc(fc, d64, w64).reshape(-1), m @ w64,
                               rtol=1e-9, atol=1e-9)
    d = jnp.asarray(d64.astype(np.float32))
    w = jnp.asarray(w64.astype(np.float32))
    got = np.asarray(dsc_reference(fc, d, w), np.float64).reshape(-1)
    np.testing.assert_allclose(got, m @ w64, rtol=2e-4, atol=2e-5)


def test_empty_segment_rows_are_exact_zeros():
    """Output rows no coefficient touches never appear in any segment map,
    so they come out as exact (bitwise) zeros from both ops."""
    r = np.random.default_rng(11)
    nv, nf = 20, 15
    phi = PhiTensor(                       # only even voxels / fibers < 5
        atoms=jnp.asarray(r.integers(0, 4, 50), jnp.int32),
        voxels=jnp.asarray(2 * r.integers(0, nv // 2, 50), jnp.int32),
        fibers=jnp.asarray(r.integers(0, 5, 50), jnp.int32),
        values=jnp.asarray(r.normal(size=50).astype(np.float32)),
        n_atoms=4, n_voxels=nv, n_fibers=nf)
    fc = FcooPhi.encode(phi, c_tile=16, seg_tile=8)
    touched_v = set(np.asarray(phi.voxels).tolist())
    touched_f = set(np.asarray(phi.fibers).tolist())
    assert set(fc.seg_rows_dsc.reshape(-1).tolist()) <= touched_v | {nv}
    assert set(fc.seg_rows_wc.reshape(-1).tolist()) <= touched_f | {nf}
    d = jnp.asarray(r.normal(size=(4, 6)).astype(np.float32))
    y_dsc = np.asarray(dsc_reference(fc, d, jnp.ones((nf,), jnp.float32)))
    w_wc = np.asarray(wc_reference(
        fc, d, jnp.asarray(r.normal(size=(nv, 6)).astype(np.float32))))
    for v in range(nv):
        if v not in touched_v:
            assert (y_dsc[v] == 0.0).all()
    for f in range(nf):
        if f not in touched_f:
            assert w_wc[f] == 0.0
    # and the kernel executors agree bit-for-bit on the untouched rows
    from repro.kernels.ops import make_fcoo_ops
    mv, rmv = make_fcoo_ops(fc, d)
    yk = np.asarray(mv(jnp.ones((nf,), jnp.float32)))
    for v in range(nv):
        if v not in touched_v:
            assert (yk[v] == 0.0).all()


@settings(max_examples=10, deadline=None)
@given(small_phi(), st.integers(1, 50), st.integers(0, 2**31 - 1))
def test_zero_value_coefficients_are_inert(phi, n_zero, seed):
    """Appending explicit value-0 coefficients (anywhere) never changes
    either op — they may shift chunk boundaries and segment counts, so the
    comparison runs in float64 numpy where re-chunked summation order is
    exact to ~1e-12."""
    r = np.random.default_rng(seed)
    aug = PhiTensor(
        atoms=jnp.concatenate([phi.atoms, jnp.asarray(
            r.integers(0, phi.n_atoms, n_zero), jnp.int32)]),
        voxels=jnp.concatenate([phi.voxels, jnp.asarray(
            r.integers(0, phi.n_voxels, n_zero), jnp.int32)]),
        fibers=jnp.concatenate([phi.fibers, jnp.asarray(
            r.integers(0, phi.n_fibers, n_zero), jnp.int32)]),
        values=jnp.concatenate([phi.values,
                                jnp.zeros((n_zero,), phi.values.dtype)]),
        n_atoms=phi.n_atoms, n_voxels=phi.n_voxels, n_fibers=phi.n_fibers)
    d = r.normal(size=(phi.n_atoms, 6))
    w = r.uniform(0, 1, phi.n_fibers)
    y = r.normal(size=(phi.n_voxels, 6))
    a = FcooPhi.encode(phi, c_tile=32, seg_tile=8)
    b = FcooPhi.encode(aug, c_tile=32, seg_tile=8)
    np.testing.assert_allclose(_np_dsc(a, d, w), _np_dsc(b, d, w),
                               rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(_np_wc(a, d, y), _np_wc(b, d, y),
                               rtol=1e-10, atol=1e-10)


# ----------------------------------------------------------------------------
# kernels off the single resident copy + accounting
# ----------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(small_phi(), st.sampled_from([8, 32]), st.sampled_from([8, 16]),
       st.integers(0, 2**31 - 1))
def test_kernel_pair_matches_references(phi, c_tile, seg_tile, seed):
    """Both Pallas ops off one FcooPhi equal the pure-jnp references on
    arbitrary shapes — small c_tile forces many chunks, so runs spanning
    chunk boundaries (the scatter-add combine) are exercised hard."""
    from repro.kernels.ops import make_fcoo_ops
    r = np.random.default_rng(seed)
    d = jnp.asarray(r.normal(size=(phi.n_atoms, 6)).astype(np.float32))
    w = jnp.asarray(r.uniform(0, 1, phi.n_fibers).astype(np.float32))
    y = jnp.asarray(r.normal(size=(phi.n_voxels, 6)).astype(np.float32))
    fc = FcooPhi.encode(phi, c_tile=c_tile, seg_tile=seg_tile)
    mv, rmv = make_fcoo_ops(fc, d)
    np.testing.assert_allclose(np.asarray(mv(w)),
                               np.asarray(dsc_reference(fc, d, w)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(rmv(y)),
                               np.asarray(wc_reference(fc, d, y)),
                               rtol=1e-5, atol=1e-6)


def test_empty_phi_encodes_and_runs():
    from repro.kernels.ops import make_fcoo_ops
    phi = PhiTensor(atoms=jnp.zeros((0,), jnp.int32),
                    voxels=jnp.zeros((0,), jnp.int32),
                    fibers=jnp.zeros((0,), jnp.int32),
                    values=jnp.zeros((0,), jnp.float32),
                    n_atoms=3, n_voxels=4, n_fibers=5)
    fc = FcooPhi.encode(phi, c_tile=16, seg_tile=8)
    assert fc.n_chunks == 0 and fc.nbytes == 0
    d = jnp.ones((3, 6), jnp.float32)
    mv, rmv = make_fcoo_ops(fc, d)
    assert (np.asarray(mv(jnp.ones((5,), jnp.float32))) == 0.0).all()
    assert (np.asarray(rmv(jnp.ones((4, 6), jnp.float32))) == 0.0).all()
    _assert_same_multiset(phi, fc.decode())


def test_one_copy_beats_two_sell_encodes(tiny_problem):
    """The residency claim on a real connectome: one fcoo copy, with every
    resident array counted, stays under 0.6x of SELL(DSC)+SELL(WC) — the
    same ratio benchmarks/check_regression.py gates on the bench shape."""
    from repro.formats.sell import SellPhi
    phi = tiny_problem.phi
    fc = FcooPhi.encode(phi)
    sell = (SellPhi.encode(phi, op="dsc").nbytes
            + SellPhi.encode(phi, op="wc").nbytes)
    assert fc.nbytes > 0
    assert fc.nbytes <= 0.6 * sell, (fc.nbytes, sell)
    assert fc.padding_overhead >= 0.0
    allocated = fc.values.size
    assert allocated == pytest.approx(
        (1.0 + fc.padding_overhead) * fc.n_coeffs, rel=1e-6)
