"""Conformance matrix: every executor x format pair vs the naive oracle.

Per-subsystem suites (test_kernels, test_formats) validate each code version
against its own reference; this matrix is the cross-cutting contract — every
pair the registry declares valid (``REGISTRY.consumes``) must produce the
same matvec/rmatvec as the dense oracle, and full SBBNNLS trajectories must
agree across executors.  A new executor or format is covered the moment it
registers: the parametrization is derived from the registries at import
time, so drift between subsystems fails here even when each subsystem's own
tests pass.

This is the contract new executors/formats must pass (README "Serving").

The sharded executors additionally run the whole contract per mesh
topology: in-process over every (R, C) the current device count admits
(the CI multi-device lane forces 8 host devices so the 2- and 8-device
meshes execute there), and in a subprocess that forces 8 virtual CPU
devices regardless of the parent's topology (slow lane).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.life import LifeConfig, LifeEngine
from repro.core.plan_cache import PlanCache
from repro.core.registry import REGISTRY, create_for_format
from repro.formats import format_names

#: every (executor, format) pair registered at head — REGISTRY.consumes is
#: the single source of truth, so this list grows with the registries
MATRIX = [(ex, fmt) for fmt in format_names()
          for ex in REGISTRY.executors_for_format(fmt)]

_CFG = LifeConfig(executor="opt", c_tile=64, row_tile=8, slot_tile=16,
                  plan_cache_dir="")


def _make_executor(name, fmt, problem, **overrides):
    cfg = dataclasses.replace(_CFG, executor=name, format=fmt, **overrides)
    if fmt == "coo":
        return REGISTRY.create(name, problem.phi, problem, cfg, PlanCache(""))
    return create_for_format(problem.phi, problem, cfg, PlanCache(""))


def test_matrix_covers_whole_registry():
    """Every registered executor appears in exactly one format row — and
    the rows are *derived* (REGISTRY.consumes), never hand-kept, so the
    F-COO pair is enumerated the moment ``kernel-fcoo`` registers."""
    assert sorted(ex for ex, _ in MATRIX) == sorted(REGISTRY.names())
    assert {fmt for _, fmt in MATRIX} == set(format_names())
    assert ("kernel-fcoo", "fcoo") in MATRIX
    assert REGISTRY.executors_for_format("fcoo") == ("kernel-fcoo",)
    assert REGISTRY.consumes("kernel-fcoo") == "fcoo"


@pytest.mark.parametrize("executor,fmt", MATRIX)
def test_matvec_rmatvec_match_oracle(executor, fmt, tiny_problem,
                                     tiny_dense, rng):
    """DSC and WC of every pair agree with the dense oracle."""
    p = tiny_problem
    ex = _make_executor(executor, fmt, p)
    m = np.asarray(tiny_dense, np.float64)          # (Nv*Ntheta, Nf)
    n_theta = p.dictionary.shape[1]

    w = jnp.asarray(rng.uniform(0, 1, p.phi.n_fibers), jnp.float32)
    y = jnp.asarray(rng.normal(size=(p.phi.n_voxels, n_theta)), jnp.float32)

    got_mv = np.asarray(ex.matvec(w), np.float64).reshape(-1)
    want_mv = m @ np.asarray(w, np.float64)
    np.testing.assert_allclose(got_mv, want_mv, rtol=2e-4, atol=2e-5,
                               err_msg=f"{executor}/{fmt} matvec")

    got_rmv = np.asarray(ex.rmatvec(y), np.float64)
    want_rmv = m.T @ np.asarray(y, np.float64).reshape(-1)
    np.testing.assert_allclose(got_rmv, want_rmv, rtol=2e-4, atol=2e-5,
                               err_msg=f"{executor}/{fmt} rmatvec")


@pytest.mark.parametrize("executor,fmt", MATRIX)
def test_sbbnnls_trajectories_match(executor, fmt, tiny_problem):
    """Full solver trajectories agree across every executor x format pair
    (the oracle is the naive scatter executor on canonical COO)."""
    p = tiny_problem
    base = LifeEngine(p, dataclasses.replace(_CFG, executor="naive",
                                             n_iters=8))
    w_ref, l_ref = base.run()

    cfg = dataclasses.replace(_CFG, executor=executor, format=fmt, n_iters=8)
    w, losses = LifeEngine(p, cfg).run()
    np.testing.assert_allclose(losses, l_ref, rtol=2e-3,
                               err_msg=f"{executor}/{fmt} losses")
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref), rtol=2e-2,
                               atol=2e-3, err_msg=f"{executor}/{fmt} weights")


# ----------------------------------------------------------------------------
# differential fuzzing: randomized small problems, whole matrix, both dtypes
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("seed", (0, 1))
def test_differential_fuzz_whole_matrix(seed):
    """Randomized problems cross-check every executor x format pair (the
    new kernel-fcoo included, via the derived MATRIX) against the dense
    oracle — fp32 under the tight contract, bf16 under the documented
    BF16_RTOL/ATOL storage-rounding contract (repro/tune/plan.py)."""
    from repro.core.std import materialize_dense
    from repro.data.dmri import synth_connectome
    from repro.tune.plan import BF16_ATOL, BF16_RTOL
    p = synth_connectome(n_fibers=48, n_theta=12, n_atoms=16,
                         grid=(8, 8, 8), seed=1000 + seed)
    m = np.asarray(materialize_dense(p.phi, p.dictionary), np.float64)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.uniform(0, 1, p.phi.n_fibers), jnp.float32)
    y = jnp.asarray(rng.normal(size=(p.phi.n_voxels, 12)), jnp.float32)
    want_mv = m @ np.asarray(w, np.float64)
    want_rmv = m.T @ np.asarray(y, np.float64).reshape(-1)
    for executor, fmt in MATRIX:
        for cd, rtol, atol in (("fp32", 2e-4, 2e-5),
                               ("bf16", BF16_RTOL, BF16_ATOL)):
            ex = _make_executor(executor, fmt, p, compute_dtype=cd)
            np.testing.assert_allclose(
                np.asarray(ex.matvec(w), np.float64).reshape(-1), want_mv,
                rtol=rtol, atol=atol,
                err_msg=f"{executor}/{fmt}/{cd} matvec seed={seed}")
            np.testing.assert_allclose(
                np.asarray(ex.rmatvec(y), np.float64), want_rmv,
                rtol=rtol, atol=atol,
                err_msg=f"{executor}/{fmt}/{cd} rmatvec seed={seed}")


# ----------------------------------------------------------------------------
# sharded executors per mesh topology (1 / 2 / 8 devices)
# ----------------------------------------------------------------------------

#: mesh shapes the sharded contract is held on; meshes larger than the
#: current device count skip in-process and run in the forced-8 subprocess
MESHES = ((1, 1), (2, 1), (4, 2))

SHARD_EXECUTORS = tuple(n for n in REGISTRY.names()
                        if REGISTRY.mesh_executor_for(REGISTRY.consumes(n))
                        == n)


def _mesh_params():
    n = len(jax.devices())
    return [pytest.param(R, C, marks=pytest.mark.skipif(
        R * C > n, reason=f"needs {R * C} devices, have {n}"))
        for R, C in MESHES]


def test_sharded_executors_enumerate_automatically():
    """The matrix derives the sharded rows from registry metadata alone —
    the acceptance contract that `shard-sell` is reached via
    ``executors_for_format("sell")``, not via a hand-kept list."""
    assert "shard" in REGISTRY.executors_for_format("coo")
    assert "shard-sell" in REGISTRY.executors_for_format("sell")
    assert set(SHARD_EXECUTORS) == {"shard", "shard-sell"}
    assert {("shard", "coo"), ("shard-sell", "sell")} <= set(MATRIX)


@pytest.mark.parametrize("R,C", _mesh_params())
@pytest.mark.parametrize("executor", SHARD_EXECUTORS)
def test_sharded_matvec_matches_oracle_per_mesh(executor, R, C, tiny_problem,
                                                tiny_dense, rng):
    """DSC and WC of every sharded executor agree with the dense oracle on
    every admissible mesh topology."""
    p = tiny_problem
    fmt = REGISTRY.consumes(executor)
    cfg = dataclasses.replace(_CFG, executor=executor, format=fmt,
                              shard_rows=R, shard_cols=C)
    ex = (REGISTRY.create(executor, p.phi, p, cfg, PlanCache(""))
          if fmt == "coo" else create_for_format(p.phi, p, cfg, PlanCache("")))
    assert ex.name == executor
    m = np.asarray(tiny_dense, np.float64)
    n_theta = p.dictionary.shape[1]
    w = jnp.asarray(rng.uniform(0, 1, p.phi.n_fibers), jnp.float32)
    y = jnp.asarray(rng.normal(size=(p.phi.n_voxels, n_theta)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ex.matvec(w), np.float64).reshape(-1),
        m @ np.asarray(w, np.float64), rtol=2e-4, atol=1e-5,
        err_msg=f"{executor} ({R},{C}) matvec")
    np.testing.assert_allclose(
        np.asarray(ex.rmatvec(y), np.float64),
        m.T @ np.asarray(y, np.float64).reshape(-1), rtol=2e-4, atol=1e-4,
        err_msg=f"{executor} ({R},{C}) rmatvec")


@pytest.mark.slow
def test_sharded_conformance_on_8_forced_devices(tmp_path):
    """The full sharded contract under XLA_FLAGS-forced 8 CPU devices:
    both executors x (1, 2, 8)-device meshes vs the dense oracle
    (atol=1e-5) and cross-executor SBBNNLS trajectories vs naive."""
    code = """
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        assert len(jax.devices()) == 8, jax.devices()
        from repro.data.dmri import synth_connectome
        from repro.core.std import materialize_dense
        from repro.core.life import LifeConfig, LifeEngine
        p = synth_connectome(n_fibers=64, n_theta=16, n_atoms=24,
                             grid=(10, 10, 10), seed=1)
        m = np.asarray(materialize_dense(p.phi, p.dictionary), np.float64)
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.uniform(0, 1, p.phi.n_fibers), jnp.float32)
        y = jnp.asarray(rng.normal(size=(p.phi.n_voxels, 16)), jnp.float32)
        base = LifeConfig(executor="opt", plan_cache_dir="", slot_tile=16,
                          row_tile=8, n_iters=8)
        w_ref, l_ref = LifeEngine(
            p, dataclasses.replace(base, executor="naive")).run()
        for R, C in ((1, 1), (2, 1), (4, 2)):
            for name, fmt in (("shard", "coo"), ("shard-sell", "sell")):
                cfg = dataclasses.replace(base, executor=name, format=fmt,
                                          shard_rows=R, shard_cols=C)
                eng = LifeEngine(p, cfg)
                np.testing.assert_allclose(
                    np.asarray(eng.matvec(w), np.float64).reshape(-1),
                    m @ np.asarray(w, np.float64), rtol=2e-4, atol=1e-5,
                    err_msg=f"{name} ({R},{C}) matvec")
                np.testing.assert_allclose(
                    np.asarray(eng.rmatvec(y), np.float64),
                    m.T @ np.asarray(y, np.float64).reshape(-1),
                    rtol=2e-4, atol=1e-4, err_msg=f"{name} ({R},{C}) rmatvec")
                ww, ll = eng.run()
                np.testing.assert_allclose(ll, l_ref, rtol=2e-3,
                                           err_msg=f"{name} ({R},{C}) losses")
                np.testing.assert_allclose(
                    np.asarray(ww), np.asarray(w_ref), rtol=2e-2, atol=2e-3,
                    err_msg=f"{name} ({R},{C}) weights")
        print("SHARD_CONFORM_OK")
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"),
               REPRO_PLAN_CACHE=str(tmp_path / "plans"))
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARD_CONFORM_OK" in proc.stdout


def test_invalid_pairs_are_rejected():
    """A format request never silently runs on a mismatched executor:
    non-COO formats force their own executor through create_for_format."""
    from repro.formats import select as fsel
    assert fsel.executor_for("sell", _CFG) == "kernel-sell"
    assert fsel.executor_for("alto", _CFG) == "alto"
    assert fsel.executor_for("fcoo", _CFG) == "kernel-fcoo"
    # COO defers to the configured executor
    assert fsel.executor_for("coo", _CFG) == _CFG.executor
    with pytest.raises(ValueError):
        fsel.executor_for("csr", _CFG)
    # a configured executor that itself consumes the format wins
    assert fsel.executor_for(
        "sell", dataclasses.replace(_CFG, executor="shard-sell")) \
        == "shard-sell"
    # a multi-cell mesh request maps to the format's mesh executor
    mesh_cfg = dataclasses.replace(_CFG, shard_rows=2, shard_cols=2)
    assert fsel.executor_for("coo", mesh_cfg) == "shard"
    assert fsel.executor_for("sell", mesh_cfg) == "shard-sell"
    # alto/fcoo have no sharded path: the mapping falls through, and
    # create_for_format refuses rather than silently dropping the mesh
    assert fsel.executor_for("alto", mesh_cfg) == "alto"
    assert fsel.executor_for("fcoo", mesh_cfg) == "kernel-fcoo"
