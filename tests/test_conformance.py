"""Conformance matrix: every executor x format pair vs the naive oracle.

Per-subsystem suites (test_kernels, test_formats) validate each code version
against its own reference; this matrix is the cross-cutting contract — every
pair the registry declares valid (``REGISTRY.consumes``) must produce the
same matvec/rmatvec as the dense oracle, and full SBBNNLS trajectories must
agree across executors.  A new executor or format is covered the moment it
registers: the parametrization is derived from the registries at import
time, so drift between subsystems fails here even when each subsystem's own
tests pass.

This is the contract new executors/formats must pass (README "Serving").
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.life import LifeConfig, LifeEngine
from repro.core.plan_cache import PlanCache
from repro.core.registry import REGISTRY, create_for_format
from repro.formats import format_names

#: every (executor, format) pair registered at head — REGISTRY.consumes is
#: the single source of truth, so this list grows with the registries
MATRIX = [(ex, fmt) for fmt in format_names()
          for ex in REGISTRY.executors_for_format(fmt)]

_CFG = LifeConfig(executor="opt", c_tile=64, row_tile=8, slot_tile=16,
                  plan_cache_dir="")


def _make_executor(name, fmt, problem):
    cfg = dataclasses.replace(_CFG, executor=name, format=fmt)
    if fmt == "coo":
        return REGISTRY.create(name, problem.phi, problem, cfg, PlanCache(""))
    return create_for_format(problem.phi, problem, cfg, PlanCache(""))


def test_matrix_covers_whole_registry():
    """Every registered executor appears in exactly one format row."""
    assert sorted(ex for ex, _ in MATRIX) == sorted(REGISTRY.names())
    assert {fmt for _, fmt in MATRIX} == set(format_names())


@pytest.mark.parametrize("executor,fmt", MATRIX)
def test_matvec_rmatvec_match_oracle(executor, fmt, tiny_problem,
                                     tiny_dense, rng):
    """DSC and WC of every pair agree with the dense oracle."""
    p = tiny_problem
    ex = _make_executor(executor, fmt, p)
    m = np.asarray(tiny_dense, np.float64)          # (Nv*Ntheta, Nf)
    n_theta = p.dictionary.shape[1]

    w = jnp.asarray(rng.uniform(0, 1, p.phi.n_fibers), jnp.float32)
    y = jnp.asarray(rng.normal(size=(p.phi.n_voxels, n_theta)), jnp.float32)

    got_mv = np.asarray(ex.matvec(w), np.float64).reshape(-1)
    want_mv = m @ np.asarray(w, np.float64)
    np.testing.assert_allclose(got_mv, want_mv, rtol=2e-4, atol=2e-5,
                               err_msg=f"{executor}/{fmt} matvec")

    got_rmv = np.asarray(ex.rmatvec(y), np.float64)
    want_rmv = m.T @ np.asarray(y, np.float64).reshape(-1)
    np.testing.assert_allclose(got_rmv, want_rmv, rtol=2e-4, atol=2e-5,
                               err_msg=f"{executor}/{fmt} rmatvec")


@pytest.mark.parametrize("executor,fmt", MATRIX)
def test_sbbnnls_trajectories_match(executor, fmt, tiny_problem):
    """Full solver trajectories agree across every executor x format pair
    (the oracle is the naive scatter executor on canonical COO)."""
    p = tiny_problem
    base = LifeEngine(p, dataclasses.replace(_CFG, executor="naive",
                                             n_iters=8))
    w_ref, l_ref = base.run()

    cfg = dataclasses.replace(_CFG, executor=executor, format=fmt, n_iters=8)
    w, losses = LifeEngine(p, cfg).run()
    np.testing.assert_allclose(losses, l_ref, rtol=2e-3,
                               err_msg=f"{executor}/{fmt} losses")
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref), rtol=2e-2,
                               atol=2e-3, err_msg=f"{executor}/{fmt} weights")


def test_invalid_pairs_are_rejected():
    """A format request never silently runs on a mismatched executor:
    non-COO formats force their own executor through create_for_format."""
    from repro.formats import select as fsel
    assert fsel.executor_for("sell", _CFG) == "kernel-sell"
    assert fsel.executor_for("alto", _CFG) == "alto"
    # COO defers to the configured executor
    assert fsel.executor_for("coo", _CFG) == _CFG.executor
    with pytest.raises(ValueError):
        fsel.executor_for("csr", _CFG)
