"""Mamba2 SSD: chunked scan vs sequential recurrence, decode continuity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import (init_mamba2, mamba2_decode, mamba2_prefill,
                                 ssd_chunked)


def _oracle(x, dt, a, b, c):
    """Sequential SSD recurrence in numpy."""
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    bh = np.repeat(np.asarray(b), rep, axis=2)
    ch = np.repeat(np.asarray(c), rep, axis=2)
    h = np.zeros((B, H, P, N))
    ys = np.zeros((B, S, H, P))
    for t in range(S):
        decay = np.exp(np.asarray(dt)[:, t] * np.asarray(a)[None, :])
        h = h * decay[..., None, None] + np.einsum(
            "bh,bhp,bhn->bhpn", np.asarray(dt)[:, t], np.asarray(x)[:, t],
            bh[:, t])
        ys[:, t] = np.einsum("bhpn,bhn->bhp", h, ch[:, t])
    return ys, h


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
@pytest.mark.parametrize("G", [1, 2])
def test_ssd_chunked_matches_recurrence(rng, chunk, G):
    B, S, H, P, N = 2, 32, 4, 8, 6
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(B, S, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    ys, h = _oracle(x, dt, a, b, c)
    y, hl = ssd_chunked(x, dt, a, b, c, chunk)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(hl), h, rtol=3e-4, atol=3e-4)


def test_prefill_then_decode_matches_full(rng):
    d_model, d_state, hd = 16, 6, 4
    kw = dict(d_state=d_state, head_dim=hd, expand=2)
    p = init_mamba2(jax.random.PRNGKey(0), d_model, d_state=d_state,
                    head_dim=hd, expand=2, dtype=jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 12, d_model)), jnp.float32)
    y_full, h_full, cs_full = mamba2_prefill(p, x, chunk=4, **kw)
    y_pre, h, cs = mamba2_prefill(p, x[:, :8], chunk=4, **kw)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :8]),
                               rtol=1e-4, atol=1e-4)
    for t in range(8, 12):
        y_t, h, cs = mamba2_decode(p, x[:, t:t + 1], h, cs, **kw)
        np.testing.assert_allclose(np.asarray(y_t[:, 0]),
                                   np.asarray(y_full[:, t]),
                                   rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(cs), np.asarray(cs_full),
                               rtol=5e-4, atol=5e-4)


def test_bf16_output_dtype_stable(rng):
    """Regression: d_skip/f32 internals must not promote the layer output
    (broke the scanned-carry dtype on the full bf16 configs)."""
    p = init_mamba2(jax.random.PRNGKey(0), 16, d_state=4, head_dim=4,
                    expand=2, dtype=jnp.bfloat16)
    x = jnp.asarray(rng.normal(size=(1, 8, 16)), jnp.bfloat16)
    y, h, cs = mamba2_prefill(p, x, d_state=4, head_dim=4, expand=2, chunk=4)
    assert y.dtype == jnp.bfloat16
    y2, h2, cs2 = mamba2_decode(p, x[:, :1], h, cs, d_state=4, head_dim=4,
                                expand=2)
    assert y2.dtype == jnp.bfloat16
