import os
import sys

# NOTE: no XLA_FLAGS here — tests run on the single real device; only
# launch/dryrun.py forces 512 placeholder devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:                                   # declared dev dependency; containers
    import hypothesis                  # without it fall back to the
except ImportError:                    # deterministic stub
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub
    _hypothesis_stub.install(sys.modules)

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)

# CI runs the fast lane under two values of $REPRO_TEST_SEED to flush
# seed-dependent flakiness; fixtures offset their PRNG seeds by it.
TEST_SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))


@pytest.fixture(autouse=True)
def _obs_disabled_and_clean():
    """Observability starts disabled and empty for every test — a test
    that enables it (tests/test_obs.py, serving counter checks) cannot
    leak instrument state or the enabled switch into the next test."""
    from repro import obs
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


@pytest.fixture(autouse=True)
def _isolated_plan_cache(tmp_path, monkeypatch):
    """Route all plan caching to a per-test tmpdir and clear the in-process
    autotune memo, so no test's outcome depends on suite ordering or on a
    warm on-disk cache left by an earlier run (or by the developer's own
    engines writing to ~/.cache)."""
    from repro.core import restructure
    from repro.learn import clear_load_memo, refine
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plan-cache"))
    monkeypatch.delenv("REPRO_PLAN_CACHE_MAX_BYTES", raising=False)
    restructure.clear_plan_cache()
    refine.QUEUE.clear()
    clear_load_memo()
    yield
    restructure.clear_plan_cache()
    refine.QUEUE.clear()
    clear_load_memo()


@pytest.fixture(scope="session")
def tiny_problem():
    from repro.data.dmri import synth_connectome
    return synth_connectome(n_fibers=64, n_theta=16, n_atoms=24,
                            grid=(10, 10, 10), seed=1 + TEST_SEED)


@pytest.fixture(scope="session")
def tiny_dense(tiny_problem):
    from repro.core.std import materialize_dense
    return materialize_dense(tiny_problem.phi, tiny_problem.dictionary)


@pytest.fixture()
def rng():
    return np.random.default_rng(TEST_SEED)


@pytest.fixture(scope="session")
def tiny_cohort():
    """Three small subjects sharing one acquisition (serving fixtures)."""
    from repro.data.dmri import synth_cohort
    return synth_cohort(3, base_seed=10 + TEST_SEED, n_fibers=64, n_theta=16,
                        n_atoms=24, grid=(10, 10, 10))
