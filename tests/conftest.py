import os
import sys

# NOTE: no XLA_FLAGS here — tests run on the single real device; only
# launch/dryrun.py forces 512 placeholder devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:                                   # declared dev dependency; containers
    import hypothesis                  # without it fall back to the
except ImportError:                    # deterministic stub
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub
    _hypothesis_stub.install(sys.modules)

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def tiny_problem():
    from repro.data.dmri import synth_connectome
    return synth_connectome(n_fibers=64, n_theta=16, n_atoms=24,
                            grid=(10, 10, 10), seed=1)


@pytest.fixture(scope="session")
def tiny_dense(tiny_problem):
    from repro.core.std import materialize_dense
    return materialize_dense(tiny_problem.phi, tiny_problem.dictionary)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
