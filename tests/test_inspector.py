"""Inspector invariants: tile plans and shard boundaries (hypothesis)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.inspector import plan_tiles, shard_boundaries


@st.composite
def sorted_ids(draw):
    n = draw(st.integers(0, 500))
    n_rows = draw(st.integers(1, 64))
    seed = draw(st.integers(0, 2**31 - 1))
    r = np.random.default_rng(seed)
    return np.sort(r.integers(0, n_rows, n)), n_rows


@settings(max_examples=50, deadline=None)
@given(sorted_ids(), st.sampled_from([8, 32, 128]), st.sampled_from([4, 8, 16]))
def test_plan_tiles_invariants(case, c_tile, row_tile):
    ids, n_rows = case
    plan = plan_tiles(ids, n_rows, c_tile=c_tile, row_tile=row_tile)
    nc = ids.size
    sel = plan.sel.reshape(plan.n_tiles, plan.c_tile)
    # 1. coverage: every coefficient appears exactly once
    real = sel[sel < nc]
    assert sorted(real.tolist()) == list(range(nc))
    # 2. tiles hold <= c_tile real coefficients
    assert ((sel < nc).sum(axis=1) <= c_tile).all()
    # 3. single row-block per tile + local rows in range
    lr = plan.local_row.reshape(plan.n_tiles, plan.c_tile)
    for t in range(plan.n_tiles):
        mask = sel[t] < nc
        if not mask.any():
            continue
        rows = ids[sel[t][mask]]
        blocks = rows // row_tile
        assert (blocks == plan.row_block[t]).all(), "tile crosses row-block"
        assert (lr[t][mask] == rows - plan.row_block[t] * row_tile).all()
    # 4. row_block monotone non-decreasing (sequential-grid accumulation)
    assert (np.diff(plan.row_block) >= 0).all()
    # 5. padded row count covers all rows
    assert plan.n_rows_padded >= n_rows


@settings(max_examples=50, deadline=None)
@given(sorted_ids(), st.integers(1, 16))
def test_shard_boundaries_invariants(case, n_shards):
    ids, _ = case
    cuts = shard_boundaries(ids, n_shards)
    nc = ids.size
    # monotone, full coverage
    assert cuts[0] == 0 and cuts[-1] == nc
    assert (np.diff(cuts) >= 0).all()
    # snapped: no sub-vector (run of equal ids) crosses a boundary
    for c in cuts[1:-1]:
        if 0 < c < nc:
            assert ids[c - 1] != ids[c], "cut splits a sub-vector"


@settings(max_examples=30, deadline=None)
@given(sorted_ids(), st.integers(1, 12))
def test_shard_boundaries_disjoint_and_covering(case, n_shards):
    """The coefficient ranges partition [0, Nc): pairwise disjoint, their
    union is everything, and every coefficient lands in exactly one shard
    (the set-level statement of the §4.1.3 partition contract)."""
    ids, _ = case
    cuts = shard_boundaries(ids, n_shards)
    ranges = [np.arange(cuts[i], cuts[i + 1]) for i in range(n_shards)]
    assert sum(r.size for r in ranges) == ids.size
    seen = np.concatenate(ranges) if ranges else np.zeros(0, np.int64)
    np.testing.assert_array_equal(seen, np.arange(ids.size))


@settings(max_examples=30, deadline=None)
@given(sorted_ids(), st.integers(2, 8))
def test_shard_boundaries_balance(case, n_shards):
    """Equal-nnz up to sub-vector granularity: no shard exceeds the ideal
    share by more than the largest sub-vector."""
    ids, _ = case
    if ids.size == 0:
        return
    cuts = shard_boundaries(ids, n_shards)
    _, counts = np.unique(ids, return_counts=True)
    largest_run = counts.max()
    ideal = ids.size / n_shards
    assert (np.diff(cuts) <= ideal + largest_run).all()


@settings(max_examples=25, deadline=None)
@given(sorted_ids())
def test_auto_tile_valid_geometry(case):
    from repro.core.inspector import auto_tile
    ids, n_rows = case
    if ids.size < 8:
        return
    c, r = auto_tile(ids, n_rows)
    assert 32 <= c <= 512 and r >= 1
    plan = plan_tiles(ids, n_rows, c_tile=c, row_tile=r)   # must plan cleanly
    assert plan.n_tiles >= 1


def test_auto_tile_occupancy_on_uniform_density():
    """On uniform-density data (the tractography regime) the chosen geometry
    keeps tiles reasonably full — skewed adversarial distributions are
    exempt (occupancy there is bounded by the data, not the geometry)."""
    from repro.core.inspector import auto_tile
    r = np.random.default_rng(0)
    ids = np.sort(r.integers(0, 500, 6000))        # ~12 nnz/row
    c, rt = auto_tile(ids, 500)
    plan = plan_tiles(ids, 500, c_tile=c, row_tile=rt)
    assert plan.occupancy() >= 0.3


def test_occupancy_exactly_full_tile_is_one():
    """Regression: an exactly-full tile (nc a multiple of c_tile, zero
    padding) must report occupancy 1.0.  The old implementation compared
    ``sel`` against ``sel.max()`` — miscounting the slot holding the
    largest real coefficient index as padding — and reported (nc-1)/nc."""
    ids = np.zeros(32, np.int64)              # 32 coefficients, one row block
    plan = plan_tiles(ids, 8, c_tile=32, row_tile=8)
    assert plan.sel.size == 32                # a single tile, no pad slots
    assert plan.occupancy() == 1.0
