"""BatchedLifeEngine: cohort results must match per-subject engines."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batched import BatchedLifeEngine, _pad_sorted
from repro.core.life import LifeConfig, LifeEngine
from repro.core.registry import REGISTRY
from repro.core.restructure import sort_by_host
from repro.data.dmri import synth_cohort


@pytest.fixture(scope="module")
def cohort():
    return synth_cohort(3, base_seed=10, n_fibers=64, n_theta=16,
                        n_atoms=24, grid=(10, 10, 10))


@pytest.mark.parametrize("executor", ["naive", "opt", "opt-paper"])
def test_batched_matches_per_subject(cohort, executor):
    cfg = LifeConfig(executor=executor, n_iters=12, plan_cache_dir="")
    beng = BatchedLifeEngine(cohort, cfg)
    W, losses = beng.run()
    assert W.shape == (3, cohort[0].phi.n_fibers)
    assert losses.shape == (3, 12)
    for s, p in enumerate(cohort):
        w_ref, l_ref = LifeEngine(p, cfg).run()
        np.testing.assert_allclose(np.asarray(W[s]), np.asarray(w_ref),
                                   rtol=1e-5, atol=1e-6,
                                   err_msg=f"{executor} subject {s}")
        np.testing.assert_allclose(losses[s], l_ref, rtol=1e-5)


def test_batched_auto_uses_one_tuned_recipe(cohort, tmp_path):
    cfg = LifeConfig(executor="auto", n_iters=10,
                     plan_cache_dir=str(tmp_path))
    beng = BatchedLifeEngine(cohort, cfg)
    W, _ = beng.run()
    # auto tunes on subject 0 through the persistent cache
    assert beng.cache.stats.misses == 2
    # per-subject results still close to the reference executor
    ref_cfg = LifeConfig(executor="opt", n_iters=10, plan_cache_dir="")
    for s, p in enumerate(cohort):
        w_ref, _ = LifeEngine(p, ref_cfg).run()
        np.testing.assert_allclose(np.asarray(W[s]), np.asarray(w_ref),
                                   rtol=1e-3, atol=1e-4)


def test_padding_is_inert():
    """A padded subject must produce bit-comparable results to unpadded."""
    from repro.core import spmv
    [p] = synth_cohort(1, base_seed=3, n_fibers=32, n_theta=8, n_atoms=12,
                       grid=(8, 8, 8))
    phi_v, _ = sort_by_host(p.phi, "voxel")
    padded = _pad_sorted(phi_v, phi_v.n_coeffs + 37, "voxel", True)
    assert padded.n_coeffs == phi_v.n_coeffs + 37
    assert not np.any(np.diff(np.asarray(padded.voxels)) < 0)  # still sorted
    w = jnp.asarray(np.random.default_rng(0).uniform(size=32), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(spmv.dsc(padded, p.dictionary, w)),
        np.asarray(spmv.dsc(phi_v, p.dictionary, w)),
        rtol=1e-6, atol=1e-7)


def test_rejects_non_vmappable_executor(cohort):
    for executor in ("kernel", "shard"):
        with pytest.raises(ValueError, match="not vmappable"):
            BatchedLifeEngine(
                cohort, LifeConfig(executor=executor, plan_cache_dir=""))


def test_rejects_mismatched_geometry(cohort):
    small = synth_cohort(1, base_seed=99, n_fibers=32, n_theta=16,
                         n_atoms=24, grid=(10, 10, 10))
    with pytest.raises(ValueError, match="geometry"):
        BatchedLifeEngine(cohort + small, LifeConfig(plan_cache_dir=""))


def test_registry_names_cover_ladder():
    for name in ("naive", "opt", "opt-paper", "kernel", "auto", "shard"):
        assert name in REGISTRY
    with pytest.raises(ValueError, match="executor must be one of"):
        REGISTRY.create("nope", None, None, None)


# ----------------------------------------------------------------------------
# mesh placement (DESIGN.md §9.4): subjects over `data`, Phi slots over
# `model`.  The multi-device variant executes in the CI multi-device lane
# (8 forced host devices); on one device it validates the error surface.
# ----------------------------------------------------------------------------

def test_batched_mesh_rejects_oversized_mesh(cohort):
    import jax
    n = len(jax.devices())
    cfg = LifeConfig(executor="opt", n_iters=4, plan_cache_dir="",
                     shard_rows=n + 1, shard_cols=2)
    with pytest.raises(ValueError, match="devices"):
        BatchedLifeEngine(cohort, cfg)


def _mesh_skip(n_needed):
    import jax
    return pytest.mark.skipif(
        len(jax.devices()) < n_needed,
        reason=f"needs {n_needed} devices")


@pytest.mark.parametrize("R,C", [
    pytest.param(2, 2, marks=_mesh_skip(4)),
    pytest.param(4, 2, marks=_mesh_skip(8)),
])
def test_batched_mesh_placement_matches_unplaced(cohort, R, C):
    """Device-placing the stacked cohort (subjects x slots over the mesh)
    never changes results — GSPMD repartitions, the math is identical."""
    base = LifeConfig(executor="opt", n_iters=10, plan_cache_dir="")
    W0, L0 = BatchedLifeEngine(cohort, base).run()
    import dataclasses
    eng = BatchedLifeEngine(
        cohort, dataclasses.replace(base, shard_rows=R, shard_cols=C))
    assert eng.mesh is not None
    W1, L1 = eng.run()
    np.testing.assert_allclose(np.asarray(W1), np.asarray(W0),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(L1, L0, rtol=1e-4)
