"""HLO cost model: loop multipliers, flops and bytes vs XLA ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo_cost
from repro.roofline.analysis import collective_bytes, model_flops, roofline


def _rms(x):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)


def _body(x, w):
    return x + _rms(x) @ w, None


def test_scan_flops_corrected():
    """cost_analysis counts a while body once; the cost model multiplies by
    the trip count (the whole reason this module exists)."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)

    def scanned(x, ws):
        y, _ = jax.lax.scan(_body, x, ws)
        return y

    c = jax.jit(scanned).lower(x, ws).compile()
    xla = hlo_cost.xla_cost_analysis(c)["flops"]
    hc = hlo_cost.analyze(c.as_text(), 1)
    expected = 8 * 2 * 128 ** 3
    assert xla < expected / 4                   # XLA undercounts
    np.testing.assert_allclose(hc.flops, expected, rtol=0.02)
    assert any(v == 8.0 for v in hc.loops.values())


def test_matches_xla_on_unrolled_grad():
    """On an unrolled model (no while) both flops and bytes must agree with
    XLA's own cost analysis."""
    x = jax.ShapeDtypeStruct((64, 256), jnp.bfloat16)
    ws = jax.ShapeDtypeStruct((6, 256, 256), jnp.bfloat16)

    def loss(x, ws):
        y, _ = jax.lax.scan(jax.checkpoint(_body), x, ws, unroll=6)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    c = jax.jit(jax.grad(loss, argnums=(0, 1))).lower(x, ws).compile()
    ca = hlo_cost.xla_cost_analysis(c)
    hc = hlo_cost.analyze(c.as_text(), 1)
    assert 0.8 <= hc.flops / ca["flops"] <= 1.05       # dots only
    np.testing.assert_allclose(hc.bytes_accessed_xla, ca["bytes accessed"],
                               rtol=0.05)
    # the HBM approximation only ever discounts the visitor accounting
    assert hc.bytes_accessed <= hc.bytes_accessed_xla


def test_scan_equals_unrolled_through_cost_model():
    """The corrected scan cost must equal the unrolled XLA cost."""
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)

    def f_scan(x, ws):
        return jax.lax.scan(_body, x, ws)[0].sum()

    def f_unroll(x, ws):
        return jax.lax.scan(_body, x, ws, unroll=5)[0].sum()

    c_scan = jax.jit(jax.grad(f_scan, argnums=(0, 1))).lower(x, ws).compile()
    c_un = jax.jit(jax.grad(f_unroll, argnums=(0, 1))).lower(x, ws).compile()
    hc = hlo_cost.analyze(c_scan.as_text(), 1)
    xla_unrolled = hlo_cost.xla_cost_analysis(c_un)["flops"]
    np.testing.assert_allclose(hc.flops, xla_unrolled, rtol=0.15)


@pytest.mark.slow        # 8-device subprocess + fresh compile
def test_collective_parse_on_psum():
    """Collectives inside an 8-step scan are multiplied by the trip count."""
    import subprocess, sys, os, textwrap, json
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, json
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.roofline import hlo_cost
        mesh = compat.make_mesh((8,), ("d",))
        def body(x, w):
            y = jax.lax.psum(x @ w, "d")          # (16, 64) all-reduce
            i = jax.lax.axis_index("d")
            return jax.lax.dynamic_slice(y, (0, i * 8), (16, 8)), None
        def f(x, ws):
            return jax.lax.scan(body, x, ws)[0]
        sm = compat.shard_map(f, mesh=mesh,
                              in_specs=(P(None, "d"), P(None, "d", None)),
                              out_specs=P(None, None))
        x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
        with mesh:
            c = jax.jit(sm).lower(x, ws).compile()
        hc = hlo_cost.analyze(c.as_text(), 8)
        print(json.dumps({"ar": hc.collective["all-reduce"]}))
    """ % os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    ar = json.loads(proc.stdout.strip().splitlines()[-1])["ar"]
    # 8 iterations x all-reduce of (16, 64) f32 = 4096 B result each,
    # ring model: 2 * 4096 * 7/8 -> x8 steps
    expected = 8 * 2 * (16 * 64 * 4) * 7 / 8
    np.testing.assert_allclose(ar, expected, rtol=0.3)


def test_roofline_terms_and_dominance():
    r = roofline(flops_per_chip=1.97e14, bytes_per_chip=819e9,
                 coll_bytes_per_chip=100e9, n_chips=4,
                 model_flops_global=4 * 1.97e14 * 0.5)
    assert np.isclose(r.compute_s, 1.0)
    assert np.isclose(r.memory_s, 1.0)
    assert np.isclose(r.collective_s, 2.0)
    assert r.dominant == "collective"
    assert np.isclose(r.useful_ratio, 0.5)
