"""Sparse-format subsystem: round-trips, SELL kernels vs oracle, selection.

Property tests run through the hypothesis stub when the real package is
missing (tests/_hypothesis_stub.py), so they execute everywhere.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import spmv
from repro.core.inspector import phi_stats
from repro.core.restructure import compact_by_weight
from repro.core.std import PhiTensor, make_dictionary, materialize_dense
from repro.formats import (AltoPhi, CooPhi, SellPhi, canonical_triples,
                           format_names, get_format)
from repro.formats import select as fsel
from repro.formats.base import FormatPlan
from repro.formats.sell import dsc_reference, wc_reference


@st.composite
def coo(draw):
    nc = draw(st.integers(0, 300))
    na = draw(st.integers(1, 16))
    nv = draw(st.integers(1, 40))
    nf = draw(st.integers(1, 30))
    seed = draw(st.integers(0, 2**31 - 1))
    r = np.random.default_rng(seed)
    return PhiTensor(
        atoms=jnp.asarray(r.integers(0, na, nc), jnp.int32),
        voxels=jnp.asarray(r.integers(0, nv, nc), jnp.int32),
        fibers=jnp.asarray(r.integers(0, nf, nc), jnp.int32),
        values=jnp.asarray(r.normal(size=nc), jnp.float32),
        n_atoms=na, n_voxels=nv, n_fibers=nf), seed


def _assert_same_triples(got: PhiTensor, want: PhiTensor):
    for g, w in zip(canonical_triples(got), canonical_triples(want)):
        np.testing.assert_array_equal(g, w)


# ----------------------------------------------------------------------------
# Round-trips: every format reproduces the COO triples/values exactly
# ----------------------------------------------------------------------------

def test_registry_lists_formats():
    assert format_names() == ("alto", "coo", "fcoo", "sell")
    assert get_format("sell") is SellPhi
    with pytest.raises(ValueError):
        get_format("csr")


@settings(max_examples=15, deadline=None)
@given(coo(), st.sampled_from(["dsc", "wc"]))
def test_property_roundtrip_all_formats(case, op):
    phi, _ = case
    for name in format_names():
        enc = get_format(name).encode(phi, op=op)
        _assert_same_triples(enc.decode(), phi)
        assert enc.padding_overhead >= 0.0
        assert enc.nbytes > 0 or phi.n_coeffs == 0


def test_coo_roundtrip_preserves_order(tiny_problem):
    enc = CooPhi.encode(tiny_problem.phi, op="dsc")
    dec = enc.decode()
    np.testing.assert_array_equal(np.asarray(dec.atoms),
                                  np.asarray(tiny_problem.phi.atoms))
    np.testing.assert_array_equal(np.asarray(dec.values),
                                  np.asarray(tiny_problem.phi.values))


def test_alto_sort_and_compact(tiny_problem):
    enc = AltoPhi.encode(tiny_problem.phi)
    srt, order = enc.sort()
    assert np.all(np.diff(srt.lin.astype(np.uint64)) >= 0)
    _assert_same_triples(srt.decode(), tiny_problem.phi)
    np.testing.assert_array_equal(srt.fibers_of(),
                                  np.asarray(srt.decode().fibers))
    # compaction via the linearized fiber view == compact_by_weight
    w = np.zeros(tiny_problem.phi.n_fibers, np.float32)
    w[: len(w) // 3] = 1.0
    kept_enc = enc.compact(w[enc.fibers_of()] > 0)
    want = compact_by_weight(tiny_problem.phi, jnp.asarray(w))
    _assert_same_triples(kept_enc.decode(), want)


def test_alto_bit_budget_guard():
    phi = PhiTensor(atoms=jnp.zeros(1, jnp.int32), voxels=jnp.zeros(1, jnp.int32),
                    fibers=jnp.zeros(1, jnp.int32), values=jnp.ones(1),
                    n_atoms=2**30, n_voxels=2**30, n_fibers=2**30)
    with pytest.raises(ValueError, match="bits"):
        AltoPhi.encode(phi)


# ----------------------------------------------------------------------------
# compact_by_weight + formats: executors agree with the dense oracle
# ----------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(coo())
def test_property_compaction_preserves_dsc(case):
    """Dropping zero-weight fibers' coefficients never changes y = M w."""
    phi, seed = case
    r = np.random.default_rng(seed + 11)
    d = make_dictionary(phi.n_atoms, 8)
    w = r.uniform(size=phi.n_fibers).astype(np.float32)
    w[r.uniform(size=phi.n_fibers) < 0.5] = 0.0
    compacted = compact_by_weight(phi, w)
    np.testing.assert_allclose(
        np.asarray(spmv.dsc_naive(compacted, d, jnp.asarray(w))),
        np.asarray(spmv.dsc_naive(phi, d, jnp.asarray(w))),
        rtol=1e-4, atol=1e-5)
    # and every format round-trips the compacted tensor too
    for name in format_names():
        _assert_same_triples(get_format(name).encode(compacted).decode(),
                             compacted)


@settings(max_examples=10, deadline=None)
@given(coo())
def test_property_sell_references_match_dense(case):
    phi, seed = case
    r = np.random.default_rng(seed + 5)
    d = make_dictionary(phi.n_atoms, 8)
    w = jnp.asarray(r.uniform(size=phi.n_fibers), jnp.float32)
    y = jnp.asarray(r.normal(size=(phi.n_voxels, 8)), jnp.float32)
    m = materialize_dense(phi, d)
    got_y = dsc_reference(SellPhi.encode(phi, op="dsc"), d, w)
    got_w = wc_reference(SellPhi.encode(phi, op="wc"), d, y)
    np.testing.assert_allclose(np.asarray(got_y).reshape(-1),
                               np.asarray(m @ w), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(got_w),
                               np.asarray(m.T @ y.reshape(-1)),
                               rtol=1e-3, atol=1e-3)


def test_sell_kernel_executor_matches_dense(tiny_problem, tiny_dense, rng):
    """The SELL-backed Pallas executor (interpret) vs the dense oracle."""
    from repro.kernels import ops as kops
    p = tiny_problem
    w = jnp.asarray(rng.uniform(size=p.phi.n_fibers), jnp.float32)
    mv = kops.make_dsc_sell(SellPhi.encode(p.phi, op="dsc"), p.dictionary,
                            interpret=True)
    np.testing.assert_allclose(
        np.asarray(mv(w)).reshape(-1), np.asarray(tiny_dense @ w),
        rtol=2e-4, atol=2e-4)
    y = jnp.asarray(rng.normal(size=(p.phi.n_voxels, 16)), jnp.float32)
    rv = kops.make_wc_sell(SellPhi.encode(p.phi, op="wc"), p.dictionary,
                           interpret=True)
    np.testing.assert_allclose(
        np.asarray(rv(y)), np.asarray(tiny_dense.T @ y.reshape(-1)),
        rtol=2e-4, atol=2e-4)


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(coo())
def test_property_sell_kernels(case):
    """Pallas SELL kernels (interpret) vs naive, random COO sweep."""
    from repro.kernels import ops as kops
    phi, seed = case
    r = np.random.default_rng(seed + 7)
    d = make_dictionary(phi.n_atoms, 8)
    w = jnp.asarray(r.uniform(size=phi.n_fibers), jnp.float32)
    y = jnp.asarray(r.normal(size=(phi.n_voxels, 8)), jnp.float32)
    mv = kops.make_dsc_sell(SellPhi.encode(phi, op="dsc"), d, interpret=True)
    rv = kops.make_wc_sell(SellPhi.encode(phi, op="wc"), d, interpret=True)
    np.testing.assert_allclose(np.asarray(mv(w)),
                               np.asarray(spmv.dsc_naive(phi, d, w)),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(rv(y)),
                               np.asarray(spmv.wc_naive(phi, d, y)),
                               rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------------------------
# Selection: heuristic, autotune fallback, FormatPlan caching
# ----------------------------------------------------------------------------

def _uniform_phi(nv=32, nf=32, na=8, per_row=32):
    """Every voxel and every fiber gets exactly per_row coefficients:
    SELL padding overhead ~0 on both ops."""
    nc = nv * per_row
    r = np.random.default_rng(3)
    return PhiTensor(
        atoms=jnp.asarray(r.integers(0, na, nc), jnp.int32),
        voxels=jnp.asarray(np.repeat(np.arange(nv), per_row), jnp.int32),
        fibers=jnp.asarray(np.tile(np.arange(nf), nc // nf), jnp.int32),
        values=jnp.asarray(r.normal(size=nc), jnp.float32),
        n_atoms=na, n_voxels=nv, n_fibers=nf)


def _skewed_phi(nv=64, nf=64, na=8):
    """One voxel and one fiber hoard most coefficients: SELL pads wildly."""
    r = np.random.default_rng(4)
    hot = 256
    cold = 64
    voxels = np.concatenate([np.zeros(hot, np.int64),
                             r.integers(1, nv, cold)])
    fibers = np.concatenate([np.zeros(hot, np.int64),
                             r.integers(1, nf, cold)])
    nc = hot + cold
    return PhiTensor(
        atoms=jnp.asarray(r.integers(0, na, nc), jnp.int32),
        voxels=jnp.asarray(voxels, jnp.int32),
        fibers=jnp.asarray(fibers, jnp.int32),
        values=jnp.asarray(r.normal(size=nc), jnp.float32),
        n_atoms=na, n_voxels=nv, n_fibers=nf)


@settings(max_examples=10, deadline=None)
@given(coo())
def test_property_predicted_sell_overhead_matches_encode(case):
    """The selector's O(Nc) overhead prediction must equal what
    SellPhi.encode actually allocates (shared sell_geometry)."""
    phi, _ = case
    stats = phi_stats(phi, row_tile=8, slot_tile=32)
    for op in ("dsc", "wc"):
        enc = SellPhi.encode(phi, op=op, row_tile=8, slot_tile=32)
        np.testing.assert_allclose(stats[f"{op}.sell_overhead"],
                                   enc.padding_overhead, rtol=1e-12)


def test_phi_stats_shapes(tiny_problem):
    s = phi_stats(tiny_problem.phi)
    for k in ("dsc.sell_overhead", "wc.sell_overhead", "dsc.run_mean",
              "wc.run_max", "nc_per_fiber"):
        assert k in s and np.isfinite(s[k])
    assert s["dsc.sell_overhead"] >= 0.0


def test_heuristic_picks_sell_on_uniform_rows():
    phi = _uniform_phi()
    d = make_dictionary(phi.n_atoms, 8)
    plan = fsel.choose_format(phi, d)
    assert plan.format == "sell" and plan.reason == "heuristic"
    assert plan.stats["dsc.sell_overhead"] <= fsel.DEFAULT_SELL_ACCEPT


def test_heuristic_rejects_sell_on_skew():
    phi = _skewed_phi()
    d = make_dictionary(phi.n_atoms, 8)
    # sell vs coo only: rejection leaves one candidate -> pure heuristic
    plan = fsel.choose_format(phi, d, allowed=("coo", "sell"))
    assert plan.format == "coo" and plan.reason == "heuristic"
    assert plan.stats["dsc.sell_overhead"] >= fsel.DEFAULT_SELL_REJECT
    # with alto/fcoo also in the running the survivors are measured, so
    # those candidates stay live — only sell is struck by the skew
    plan = fsel.choose_format(phi, d)
    assert plan.reason == "autotune"
    assert plan.format in ("coo", "alto", "fcoo")


def test_autotune_fallback_runs_in_ambiguous_zone(tiny_problem):
    d = tiny_problem.dictionary
    plan = fsel.choose_format(tiny_problem.phi, d, sell_accept=-1.0,
                              sell_reject=float("inf"))
    assert plan.reason == "autotune"
    assert plan.format in format_names()


def test_sell_only_candidate_set_survives_rejection():
    """An explicit allowed=("sell",) wins over the skew heuristic — and
    never crashes on an empty candidate set."""
    phi = _skewed_phi()
    d = make_dictionary(phi.n_atoms, 8)
    plan = fsel.choose_format(phi, d, allowed=("sell",))
    assert plan.format == "sell" and plan.reason == "heuristic"
    with pytest.raises(ValueError, match="at least one"):
        fsel.choose_format(phi, d, allowed=())


def test_threshold_change_misses_format_cache(tmp_path):
    """Different sell thresholds may choose differently -> different key."""
    from repro.core.plan_cache import PlanCache
    phi = _uniform_phi()
    d = make_dictionary(phi.n_atoms, 8)
    cache = PlanCache(str(tmp_path))
    p1 = fsel.choose_format(phi, d, cache=cache)
    assert p1.format == "sell"
    p2 = fsel.choose_format(phi, d, cache=cache, sell_accept=-1.0,
                            sell_reject=-0.5)
    assert p2.format != "sell"            # not served the stale choice
    assert cache.stats.misses == 2


def test_format_plan_cache_roundtrip(tmp_path):
    from repro.core.plan_cache import PlanCache, format_plan_key
    cache = PlanCache(str(tmp_path))
    key = format_plan_key(np.arange(5), np.arange(5), np.arange(5),
                          sizes=(8, 16, 8), row_tile=8, slot_tile=32,
                          allowed=("coo", "sell"))
    assert cache.get_format_plan(key) is None
    plan = FormatPlan("sell", "heuristic", dict(row_tile=8, slot_tile=32),
                      {"dsc.sell_overhead": 0.25})
    cache.put_format_plan(key, plan)
    got = cache.get_format_plan(key)
    assert (got.format, got.reason) == ("sell", "heuristic")
    assert got.params == plan.params
    assert got.stats == {"dsc.sell_overhead": 0.25}
    # candidate set is part of the key
    other = format_plan_key(np.arange(5), np.arange(5), np.arange(5),
                            sizes=(8, 16, 8), row_tile=8, slot_tile=32,
                            allowed=("coo",))
    assert other != key


# ----------------------------------------------------------------------------
# Engine integration
# ----------------------------------------------------------------------------

def test_engine_explicit_formats_match_oracle(tiny_problem, tiny_dense, rng):
    from repro.core.life import LifeConfig, LifeEngine
    w = jnp.asarray(rng.uniform(size=tiny_problem.phi.n_fibers), jnp.float32)
    y = jnp.asarray(rng.normal(size=(tiny_problem.phi.n_voxels, 16)),
                    jnp.float32)
    for fmt, exec_name in (("sell", "kernel-sell"), ("alto", "alto")):
        eng = LifeEngine(tiny_problem,
                         LifeConfig(format=fmt, plan_cache_dir=""))
        assert eng.executor.name == exec_name
        assert eng.format_plan.format == fmt
        np.testing.assert_allclose(
            np.asarray(eng.matvec(w)).reshape(-1),
            np.asarray(tiny_dense @ w), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(
            np.asarray(eng.rmatvec(y)),
            np.asarray(tiny_dense.T @ y.reshape(-1)), rtol=2e-4, atol=2e-4)


def test_engine_auto_format_warm_cache_skips_selection(tiny_problem,
                                                       tmp_path, monkeypatch):
    """Warm rebuild must load the FormatPlan, not re-run the selector."""
    from repro.core.life import LifeConfig, LifeEngine
    cfg = LifeConfig(format="auto", plan_cache_dir=str(tmp_path))
    eng1 = LifeEngine(tiny_problem, cfg)
    assert eng1.format_plan is not None

    def boom(*a, **k):
        raise AssertionError("selection re-ran despite cached FormatPlan")

    monkeypatch.setattr(fsel, "phi_stats", boom)
    monkeypatch.setattr(fsel, "_measure_formats", boom)
    eng2 = LifeEngine(tiny_problem, cfg)
    assert eng2.format_plan.format == eng1.format_plan.format
    assert eng2.cache_stats.hits >= 1


def test_engine_auto_format_runs_sbbnnls(tiny_problem):
    from repro.core.life import LifeConfig, LifeEngine
    eng = LifeEngine(tiny_problem,
                     LifeConfig(format="auto", n_iters=10, plan_cache_dir=""))
    w, losses = eng.run()
    assert losses[-1] < losses[0]


def test_batched_engine_auto_format(tmp_path):
    from repro.core.batched import BatchedLifeEngine
    from repro.core.life import LifeConfig
    from repro.data.dmri import synth_cohort
    cohort = synth_cohort(2, n_fibers=48, n_theta=12, n_atoms=16,
                          grid=(8, 8, 8))
    eng = BatchedLifeEngine(cohort, LifeConfig(
        executor="opt", format="auto", n_iters=5,
        plan_cache_dir=str(tmp_path)))
    assert eng.format_plan is not None
    assert eng.format_plan.format in ("coo", "alto")   # vmappable subset
    w, losses = eng.run()
    assert w.shape == (2, 48)
    assert np.isfinite(losses).all()


def test_batched_engine_rejects_sell():
    from repro.core.batched import BatchedLifeEngine
    from repro.core.life import LifeConfig
    from repro.data.dmri import synth_cohort
    cohort = synth_cohort(2, n_fibers=32, n_theta=8, n_atoms=8, grid=(6, 6, 6))
    with pytest.raises(ValueError, match="sell"):
        BatchedLifeEngine(cohort, LifeConfig(executor="opt", format="sell",
                                             plan_cache_dir=""))


def test_engine_sell_with_compaction(tiny_problem):
    """Weight compaction re-encodes the SELL layout mid-run and converges."""
    from repro.core.life import LifeConfig, LifeEngine
    eng = LifeEngine(tiny_problem, LifeConfig(
        format="sell", n_iters=8, compact_every=4, plan_cache_dir=""))
    w, losses = eng.run()
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_measure_formats_times_registry_alto_executor(tiny_problem,
                                                      monkeypatch):
    """Regression: format arbitration used to time ALTO as dsc_naive over
    a decoded COO tensor — never building the registry executor whose cost
    the measured rung is supposed to charge, so ALTO kept "winning" on a
    code path it never runs in production."""
    from repro.core.registry import REGISTRY
    built = []
    real = REGISTRY._factories["alto"]

    def counting(*args, **kwargs):
        built.append(1)
        return real(*args, **kwargs)

    monkeypatch.setitem(REGISTRY._factories, "alto", counting)
    fmt = fsel._measure_formats(tiny_problem.phi, tiny_problem.dictionary,
                                ("coo", "alto"), 8, 32)
    assert built, "arbitration must build the registry alto executor"
    assert fmt in ("coo", "alto")
