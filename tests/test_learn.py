"""Learned zero-measurement selection (repro.learn, DESIGN.md §14).

Covers the feature schema, the dependency-free models, harvesting from the
plan cache, the predicted cold-start contract (a cache miss answered with
zero timing measurements), background refinement upgrading predicted plans
in place, and the serve frontend's idle-tick drain hook.
"""
import dataclasses
import json
import time

import numpy as np
import pytest

from repro.core.inspector import phi_stats
from repro.core.life import LifeConfig, LifeEngine
from repro.core.plan_cache import PlanCache
from repro.data.dmri import synth_connectome
from repro.formats.base import FormatPlan
from repro.learn import (FEATURE_NAMES, CentroidClassifier, NearestExample,
                         Predictor, feature_vector, harvest, load_predictor,
                         predictor_path, run_pending, train_predictor)
from repro.learn import refine
from repro.tune import search as tsearch

TRAIN_SPECS = (
    dict(n_fibers=96, n_theta=16, n_atoms=24, grid=(8, 8, 8),
         algorithm="PROB", seed=71),
    dict(n_fibers=128, n_theta=16, n_atoms=24, grid=(8, 8, 8),
         algorithm="DET", seed=72),
)


def _boom(*a, **k):
    raise AssertionError("timing measurement on a zero-measurement path")


def _train_cfg(cache_dir, **kw):
    base = dict(executor="opt", format="auto", n_iters=1, tune="full",
                compute_dtype="auto", tune_budget=4, predict="off",
                plan_cache_dir=str(cache_dir))
    base.update(kw)
    return LifeConfig(**base)


def _trained_cache(cache_dir, **kw):
    """Fill ``cache_dir`` with measured plans for the training fleet and
    train the predictor beside them."""
    for spec in TRAIN_SPECS:
        LifeEngine(synth_connectome(**spec), _train_cfg(cache_dir, **kw))
    cache = PlanCache(str(cache_dir))
    return cache, train_predictor(cache)


# ----------------------------------------------------------------------------
# features
# ----------------------------------------------------------------------------

def test_feature_vector_schema(tiny_problem):
    stats = phi_stats(tiny_problem.phi)
    x = feature_vector(stats)
    assert x is not None and x.shape == (len(FEATURE_NAMES),)
    assert np.all(np.isfinite(x)) and np.all(x >= 0.0)   # log1p of >= 0
    # any missing feature -> None (old plans are skipped, never padded)
    partial = dict(stats)
    del partial["dsc.run_p99"]
    assert feature_vector(partial) is None
    # non-finite values -> None
    assert feature_vector(dict(stats, n_coeffs=float("nan"))) is None


# ----------------------------------------------------------------------------
# models
# ----------------------------------------------------------------------------

def _toy_training_set():
    r = np.random.default_rng(9)
    a = r.normal(loc=0.0, size=(10, 4))
    b = r.normal(loc=6.0, size=(10, 4))
    x = np.vstack([a, b])
    y = ["coo"] * 10 + ["sell"] * 10
    return x, y, a, b


def test_centroid_classifier_predicts_and_respects_allowed():
    x, y, a, b = _toy_training_set()
    clf = CentroidClassifier.fit(x, y)
    assert clf.predict(a[0]) == "coo"
    assert clf.predict(b[0]) == "sell"
    # restriction to the caller's candidate set is honored...
    assert clf.predict(a[0], allowed=("sell",)) == "sell"
    # ...and an allowed set with no trained class yields None, not a guess
    assert clf.predict(a[0], allowed=("alto", "fcoo")) is None
    assert clf.predict(a[0], allowed=()) is None


def test_nearest_example_replays_group_payloads():
    r = np.random.default_rng(11)
    x = r.normal(size=(4, 3))
    keys = [NearestExample.group_key("kernel-sell", "cpu")] * 2 + \
           [NearestExample.group_key("opt", "cpu")] * 2
    payloads = [dict(row_tile=8, slot_tile=16, compute_dtype="fp32"),
                dict(row_tile=16, slot_tile=32, compute_dtype="bf16"),
                dict(compute_dtype="fp32"), dict(compute_dtype="bf16")]
    nn = NearestExample.fit(x, keys, payloads)
    got = nn.predict(x[1], executor="kernel-sell", backend="cpu")
    assert got == payloads[1]
    # neighbours never cross (executor, backend) groups
    assert nn.predict(x[0], executor="opt", backend="cpu") in payloads[2:]
    assert nn.predict(x[0], executor="alto", backend="cpu") is None


def test_predictor_json_roundtrip(tmp_path):
    r = np.random.default_rng(13)
    n_feat = len(FEATURE_NAMES)
    x = np.vstack([r.normal(loc=0.0, size=(8, n_feat)),
                   r.normal(loc=6.0, size=(8, n_feat))])
    y = ["coo"] * 8 + ["sell"] * 8
    pred = Predictor(format_model=CentroidClassifier.fit(x, y),
                     n_format_examples=len(y))
    blob = json.dumps(pred.to_json())
    back = Predictor.from_json(json.loads(blob))
    stats = {name: float(i + 1) for i, name in enumerate(FEATURE_NAMES)}
    assert (back.predict_format(stats, allowed=("coo", "sell"))
            == pred.predict_format(stats, allowed=("coo", "sell")))
    # a schema bump must refuse to load (silent reorder = wrong predictions)
    stale = json.loads(blob)
    stale["schema"] = -1
    assert Predictor.from_json(stale) is None
    stale = json.loads(blob)
    stale["feature_names"] = list(reversed(stale["feature_names"]))
    assert Predictor.from_json(stale) is None


# ----------------------------------------------------------------------------
# harvest + train + load
# ----------------------------------------------------------------------------

def test_harvest_excludes_non_training_reasons(tmp_path, tiny_problem):
    cache = PlanCache(str(tmp_path / "c"))
    stats = phi_stats(tiny_problem.phi)
    params = dict(row_tile=8, slot_tile=32)
    cache.put_format_plan("k1", FormatPlan("sell", "heuristic", params, stats))
    cache.put_format_plan("k2", FormatPlan("coo", "autotune", params, stats))
    cache.put_format_plan("k3", FormatPlan("alto", "explicit", params, stats))
    cache.put_format_plan("k4", FormatPlan("coo", "predicted", params, stats))
    cache.put_format_plan("k5", FormatPlan("coo", "heuristic", params, {}))
    fmt, tune = harvest(cache)
    # explicit (user-forced), predicted (model's own output) and stats-less
    # plans are all excluded from the training set
    assert sorted(lab for _, lab in fmt) == ["coo", "sell"]
    assert tune == []


def test_train_and_load_predictor(tmp_path, tiny_problem):
    cache, predictor = _trained_cache(tmp_path / "train")
    assert predictor is not None
    assert predictor.n_format_examples >= 2
    assert predictor.n_tune_examples >= 2      # dtype axis forces a search
    # persisted beside the plans, reloadable, memo invalidates on retrain
    loaded = load_predictor(cache.directory)
    assert loaded is not None
    assert loaded.n_format_examples == predictor.n_format_examples
    stats = phi_stats(tiny_problem.phi)
    assert loaded.predict_format(stats, allowed=("coo", "sell", "alto",
                                                 "fcoo")) is not None
    # an empty cache trains nothing and writes nothing
    empty = PlanCache(str(tmp_path / "empty"))
    assert train_predictor(empty) is None
    assert load_predictor(empty.directory) is None


def test_predictor_survives_npz_pruning(tmp_path, tiny_problem):
    """The trained model must not be evicted by the cache's size cap —
    pruning only touches .npz entries."""
    cache, _ = _trained_cache(tmp_path / "train")
    capped = PlanCache(cache.directory, max_bytes=1)
    stats = phi_stats(tiny_problem.phi)
    capped.put_format_plan(
        "evictor", FormatPlan("coo", "heuristic",
                              dict(row_tile=8, slot_tile=32), stats))
    assert load_predictor(cache.directory) is not None


# ----------------------------------------------------------------------------
# the cold-start contract (tentpole acceptance)
# ----------------------------------------------------------------------------

def test_predicted_cold_start_zero_measurements(tmp_path, tiny_problem,
                                                monkeypatch):
    """A cache miss on an unseen dataset with a warm-trained predictor
    yields a usable engine with reason="predicted" plans and not a single
    timing measurement."""
    cache, predictor = _trained_cache(tmp_path / "train")
    assert predictor is not None

    n0 = tsearch.measurement_count()
    monkeypatch.setattr(tsearch, "time_call", _boom)
    cfg = LifeConfig(executor="opt", format="auto", n_iters=2, tune="cached",
                     compute_dtype="auto", plan_cache_dir=cache.directory)
    eng = LifeEngine(tiny_problem, cfg)
    assert tsearch.measurement_count() == n0
    assert eng.format_plan.reason == "predicted"
    assert eng.format_plan.format in ("coo", "sell", "alto", "fcoo")
    # the engine is usable, not just constructed
    w, losses = eng.run()
    assert losses[-1] <= losses[0]


def test_predicted_tune_plan_zero_measurements(tmp_path, tiny_problem,
                                               monkeypatch):
    """tune="cached" miss on a trained cache replays the nearest example's
    launch params as a predicted TunePlan — no search, params legal."""
    cache, predictor = _trained_cache(tmp_path / "train", format="sell",
                                      slot_tile=16)
    assert predictor is not None and predictor.tune_model is not None

    monkeypatch.setattr(tsearch, "time_call", _boom)
    cfg = LifeConfig(executor="opt", format="sell", slot_tile=16, n_iters=1,
                     tune="cached", compute_dtype="auto",
                     plan_cache_dir=cache.directory)
    eng = LifeEngine(tiny_problem, cfg)
    plan = eng.tune_plan
    assert plan is not None and plan.reason == "predicted"
    assert plan.executor == "kernel-sell"
    assert set(plan.params) == {"row_tile", "slot_tile"}
    assert plan.compute_dtype in ("fp32", "bf16")       # resolved, not auto
    # predicted plans are persisted: a second cached build replays it
    eng2 = LifeEngine(tiny_problem, dataclasses.replace(cfg))
    assert eng2.tune_plan == plan


def test_predicted_format_respects_allowed_and_mesh(tmp_path, tiny_problem):
    """Predicted plans always name a format from the caller's allowed /
    mesh-capable candidate set, even when the model's favourite class is
    excluded from it."""
    from repro.core.registry import REGISTRY
    from repro.formats import select as fsel
    cache, predictor = _trained_cache(tmp_path / "train")
    assert predictor is not None
    d = tiny_problem.dictionary
    for allowed in (("coo",), ("alto",), ("coo", "fcoo")):
        plan = fsel.choose_format(tiny_problem.phi, d, allowed=allowed,
                                  predictor=predictor)
        assert plan.format in allowed
    # a multi-cell mesh restricts "auto" to mesh-capable formats before
    # the predictor sees the candidate set
    cfg = LifeConfig(format="auto", shard_rows=2, shard_cols=1,
                     plan_cache_dir=cache.directory, tune="off")
    plan = fsel.resolve_format(tiny_problem.phi, tiny_problem, cfg,
                               cache=PlanCache(cache.directory))
    assert REGISTRY.mesh_executor_for(plan.format) is not None


def test_selection_determinism_across_rebuilds(tmp_path, tiny_problem):
    """Same phi + same cache dir => byte-identical FormatPlan/TunePlan on
    every rebuild (warm replay, no re-selection drift)."""
    cfg = _train_cfg(tmp_path / "c", format="auto")
    engines = [LifeEngine(tiny_problem, cfg) for _ in range(3)]
    plans = [e.format_plan for e in engines]
    tunes = [e.tune_plan for e in engines]
    assert plans[0] == plans[1] == plans[2]
    assert tunes[0] == tunes[1] == tunes[2]
    assert tunes[0] is not None and tunes[0].reason in ("search", "default")


# ----------------------------------------------------------------------------
# background refinement
# ----------------------------------------------------------------------------

def test_refine_queue_dedups_and_survives_failure():
    q = refine.RefineQueue(max_tasks=2)
    ran = []
    assert q.push("format", "k", lambda: ran.append(1))
    assert not q.push("format", "k", lambda: ran.append(2))   # dup identity
    assert q.push("tune", "k", lambda: 1 / 0)                 # distinct kind
    assert not q.push("format", "k2", lambda: None)           # full
    assert len(q) == 2
    assert q.run_one() and ran == [1]
    assert q.run_one()            # the failing task runs, is dropped, no raise
    assert not q.run_one() and len(q) == 0


def test_refinement_upgrades_predicted_plan_in_place(tmp_path, tiny_problem,
                                                     monkeypatch):
    """Draining the refine queue re-runs the measured pipeline and
    overwrites the predicted cache entries; the next rebuild replays the
    measured plans with zero measurements."""
    cache, _ = _trained_cache(tmp_path / "train", format="sell", slot_tile=16)
    cfg = LifeConfig(executor="opt", format="sell", slot_tile=16, n_iters=1,
                     tune="cached", compute_dtype="auto",
                     plan_cache_dir=cache.directory)
    monkeypatch.setattr(tsearch, "time_call", _boom)
    eng = LifeEngine(tiny_problem, cfg)
    assert eng.tune_plan.reason == "predicted"
    assert len(refine.QUEUE) >= 1

    monkeypatch.undo()            # refinement is allowed to measure
    assert run_pending() >= 1
    monkeypatch.setattr(tsearch, "time_call", _boom)
    eng2 = LifeEngine(tiny_problem, cfg)
    assert eng2.tune_plan.reason == "search"
    assert eng2.tune_plan.measurements


def test_format_refinement_upgrades_predicted_plan(tmp_path, tiny_problem,
                                                   monkeypatch):
    from repro.formats import select as fsel
    cache, predictor = _trained_cache(tmp_path / "train")
    fresh = PlanCache(cache.directory)
    monkeypatch.setattr(fsel, "_measure_formats", _boom)
    plan = fsel.choose_format(tiny_problem.phi, tiny_problem.dictionary,
                              cache=fresh, predictor=predictor)
    assert plan.reason == "predicted"
    assert len(refine.QUEUE) >= 1
    monkeypatch.undo()
    assert run_pending() >= 1
    # the cached entry is now the measured/heuristic decision
    upgraded = fsel.choose_format(tiny_problem.phi, tiny_problem.dictionary,
                                  cache=fresh, predictor=predictor)
    assert upgraded.reason in ("heuristic", "autotune")


def test_cache_hit_on_predicted_plan_reenqueues_refinement(tmp_path,
                                                           tiny_problem,
                                                           monkeypatch):
    """A process restart drops the in-memory queue; a predicted plan still
    serving hits must re-enqueue its refinement."""
    from repro.formats import select as fsel
    cache, predictor = _trained_cache(tmp_path / "train")
    fresh = PlanCache(cache.directory)
    monkeypatch.setattr(fsel, "_measure_formats", _boom)
    plan = fsel.choose_format(tiny_problem.phi, tiny_problem.dictionary,
                              cache=fresh, predictor=predictor)
    assert plan.reason == "predicted"
    refine.QUEUE.clear()          # simulate the restart
    hit = fsel.choose_format(tiny_problem.phi, tiny_problem.dictionary,
                             cache=fresh, predictor=predictor)
    assert hit.reason == "predicted"
    assert len(refine.QUEUE) == 1


def test_frontend_idle_tick_drains_refine_queue(tiny_problem):
    """The serve driver spends idle ticks on refinement tasks — without a
    single job ever being submitted."""
    from repro.serve.frontend import LifeFrontend
    ran = []
    refine.QUEUE.push("format", "idle-test", lambda: ran.append(1))
    with LifeFrontend(LifeConfig(n_iters=1, plan_cache_dir=""),
                      idle_wait=0.001) as fe:
        deadline = time.monotonic() + 5.0
        while not ran and time.monotonic() < deadline:
            time.sleep(0.01)
        assert fe.service is not None
    assert ran == [1]
    assert len(refine.QUEUE) == 0


def test_frontend_refine_disabled_leaves_queue(tiny_problem):
    from repro.serve.frontend import LifeFrontend
    ran = []
    refine.QUEUE.push("format", "disabled-test", lambda: ran.append(1))
    with LifeFrontend(LifeConfig(n_iters=1, plan_cache_dir=""),
                      idle_wait=0.001, refine=False):
        time.sleep(0.1)
    assert ran == [] and len(refine.QUEUE) == 1


# ----------------------------------------------------------------------------
# config surface
# ----------------------------------------------------------------------------

def test_predict_off_disables_the_rung(tmp_path, tiny_problem):
    cache, predictor = _trained_cache(tmp_path / "train")
    assert predictor is not None          # a trained model exists...
    cfg = LifeConfig(executor="opt", format="auto", n_iters=1, tune="cached",
                     predict="off", plan_cache_dir=cache.directory)
    eng = LifeEngine(tiny_problem, cfg)
    # ...but predict="off" skips the rung: heuristic/measured only
    assert eng.format_plan.reason in ("heuristic", "autotune")
    assert eng.tune_plan.reason != "predicted"


def test_predict_validation():
    from repro.tune.tuner import validate_config
    with pytest.raises(ValueError, match="predict"):
        validate_config(LifeConfig(predict="sometimes"))


def test_predictor_file_location(tmp_path):
    assert predictor_path(str(tmp_path)).endswith("predictor.json")
