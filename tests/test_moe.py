"""MoE sort-based dispatch vs per-token dense-expert reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import init_moe, moe_ffn


def _dense_reference(p, x, top_k):
    """Route each token independently through its top-k experts (no capacity)."""
    B, S, d = x.shape
    xf = np.asarray(x.reshape(-1, d), np.float64)
    router = np.asarray(p["router"], np.float64)
    logits = xf @ router
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        top = np.argsort(-probs[t])[:top_k]
        gates = probs[t][top] / probs[t][top].sum()
        for e, g in zip(top, gates):
            wi_g = np.asarray(p["wi_gate"][e], np.float64)
            wi_u = np.asarray(p["wi_up"][e], np.float64)
            wo = np.asarray(p["wo"][e], np.float64)
            h = xf[t] @ wi_g
            silu = h / (1 + np.exp(-h))
            out[t] += g * ((silu * (xf[t] @ wi_u)) @ wo)
    return out.reshape(B, S, d)


@pytest.mark.parametrize("E,top_k", [(4, 2), (8, 1)])
def test_dispatch_matches_dense_reference(rng, E, top_k):
    d, ff = 16, 32
    p = init_moe(jax.random.PRNGKey(0), d, ff, E, 0, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, d)), jnp.float32)
    # capacity_factor = E => drop-free
    out, aux = moe_ffn(p, x, top_k=top_k, capacity_factor=float(E))
    ref = _dense_reference(p, x, top_k)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)
    assert float(aux) > 0.0


def test_capacity_drops_are_bounded(rng):
    """With tight capacity some tokens drop; output stays finite and close
    in norm (dropped tokens pass through the residual path upstream)."""
    d, ff, E = 16, 32, 4
    p = init_moe(jax.random.PRNGKey(1), d, ff, E, 0, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 16, d)), jnp.float32)
    full, _ = moe_ffn(p, x, top_k=2, capacity_factor=float(E))
    tight, _ = moe_ffn(p, x, top_k=2, capacity_factor=1.0)
    assert np.isfinite(np.asarray(tight)).all()
    # at least the capacity-share of mass is preserved
    assert np.linalg.norm(np.asarray(tight)) <= np.linalg.norm(np.asarray(full)) * 1.05


def test_shared_expert_adds(rng):
    d, ff, E = 8, 16, 4
    p = init_moe(jax.random.PRNGKey(2), d, ff, E, 1, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 4, d)), jnp.float32)
    out, _ = moe_ffn(p, x, top_k=2, capacity_factor=float(E))
    p2 = dict(p)
    p2.pop("shared")
    out2, _ = moe_ffn(p2, x, top_k=2, capacity_factor=float(E))
    assert float(jnp.abs(out - out2).max()) > 1e-6


def test_grad_flows_through_dispatch(rng):
    d, ff, E = 8, 16, 4
    p = init_moe(jax.random.PRNGKey(3), d, ff, E, 0, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 8, d)), jnp.float32)

    def loss(p):
        out, aux = moe_ffn(p, x, top_k=2, capacity_factor=float(E))
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("wi_gate", "wi_up", "wo", "router"):
        assert float(jnp.abs(g[name]).max()) > 0, name
