"""Pallas kernels (interpret mode) vs pure-jnp oracles — shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

pytestmark = pytest.mark.slow        # Pallas interpret sweeps

from repro.core import spmv
from repro.core.inspector import plan_tiles
from repro.core.restructure import sort_by_host
from repro.core.std import PhiTensor, make_dictionary
from repro.data.dmri import synth_connectome
from repro.kernels import ops as kops
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.ref import moe_gmm_ref


def _problem(nc, na, nv, nf, seed):
    r = np.random.default_rng(seed)
    return PhiTensor(
        atoms=jnp.asarray(r.integers(0, na, nc), jnp.int32),
        voxels=jnp.asarray(r.integers(0, nv, nc), jnp.int32),
        fibers=jnp.asarray(r.integers(0, nf, nc), jnp.int32),
        values=jnp.asarray(r.normal(size=nc), jnp.float32),
        n_atoms=na, n_voxels=nv, n_fibers=nf)


@pytest.mark.parametrize("nc,nv,nf,c_tile,row_tile", [
    (50, 40, 30, 16, 4),
    (513, 100, 64, 64, 8),
    (1000, 17, 23, 128, 8),      # many coeffs per row
    (7, 300, 200, 32, 16),       # sparse rows
])
@pytest.mark.parametrize("n_theta", [8, 96])
def test_dsc_kernel_shapes(nc, nv, nf, c_tile, row_tile, n_theta):
    phi = _problem(nc, 12, nv, nf, seed=nc + n_theta)
    d = make_dictionary(12, n_theta)
    w = jnp.asarray(np.random.default_rng(1).uniform(size=nf), jnp.float32)
    phi_v, _ = sort_by_host(phi, "voxel")
    plan = plan_tiles(np.asarray(phi_v.voxels), nv, c_tile=c_tile,
                      row_tile=row_tile)
    mv = kops.make_dsc(phi_v, d, plan, interpret=True)
    want = spmv.dsc_naive(phi, d, w)
    np.testing.assert_allclose(np.asarray(mv(w)), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("nc,nv,nf,c_tile,row_tile", [
    (50, 40, 30, 16, 8),
    (513, 100, 64, 64, 8),
    (600, 25, 11, 128, 8),
])
def test_wc_kernel_shapes(nc, nv, nf, c_tile, row_tile):
    phi = _problem(nc, 12, nv, nf, seed=7 * nc)
    d = make_dictionary(12, 16)
    y = jnp.asarray(np.random.default_rng(2).normal(size=(nv, 16)), jnp.float32)
    phi_f, _ = sort_by_host(phi, "fiber")
    plan = plan_tiles(np.asarray(phi_f.fibers), nf, c_tile=c_tile,
                      row_tile=row_tile)
    rv = kops.make_wc(phi_f, d, plan, interpret=True)
    want = spmv.wc_naive(phi, d, y)
    np.testing.assert_allclose(np.asarray(rv(y)), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dsc_kernel_dtypes(dtype):
    phi = _problem(200, 12, 50, 40, seed=3)
    d = make_dictionary(12, 16, dtype=dtype)
    phi = phi.astype(dtype)
    w = jnp.asarray(np.random.default_rng(1).uniform(size=40), dtype)
    phi_v, _ = sort_by_host(phi, "voxel")
    plan = plan_tiles(np.asarray(phi_v.voxels), 50, c_tile=64, row_tile=8)
    mv = kops.make_dsc(phi_v, d, plan, interpret=True)
    want = spmv.dsc_naive(phi.astype(jnp.float32),
                          d.astype(jnp.float32), w.astype(jnp.float32))
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(mv(w), np.float32),
                               np.asarray(want), rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 400), st.integers(2, 60), st.integers(2, 40),
       st.integers(0, 1000))
def test_property_dsc_kernel(nc, nv, nf, seed):
    phi = _problem(nc, 8, nv, nf, seed)
    d = make_dictionary(8, 8)
    w = jnp.asarray(np.random.default_rng(seed).uniform(size=nf), jnp.float32)
    phi_v, _ = sort_by_host(phi, "voxel")
    plan = plan_tiles(np.asarray(phi_v.voxels), nv, c_tile=32, row_tile=8)
    mv = kops.make_dsc(phi_v, d, plan, interpret=True)
    np.testing.assert_allclose(
        np.asarray(mv(w)), np.asarray(spmv.dsc_naive(phi, d, w)),
        rtol=1e-3, atol=1e-3)


def test_kernel_on_synthetic_connectome(tiny_problem):
    """End-to-end kernel executor on tractography-shaped data."""
    p = tiny_problem
    phi_v, _ = sort_by_host(p.phi, "voxel")
    phi_f, _ = sort_by_host(p.phi, "fiber")
    dsc_plan = plan_tiles(np.asarray(phi_v.voxels), p.phi.n_voxels,
                          c_tile=128, row_tile=8)
    wc_plan = plan_tiles(np.asarray(phi_f.fibers), p.phi.n_fibers,
                         c_tile=128, row_tile=8)
    mv = kops.make_dsc(phi_v, p.dictionary, dsc_plan, interpret=True)
    rv = kops.make_wc(phi_f, p.dictionary, wc_plan, interpret=True)
    w = jnp.ones((p.phi.n_fibers,), jnp.float32)
    y = mv(w)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(spmv.dsc_naive(p.phi, p.dictionary, w)),
        rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(rv(y)), np.asarray(spmv.wc_naive(p.phi, p.dictionary, y)),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("E,d,f,tiles,t_tile,f_tile", [
    (4, 32, 64, 8, 16, 64),
    (2, 16, 32, 4, 8, 32),
    (8, 64, 128, 16, 32, 128),
])
def test_moe_gmm_kernel(E, d, f, tiles, t_tile, f_tile):
    r = np.random.default_rng(E + d)
    xs = jnp.asarray(r.normal(size=(tiles * t_tile, d)), jnp.float32)
    wexp = jnp.asarray(r.normal(size=(E, d, f)), jnp.float32)
    eot = jnp.asarray(r.integers(0, E, size=(tiles,)), jnp.int32)
    out = moe_gmm(eot, xs, wexp, t_tile=t_tile, f_tile=f_tile, interpret=True)
    ref = moe_gmm_ref(xs.reshape(tiles, t_tile, d), wexp, eot).reshape(-1, f)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
