"""Kernel autotuning subsystem (DESIGN.md §10): plan keys, cache behaviour,
zero-measurement warm rebuilds, and the bf16 accuracy contract across the
whole executor x format conformance matrix."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.life import LifeConfig, LifeEngine
from repro.core.plan_cache import PlanCache, tune_plan_key
from repro.core.registry import REGISTRY, create_for_format
from repro.formats import format_names
from repro.tune import (BF16_ATOL, BF16_RTOL, TunePlan, search_space,
                        tile_axes)
from repro.tune.plan import COMPUTE_DTYPES
from repro.tune.tuner import backend_name

#: same derivation as tests/test_conformance.py — the registry is the truth
MATRIX = [(ex, fmt) for fmt in format_names()
          for ex in REGISTRY.executors_for_format(fmt)]

_CFG = LifeConfig(executor="opt", c_tile=64, row_tile=8, slot_tile=16,
                  plan_cache_dir="")


def _make_executor(name, fmt, problem, cfg):
    if fmt == "coo":
        return REGISTRY.create(name, problem.phi, problem, cfg, PlanCache(""))
    return create_for_format(problem.phi, problem, cfg, PlanCache(""))


def _ids():
    rng = np.random.default_rng(3)
    return (rng.integers(0, 24, 200), rng.integers(0, 40, 200),
            rng.integers(0, 64, 200))


_KEY_BASE = dict(sizes=(24, 40, 64), n_theta=16, executor="kernel-sell",
                 fmt="sell", backend="cpu", n_devices=1,
                 compute_dtype="fp32", budget=12)


# ----------------------------------------------------------------------------
# key schema: content addressing across every axis the plan depends on
# ----------------------------------------------------------------------------

def test_tune_plan_key_is_content_addressed():
    ids = _ids()
    base = tune_plan_key(*ids, **_KEY_BASE)
    # same content, different buffers -> same key (warm hit on identical
    # inputs)
    assert tune_plan_key(*(a.copy() for a in ids), **_KEY_BASE) == base
    # any platform / config axis change -> clean miss
    for change in (dict(backend="tpu"), dict(n_devices=8),
                   dict(compute_dtype="bf16"), dict(compute_dtype="auto"),
                   dict(executor="kernel"), dict(fmt="coo"),
                   dict(n_theta=32), dict(sizes=(24, 40, 65)),
                   dict(budget=4), dict(mesh=(2, 1)), dict(mesh=(1, 2))):
        assert tune_plan_key(*ids, **{**_KEY_BASE, **change}) != base, change
    # index-content change -> clean miss
    bumped = (ids[0].copy(), ids[1], ids[2])
    bumped[0][0] = (bumped[0][0] + 1) % 24
    if not np.array_equal(bumped[0], ids[0]):
        assert tune_plan_key(*bumped, **_KEY_BASE) != base


def test_tune_plan_roundtrip(tmp_path):
    cache = PlanCache(str(tmp_path))
    plan = TunePlan(executor="kernel-sell", backend="cpu", n_devices=1,
                    params=dict(row_tile=16, slot_tile=32),
                    compute_dtype="bf16", reason="search",
                    measurements={"a": 1.5e-3, "b": 2.5e-3})
    key = tune_plan_key(*_ids(), **_KEY_BASE)
    assert cache.get_tune_plan(key) is None           # cold
    cache.put_tune_plan(key, plan)
    got = cache.get_tune_plan(key)
    assert got == plan
    assert cache.stats.hits == 1 and cache.stats.misses == 1


def test_tune_plan_apply_replaces_only_declared_fields():
    plan = TunePlan(executor="kernel-sell", backend="cpu", n_devices=1,
                    params=dict(row_tile=16, slot_tile=64, bogus_axis=3),
                    compute_dtype="bf16")
    cfg = plan.apply(_CFG)
    assert (cfg.row_tile, cfg.slot_tile) == (16, 64)
    assert cfg.compute_dtype == "bf16"
    assert not hasattr(cfg, "bogus_axis")
    assert _CFG.row_tile == 8                          # original untouched


# ----------------------------------------------------------------------------
# search space: the default config is never truncated away
# ----------------------------------------------------------------------------

def test_search_space_keeps_default_under_budget():
    for budget in (2, 4, 6):
        cands = search_space("kernel-sell", _CFG, budget=budget)
        assert len(cands) <= max(budget, 1)
        assert cands[0] == dict(params=dict(row_tile=8, slot_tile=16),
                                compute_dtype="fp32")


def test_search_space_dtype_axis():
    cfg = dataclasses.replace(_CFG, compute_dtype="auto")
    cands = search_space("opt", cfg)          # no tile axes: dtype axis only
    assert [c["compute_dtype"] for c in cands] == list(COMPUTE_DTYPES)
    assert all(c["params"] == {} for c in cands)
    assert tile_axes("opt") == ()
    assert tile_axes("kernel") == ("c_tile", "row_tile")
    assert tile_axes("kernel-fcoo") == ("c_tile", "seg_tile")


# ----------------------------------------------------------------------------
# engine integration: full -> cached rebuild performs ZERO measurements
# ----------------------------------------------------------------------------

def _tuned_cfg(tmp_path, **kw):
    return LifeConfig(executor="opt", format="sell", slot_tile=16, row_tile=8,
                      n_iters=2, tune="full", tune_budget=4,
                      plan_cache_dir=str(tmp_path), **kw)


def test_full_then_cached_zero_measurements(tmp_path, tiny_problem,
                                            monkeypatch):
    """The acceptance contract: tune="full" then rebuild with tune="cached"
    loads the persisted TunePlan and never measures anything."""
    cfg = _tuned_cfg(tmp_path)
    eng1 = LifeEngine(tiny_problem, cfg)
    plan1 = eng1.tune_plan
    assert plan1 is not None and plan1.reason == "search"
    assert plan1.measurements                      # the search did measure

    from repro.tune import search as tsearch

    def boom(*a, **k):
        raise AssertionError("measurement despite warm tune-plan cache")

    monkeypatch.setattr(tsearch, "time_call", boom)
    eng2 = LifeEngine(tiny_problem,
                      dataclasses.replace(cfg, tune="cached"))
    assert eng2.tune_plan == plan1
    # ... and a warm tune="full" rebuild also skips the search
    eng3 = LifeEngine(tiny_problem, cfg)
    assert eng3.tune_plan == plan1


def test_full_then_cached_zero_measurements_fcoo(tmp_path, tiny_problem,
                                                 monkeypatch):
    """Same warm-rebuild contract for the F-COO executor: its tune axes
    (c_tile, seg_tile) are searched once, then every rebuild — cached or
    full — loads the persisted TunePlan without a single measurement."""
    cfg = LifeConfig(executor="opt", format="fcoo", c_tile=64, seg_tile=16,
                     n_iters=2, tune="full", tune_budget=4,
                     plan_cache_dir=str(tmp_path))
    eng1 = LifeEngine(tiny_problem, cfg)
    plan1 = eng1.tune_plan
    assert plan1 is not None and plan1.reason == "search"
    assert plan1.executor == "kernel-fcoo"
    assert plan1.measurements

    from repro.tune import search as tsearch

    def boom(*a, **k):
        raise AssertionError("measurement despite warm tune-plan cache")

    monkeypatch.setattr(tsearch, "time_call", boom)
    eng2 = LifeEngine(tiny_problem, dataclasses.replace(cfg, tune="cached"))
    assert eng2.tune_plan == plan1
    eng3 = LifeEngine(tiny_problem, cfg)           # warm tune="full"
    assert eng3.tune_plan == plan1


def test_cached_miss_uses_defaults_without_measuring(tmp_path, tiny_problem,
                                                     monkeypatch):
    """tune="cached" on a cold cache must fall back to the config constants
    immediately — intake paths never stall on a search."""
    from repro.tune import search as tsearch

    def boom(*a, **k):
        raise AssertionError('tune="cached" measured on a miss')

    monkeypatch.setattr(tsearch, "time_call", boom)
    cfg = dataclasses.replace(_tuned_cfg(tmp_path), tune="cached")
    eng = LifeEngine(tiny_problem, cfg)
    plan = eng.tune_plan
    assert plan.reason == "untuned"
    assert plan.params == dict(row_tile=8, slot_tile=16)
    # the miss persisted nothing: a later "cached" engine still misses
    eng2 = LifeEngine(tiny_problem, cfg)
    assert eng2.tune_plan.reason == "untuned"


def test_backend_change_is_clean_miss(tmp_path, tiny_problem, monkeypatch):
    """A plan tuned on one backend must not be replayed on another."""
    cfg = _tuned_cfg(tmp_path)
    LifeEngine(tiny_problem, cfg)                   # tune + persist on "cpu"
    import repro.tune.tuner as tuner_mod
    monkeypatch.setattr(tuner_mod, "backend_name", lambda: "faketpu")
    eng = LifeEngine(tiny_problem,
                     dataclasses.replace(cfg, tune="cached"))
    assert eng.tune_plan.reason == "untuned"        # miss, not a stale hit


def test_dtype_change_is_clean_miss(tmp_path, tiny_problem, monkeypatch):
    cfg = _tuned_cfg(tmp_path)
    LifeEngine(tiny_problem, cfg)                   # fp32-keyed plan
    from repro.tune import search as tsearch
    monkeypatch.setattr(tsearch, "time_call",
                        lambda *a, **k: (_ for _ in ()).throw(
                            AssertionError("measured")))
    eng = LifeEngine(tiny_problem, dataclasses.replace(
        cfg, tune="cached", compute_dtype="bf16"))
    assert eng.tune_plan.reason == "untuned"


def test_tuned_engine_matches_oracle(tmp_path, tiny_problem, tiny_dense,
                                     rng):
    """Whatever configuration the search picks, the tuned executor still
    satisfies the conformance contract."""
    eng = LifeEngine(tiny_problem,
                     _tuned_cfg(tmp_path, compute_dtype="auto"))
    m = np.asarray(tiny_dense, np.float64)
    w = jnp.asarray(rng.uniform(0, 1, tiny_problem.phi.n_fibers),
                    jnp.float32)
    got = np.asarray(eng.matvec(w), np.float64).reshape(-1)
    want = m @ np.asarray(w, np.float64)
    np.testing.assert_allclose(got, want, rtol=BF16_RTOL, atol=BF16_ATOL)


def test_auto_dtype_requires_tuning(tiny_problem):
    with pytest.raises(ValueError, match="searched axis"):
        LifeEngine(tiny_problem, LifeConfig(executor="opt", tune="off",
                                            compute_dtype="auto",
                                            plan_cache_dir=""))
    with pytest.raises(ValueError, match="tune must be one of"):
        LifeEngine(tiny_problem, LifeConfig(executor="opt", tune="always",
                                            plan_cache_dir=""))
    with pytest.raises(ValueError, match="compute_dtype"):
        LifeEngine(tiny_problem, LifeConfig(executor="opt",
                                            compute_dtype="fp16",
                                            plan_cache_dir=""))


# ----------------------------------------------------------------------------
# bf16 storage / fp32 accumulate: documented atol across the whole matrix
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("executor,fmt", MATRIX)
def test_bf16_within_documented_atol_of_fp32(executor, fmt, tiny_problem,
                                             rng):
    """compute_dtype="bf16" stays within BF16_RTOL/BF16_ATOL of the fp32
    executor for every executor x format pair the registry declares."""
    p = tiny_problem
    n_theta = p.dictionary.shape[1]
    w = jnp.asarray(rng.uniform(0, 1, p.phi.n_fibers), jnp.float32)
    y = jnp.asarray(rng.normal(size=(p.phi.n_voxels, n_theta)), jnp.float32)
    outs = {}
    for dt in ("fp32", "bf16"):
        cfg = dataclasses.replace(_CFG, executor=executor, format=fmt,
                                  compute_dtype=dt)
        ex = _make_executor(executor, fmt, p, cfg)
        outs[dt] = (np.asarray(ex.matvec(w), np.float64),
                    np.asarray(ex.rmatvec(y), np.float64))
    # fp32 outputs keep fp32 dtype end to end (accumulators never narrow)
    np.testing.assert_allclose(outs["bf16"][0], outs["fp32"][0],
                               rtol=BF16_RTOL, atol=BF16_ATOL,
                               err_msg=f"{executor}/{fmt} matvec")
    np.testing.assert_allclose(outs["bf16"][1], outs["fp32"][1],
                               rtol=BF16_RTOL,
                               atol=BF16_ATOL * max(
                                   1.0, np.abs(outs["fp32"][1]).max()),
                               err_msg=f"{executor}/{fmt} rmatvec")


def test_bf16_output_dtype_stays_fp32(tiny_problem):
    """bf16 is a *storage* dtype: matvec/rmatvec still return fp32."""
    cfg = dataclasses.replace(_CFG, executor="kernel-sell", format="sell",
                              compute_dtype="bf16")
    ex = _make_executor("kernel-sell", "sell", tiny_problem, cfg)
    w = jnp.ones((tiny_problem.phi.n_fibers,), jnp.float32)
    y = ex.matvec(w)
    assert y.dtype == jnp.float32
    assert ex.rmatvec(y).dtype == jnp.float32


def test_bf16_batched_engine(tiny_cohort):
    """The batched engine honors compute_dtype: bf16 trajectories track
    fp32 within the documented tolerance."""
    from repro.core.batched import BatchedLifeEngine
    cfg32 = LifeConfig(executor="opt", n_iters=4, plan_cache_dir="")
    cfg16 = dataclasses.replace(cfg32, compute_dtype="bf16")
    _, l32 = BatchedLifeEngine(tiny_cohort, cfg32).run()
    _, l16 = BatchedLifeEngine(tiny_cohort, cfg16).run()
    np.testing.assert_allclose(l16, l32, rtol=BF16_RTOL)


# ----------------------------------------------------------------------------
# serving: tuning settings partition micro-batches
# ----------------------------------------------------------------------------

def test_scheduler_buckets_split_on_tune_settings(tiny_cohort):
    from repro.serve.scheduler import Job, Scheduler
    s = Scheduler(LifeConfig(executor="opt", n_iters=4, plan_cache_dir=""))
    s.submit(Job(job_id="a", problem=tiny_cohort[0], n_iters=4,
                 format="coo"))
    s.submit(Job(job_id="b", problem=tiny_cohort[1], n_iters=4,
                 format="coo", compute_dtype="bf16"))
    s.submit(Job(job_id="c", problem=tiny_cohort[2], n_iters=4,
                 format="coo"))
    s._admit()
    members = sorted(tuple(sorted(j.job_id for j in b.jobs))
                     for b in s._buckets.values())
    assert members == [("a", "c"), ("b",)]
    done = s.run_until_idle()
    assert sorted(j.job_id for j in done) == ["a", "b", "c"]


def test_fcoo_jobs_never_share_a_microbatch(tiny_cohort):
    """F-COO is a solo format AND tune settings are part of the bucket
    key: two fcoo jobs never co-batch, whether their tuning matches or
    not — differently-tuned jobs sharing a micro-batch would force one
    tenant's tile plan on the other."""
    from repro.serve.scheduler import Job, Scheduler
    s = Scheduler(LifeConfig(executor="opt", n_iters=4, plan_cache_dir=""))
    s.submit(Job(job_id="a", problem=tiny_cohort[0], n_iters=4,
                 format="fcoo"))
    s.submit(Job(job_id="b", problem=tiny_cohort[1], n_iters=4,
                 format="fcoo", compute_dtype="bf16"))
    s.submit(Job(job_id="c", problem=tiny_cohort[2], n_iters=4,
                 format="fcoo"))
    s._admit()
    members = sorted(tuple(sorted(j.job_id for j in b.jobs))
                     for b in s._buckets.values())
    assert members == [("a",), ("b",), ("c",)]
    keys = {b.key for b in s._buckets.values()}
    assert len(keys) == 3                      # distinct bucket identities
    done = s.run_until_idle()
    assert sorted(j.job_id for j in done) == ["a", "b", "c"]


def test_scheduler_rejects_bad_tune_values(tiny_cohort):
    from repro.serve.scheduler import Job, Scheduler
    s = Scheduler(LifeConfig(executor="opt", plan_cache_dir=""))
    with pytest.raises(ValueError, match="tune must be"):
        s.submit(Job(job_id="x", problem=tiny_cohort[0], n_iters=2,
                     format="coo", tune="sometimes"))
    with pytest.raises(ValueError, match="searched axis"):
        s.submit(Job(job_id="y", problem=tiny_cohort[0], n_iters=2,
                     format="coo", compute_dtype="auto"))


def test_auto_dtype_pins_resolved_value_in_checkpoints(tmp_path,
                                                       tiny_cohort):
    """A compute_dtype="auto" job is pinned to the tuner's resolved dtype
    the moment its engine builds: the checkpoint manifest must record the
    numerics that actually ran, never the open "auto" request (a re-search
    after cache eviction could resolve differently on resume)."""
    from repro.serve.service import LifeService
    svc = LifeService(LifeConfig(executor="opt", n_iters=8,
                                 plan_cache_dir=str(tmp_path / "plans")),
                      ckpt_dir=str(tmp_path / "ck"), checkpoint_every=1,
                      slice_iters=2)
    jid = svc.submit(tiny_cohort[0], n_iters=8, tune="full",
                     compute_dtype="auto")
    svc.step()
    job = svc.scheduler.job(jid)
    assert job.compute_dtype in COMPUTE_DTYPES           # pinned, not "auto"
    from repro.checkpoint import manager as ckpt
    _, _, manifest = ckpt.load_latest(str(tmp_path / "ck"))
    assert manifest["jobs"][jid]["compute_dtype"] == job.compute_dtype


def test_service_resume_rejects_conflicting_compute_dtype(tmp_path,
                                                          tiny_cohort):
    """A checkpointed solve's numerics are part of its identity: resuming
    under a different compute_dtype is an error, not a silent override."""
    from repro.serve.service import LifeService
    ck = str(tmp_path / "ck")
    svc = LifeService(LifeConfig(executor="opt", n_iters=8,
                                 plan_cache_dir=""),
                      ckpt_dir=ck, checkpoint_every=1, slice_iters=2)
    jid = svc.submit(tiny_cohort[0], n_iters=8, compute_dtype="bf16")
    svc.step()
    svc.checkpoint()
    svc2 = LifeService(LifeConfig(executor="opt", n_iters=8,
                                  plan_cache_dir=""), ckpt_dir=ck)
    assert jid in svc2.resumable_jobs
    with pytest.raises(ValueError, match="compute_dtype"):
        svc2.submit(tiny_cohort[0], job_id=jid, compute_dtype="fp32")
    # omitted -> inherited from the checkpoint, resume proceeds
    svc2.submit(tiny_cohort[0], job_id=jid)
    assert svc2.scheduler.job(jid).compute_dtype == "bf16"


# ----------------------------------------------------------------------------
# tuner internals
# ----------------------------------------------------------------------------

def test_tuner_measures_within_budget(tmp_path, tiny_problem):
    cfg = _tuned_cfg(tmp_path, compute_dtype="auto")
    cfg = dataclasses.replace(cfg, tune_budget=4)
    eng = LifeEngine(tiny_problem, cfg)
    plan = eng.tune_plan
    assert plan.reason == "search"
    assert len(plan.measurements) <= 4
    assert plan.compute_dtype in COMPUTE_DTYPES
    assert plan.backend == backend_name()
    assert plan.n_devices == len(jax.devices())


def test_degenerate_search_space_persists_default_plan(tmp_path,
                                                       tiny_problem,
                                                       monkeypatch):
    """No tile axes + fixed dtype: nothing to measure, but the plan is
    persisted so tune="cached" rebuilds hit."""
    from repro.tune import search as tsearch
    monkeypatch.setattr(tsearch, "time_call",
                        lambda *a, **k: (_ for _ in ()).throw(
                            AssertionError("measured a 1-candidate space")))
    cfg = LifeConfig(executor="opt", n_iters=2, tune="full",
                     plan_cache_dir=str(tmp_path))
    eng = LifeEngine(tiny_problem, cfg)
    assert eng.tune_plan.reason == "default"
    eng2 = LifeEngine(tiny_problem, dataclasses.replace(cfg, tune="cached"))
    assert eng2.tune_plan.reason == "default"       # warm hit, not untuned


def test_measure_candidates_keeps_duplicate_labels():
    """Regression: two candidates stringifying to the same label used to
    silently overwrite each other in the measurements dict, so persisted
    TunePlans under-counted the search."""
    from repro.tune import search as tsearch
    costs_seen = iter([2.0, 1.0])
    with pytest.warns(UserWarning, match="duplicate search candidate"):
        best, costs = tsearch.measure_candidates(
            [dict(row_tile=8), dict(row_tile=8)],
            lambda c: next(costs_seen))
    assert best == 1                          # the cheaper repeat still wins
    assert len(costs) == 2                    # both measurements audited
    assert set(costs.values()) == {2.0, 1.0}
