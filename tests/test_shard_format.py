"""ShardPhi partitioned layout: round-trips, partition invariants, inert
padding (DESIGN.md §9).

Property tests run through the hypothesis stub when the real package is
missing (tests/_hypothesis_stub.py), so they execute everywhere.  The
pure-numpy references over the stacked cell arrays are what lets multi-cell
layouts (R*C > 1) be exercised in a single-device test process — the
shard_map executors themselves are covered by test_conformance.py.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.inspector import run_lengths
from repro.core.std import PhiTensor
from repro.formats import FORMATS, canonical_triples
from repro.formats.shard import (CELL_FORMATS, ShardPhi, dsc_reference,
                                 partition_cuts, wc_reference)


@st.composite
def small_phi(draw):
    nc = draw(st.integers(1, 400))
    nv = draw(st.integers(1, 40))
    nf = draw(st.integers(1, 24))
    na = draw(st.integers(1, 8))
    skewed = draw(st.booleans())
    seed = draw(st.integers(0, 2**31 - 1))
    r = np.random.default_rng(seed)
    voxels = r.integers(0, nv, nc)
    fibers = r.integers(0, nf, nc)
    if skewed:
        # concentrate most coefficients on one id per mode — the regime
        # where an equal-nnz cut can land at coefficient offset 0 and the
        # snapping/monotonicity corner cases live
        voxels[: (6 * nc) // 10] = int(r.integers(0, nv))
        fibers[: (6 * nc) // 10] = int(r.integers(0, nf))
    return PhiTensor(
        atoms=jnp.asarray(r.integers(0, na, nc), jnp.int32),
        voxels=jnp.asarray(voxels, jnp.int32),
        fibers=jnp.asarray(fibers, jnp.int32),
        values=jnp.asarray(r.normal(size=nc).astype(np.float32)),
        n_atoms=na, n_voxels=nv, n_fibers=nf)


def _assert_same_multiset(a: PhiTensor, b: PhiTensor):
    for x, y in zip(canonical_triples(a), canonical_triples(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ----------------------------------------------------------------------------
# round-trip: encode/decode preserves the coefficient multiset exactly
# ----------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(small_phi(), st.sampled_from(["dsc", "wc"]),
       st.sampled_from(CELL_FORMATS), st.integers(1, 4), st.integers(1, 4))
def test_shard_roundtrip_exact(phi, op, cell_format, R, C):
    sp = ShardPhi.encode(phi, op=op, cell_format=cell_format, R=R, C=C,
                         row_tile=4, slot_tile=8)
    assert sp.n_coeffs == phi.n_coeffs
    _assert_same_multiset(phi, sp.decode())


def test_shard_is_not_a_leaf_format():
    """ShardPhi satisfies the PhiFormat contract but stays out of the
    selectable FORMATS registry — the registry citizens are the executors
    that consume it (shard / shard-sell)."""
    from repro.core.registry import REGISTRY
    assert "shard" not in FORMATS
    assert REGISTRY.consumes("shard") == "coo"
    assert REGISTRY.consumes("shard-sell") == "sell"
    assert REGISTRY.mesh_executor_for("coo") == "shard"
    assert REGISTRY.mesh_executor_for("sell") == "shard-sell"
    assert REGISTRY.mesh_executor_for("alto") is None


def test_encode_rejects_unknown_cell_format(tiny_problem):
    with pytest.raises(ValueError, match="cell format"):
        ShardPhi.encode(tiny_problem.phi, cell_format="csr")
    with pytest.raises(ValueError, match="positive"):
        partition_cuts(tiny_problem.phi, 0, 2)


def test_mesh_request_is_never_silently_dropped(tiny_problem):
    """A multi-cell mesh request either runs a sharded executor or raises —
    it must not fall back to a single-device solve (ISSUE 4 review fix)."""
    from repro.core.life import LifeConfig, LifeEngine
    # format="alto" has no sharded path -> refused outright
    with pytest.raises(ValueError, match="mesh executor"):
        LifeEngine(tiny_problem, LifeConfig(
            executor="opt", format="alto", shard_rows=2, shard_cols=1,
            plan_cache_dir=""))
    # default format="coo" with a single-device executor routes to `shard`;
    # on a host without enough devices that surfaces as a loud error
    # instead of a silent single-device run
    import jax
    n = len(jax.devices())
    cfg = LifeConfig(executor="opt", shard_rows=n + 1, shard_cols=1,
                     plan_cache_dir="")
    with pytest.raises(ValueError, match="devices"):
        LifeEngine(tiny_problem, cfg)
    # with enough devices the mesh request lands on the sharded executor
    ok = LifeEngine(tiny_problem, LifeConfig(
        executor="opt", shard_rows=1, shard_cols=1, format="coo",
        plan_cache_dir=""))
    assert ok.executor.name == "opt"    # 1x1 mesh request = no mesh request


# ----------------------------------------------------------------------------
# partition invariants: disjoint, covering, equal-nnz within sub-vector
# tolerance, snapped to id boundaries
# ----------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(small_phi(), st.integers(1, 6), st.integers(1, 6))
def test_partition_cuts_invariants(phi, R, C):
    plan = partition_cuts(phi, R, C)
    voxels = np.asarray(phi.voxels, np.int64)
    fibers = np.asarray(phi.fibers, np.int64)
    for cuts, n_ids, ids, k in ((plan.voxel_cuts, phi.n_voxels, voxels, R),
                                (plan.fiber_cuts, phi.n_fibers, fibers, C)):
        # id-space ranges are monotone and cover [0, n_ids) exactly —
        # disjointness and coverage of the cells follow
        assert cuts[0] == 0 and cuts[-1] == n_ids
        assert (np.diff(cuts) >= 0).all()
        # equal-nnz within sub-vector tolerance: no range exceeds the ideal
        # share by more than the largest run of one id (Figure 5b snapping)
        counts = np.asarray([np.sum((ids >= cuts[i]) & (ids < cuts[i + 1]))
                             for i in range(k)])
        assert counts.sum() == phi.n_coeffs
        largest_run = int(run_lengths(ids).max()) if ids.size else 0
        assert (counts <= ids.size / k + largest_run).all()
    # the (R x C) cells partition the coefficient set
    sp = ShardPhi.encode(phi, op="dsc", cell_format="coo", plan=plan)
    assert int(sp.cell_nnz.sum()) == phi.n_coeffs


def test_id_cuts_monotone_on_dominant_first_id():
    """Regression: an interior shard_boundaries cut at coefficient offset 0
    (the smallest id owns >= its shard's whole nnz share) must map to an
    empty leading range, not to a non-monotone n_ids boundary that sends
    later ids' contributions to never-written padded rows."""
    from repro.formats.shard import _id_cuts
    ids = np.sort(np.asarray([0] * 10 + [1, 2, 3], np.int64))
    cuts = _id_cuts(ids, 4, 4)
    assert (np.diff(cuts) >= 0).all(), cuts
    assert cuts[0] == 0 and cuts[-1] == 4
    # and the full sharded SpMV stays correct under that skew
    r = np.random.default_rng(0)
    phi = PhiTensor(
        atoms=jnp.asarray(r.integers(0, 4, 13), jnp.int32),
        voxels=jnp.asarray(ids, jnp.int32),
        fibers=jnp.asarray(r.integers(0, 5, 13), jnp.int32),
        values=jnp.asarray(r.normal(size=13).astype(np.float32)),
        n_atoms=4, n_voxels=4, n_fibers=5)
    d = r.normal(size=(4, 6)).astype(np.float32)
    w = r.uniform(0, 1, 5).astype(np.float32)
    from repro.core.spmv import dsc_naive
    want = np.asarray(dsc_naive(phi, jnp.asarray(d), jnp.asarray(w)))
    sp = ShardPhi.encode(phi, op="dsc", cell_format="coo", R=4, C=2)
    np.testing.assert_allclose(dsc_reference(sp, d, w), want,
                               rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------------------------
# inert padding: value-0 slots never change DSC/WC results
# ----------------------------------------------------------------------------

def _inflate_coo(sp: ShardPhi, extra: int) -> ShardPhi:
    """Append `extra` all-zero padding slots to every cell."""
    pad = [(0, 0), (0, 0), (0, extra)]
    return dataclasses.replace(
        sp, arrays={k: np.pad(v, pad) for k, v in sp.arrays.items()})


def _inflate_sell(sp: ShardPhi) -> ShardPhi:
    """Grow every cell by one slot chunk and one row block of zeros."""
    arrays = dict(sp.arrays)
    pad = [(0, 0), (0, 0), (0, sp.row_tile), (0, sp.slot_tile)]
    for k in ("atoms", "others", "values"):
        arrays[k] = np.pad(arrays[k], pad)
    return dataclasses.replace(sp, arrays=arrays)


@settings(max_examples=15, deadline=None)
@given(small_phi(), st.sampled_from(CELL_FORMATS), st.integers(1, 3),
       st.integers(1, 3), st.integers(0, 2**31 - 1))
def test_padded_cells_are_inert(phi, cell_format, R, C, seed):
    """Inflating the per-cell padding (pure value-0 slots) leaves both ops
    bit-identical — the §4.2.1.2 sync-free invariant the sharded layouts
    rely on."""
    r = np.random.default_rng(seed)
    d = r.normal(size=(phi.n_atoms, 6)).astype(np.float32)
    w = r.uniform(0, 1, phi.n_fibers).astype(np.float32)
    y = r.normal(size=(phi.n_voxels, 6)).astype(np.float32)

    sp_dsc = ShardPhi.encode(phi, op="dsc", cell_format=cell_format, R=R,
                             C=C, row_tile=4, slot_tile=8)
    sp_wc = ShardPhi.encode(phi, op="wc", cell_format=cell_format, R=R,
                            C=C, row_tile=4, slot_tile=8)
    inflate = (_inflate_sell if cell_format == "sell"
               else lambda s: _inflate_coo(s, 7))
    np.testing.assert_array_equal(dsc_reference(sp_dsc, d, w),
                                  dsc_reference(inflate(sp_dsc), d, w))
    np.testing.assert_array_equal(wc_reference(sp_wc, d, y),
                                  wc_reference(inflate(sp_wc), d, y))


@settings(max_examples=10, deadline=None)
@given(small_phi(), st.integers(1, 3), st.integers(1, 3),
       st.integers(1, 50), st.integers(0, 2**31 - 1))
def test_zero_value_coefficients_are_inert(phi, R, C, n_zero, seed):
    """Appending explicit value-0 coefficients (anywhere in the tensor)
    never changes either op — they may shift the equal-nnz boundaries, so
    the comparison runs in float64 where the re-partitioned summation
    order is exact to ~1e-12."""
    r = np.random.default_rng(seed)
    aug = PhiTensor(
        atoms=jnp.concatenate([phi.atoms, jnp.asarray(
            r.integers(0, phi.n_atoms, n_zero), jnp.int32)]),
        voxels=jnp.concatenate([phi.voxels, jnp.asarray(
            r.integers(0, phi.n_voxels, n_zero), jnp.int32)]),
        fibers=jnp.concatenate([phi.fibers, jnp.asarray(
            r.integers(0, phi.n_fibers, n_zero), jnp.int32)]),
        values=jnp.concatenate([phi.values,
                                jnp.zeros((n_zero,), phi.values.dtype)]),
        n_atoms=phi.n_atoms, n_voxels=phi.n_voxels, n_fibers=phi.n_fibers)
    d = r.normal(size=(phi.n_atoms, 6)).astype(np.float64)
    w = r.uniform(0, 1, phi.n_fibers).astype(np.float64)
    y = r.normal(size=(phi.n_voxels, 6)).astype(np.float64)
    for cell_format in CELL_FORMATS:
        a = ShardPhi.encode(phi, op="dsc", cell_format=cell_format, R=R,
                            C=C, row_tile=4, slot_tile=8)
        b = ShardPhi.encode(aug, op="dsc", cell_format=cell_format, R=R,
                            C=C, row_tile=4, slot_tile=8)
        np.testing.assert_allclose(dsc_reference(a, d, w),
                                   dsc_reference(b, d, w),
                                   rtol=1e-10, atol=1e-10)
        aw = ShardPhi.encode(phi, op="wc", cell_format=cell_format, R=R,
                             C=C, row_tile=4, slot_tile=8)
        bw = ShardPhi.encode(aug, op="wc", cell_format=cell_format, R=R,
                             C=C, row_tile=4, slot_tile=8)
        np.testing.assert_allclose(wc_reference(aw, d, y),
                                   wc_reference(bw, d, y),
                                   rtol=1e-10, atol=1e-10)


# ----------------------------------------------------------------------------
# accounting
# ----------------------------------------------------------------------------

def test_padding_overhead_and_nbytes(tiny_problem):
    phi = tiny_problem.phi
    for cell_format in CELL_FORMATS:
        sp = ShardPhi.encode(phi, op="dsc", cell_format=cell_format, R=2,
                             C=2, slot_tile=8)
        assert sp.padding_overhead >= 0.0
        assert sp.nbytes > 0
        allocated = sp.arrays["values"].size
        assert allocated == pytest.approx(
            (1.0 + sp.padding_overhead) * sp.n_coeffs, rel=1e-6)


def test_references_match_dense_oracle(tiny_problem, tiny_dense, rng):
    """Multi-cell reference SpMVs agree with the dense oracle (the same
    contract the shard_map executors are held to in test_conformance)."""
    p = tiny_problem
    m = np.asarray(tiny_dense, np.float64)
    n_theta = p.dictionary.shape[1]
    w = rng.uniform(0, 1, p.phi.n_fibers).astype(np.float32)
    y = rng.normal(size=(p.phi.n_voxels, n_theta)).astype(np.float32)
    for cell_format in CELL_FORMATS:
        sp = ShardPhi.encode(p.phi, op="dsc", cell_format=cell_format,
                             R=3, C=2, slot_tile=8)
        got = dsc_reference(sp, p.dictionary, w).astype(np.float64)
        np.testing.assert_allclose(got.reshape(-1),
                                   m @ w.astype(np.float64),
                                   rtol=2e-4, atol=2e-5)
        spw = ShardPhi.encode(p.phi, op="wc", cell_format=cell_format,
                              R=3, C=2, slot_tile=8)
        gotw = wc_reference(spw, p.dictionary, y).astype(np.float64)
        np.testing.assert_allclose(gotw, m.T @ y.astype(np.float64).reshape(-1),
                                   rtol=2e-4, atol=2e-5)
