"""Deterministic fallback for the slice of the hypothesis API this suite uses.

Installed into ``sys.modules`` by conftest.py ONLY when the real hypothesis
package is missing (the declared dev dependency in pyproject.toml is the
intended path; this keeps the suite collectable on minimal containers).

Semantics: ``@given(...)`` runs the test body ``max_examples`` times with
examples drawn from a per-test seeded generator — deterministic across runs
(no shrinking, no failure database; plain exhaustive-ish sampling).
"""
from __future__ import annotations

import functools
import zlib

import numpy as np


class SearchStrategy:
    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def example_from(self, rng: np.random.Generator):
        return self._draw_fn(rng)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(
        lambda rng: elements[int(rng.integers(0, len(elements)))])


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def floats(min_value: float, max_value: float) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def composite(fn):
    """hypothesis.strategies.composite: fn(draw, *args) -> value."""
    @functools.wraps(fn)
    def builder(*args, **kwargs):
        def draw_value(rng):
            return fn(lambda strat: strat.example_from(rng), *args, **kwargs)
        return SearchStrategy(draw_value)
    return builder


DEFAULT_MAX_EXAMPLES = 10


def given(*strategies):
    def deco(test):
        # NB: no functools.wraps — pytest must see a zero-parameter
        # signature, or it would try to resolve the drawn arguments as
        # fixtures (real hypothesis rewrites the signature the same way).
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(test.__module__.encode()
                              + test.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                test(*[s.example_from(rng) for s in strategies])
        wrapper.__name__ = test.__name__
        wrapper.__qualname__ = test.__qualname__
        wrapper.__module__ = test.__module__
        wrapper.__doc__ = test.__doc__
        wrapper._stub_given = True
        return wrapper
    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_ignored):
    """Accepts and ignores everything but max_examples (deadline etc.)."""
    def deco(test):
        test._stub_max_examples = max_examples
        return test
    return deco


def install(sys_modules) -> None:
    """Register this module as `hypothesis` (+ `.strategies`)."""
    import types
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "booleans", "floats",
                 "composite", "SearchStrategy"):
        setattr(st, name, globals()[name])
    hyp.strategies = st
    sys_modules["hypothesis"] = hyp
    sys_modules["hypothesis.strategies"] = st
