"""Data pipelines: determinism, resumability, dMRI generator statistics."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.data.dmri import TRACTOGRAPHY, synth_connectome
from repro.data.tokens import DataConfig, synth_batch_for, synth_tokens


def test_tokens_deterministic_and_resumable():
    cfg = DataConfig(seed=3, seq_len=64, global_batch=4)
    a = synth_tokens(cfg, 1000, step=5)
    b = synth_tokens(cfg, 1000, step=5)     # restart at the same step
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = synth_tokens(cfg, 1000, step=6)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_tokens_host_slicing_matches_global():
    """A host materializing only its batch slice sees the global batch rows."""
    cfg = DataConfig(seed=0, seq_len=32, global_batch=8)
    full = synth_tokens(cfg, 500, step=2)
    part = synth_tokens(cfg, 500, step=2, batch_slice=slice(2, 5))
    np.testing.assert_array_equal(np.asarray(full["tokens"])[2:5],
                                  np.asarray(part["tokens"]))


def test_labels_are_shifted_tokens():
    cfg = DataConfig(seed=1, seq_len=16, global_batch=2)
    b = synth_tokens(cfg, 100, step=0)
    np.testing.assert_array_equal(np.asarray(b["tokens"])[:, 1:],
                                  np.asarray(b["labels"])[:, :-1])


@pytest.mark.parametrize("family_arch", ["musicgen-large", "qwen2-vl-7b",
                                         "deepseek-7b"])
def test_family_batches_match_specs(family_arch):
    cfg = reduced(get_config(family_arch))
    data = DataConfig(seed=0, seq_len=32, global_batch=2)
    batch = synth_batch_for(cfg, data, step=0)
    if cfg.family == "audio":
        assert batch["frame_embeds"].shape == (2, 32, cfg.d_model)
        assert batch["codes"].shape == (2, 32, cfg.n_codebooks)
    elif cfg.family == "vlm":
        assert batch["labels"].shape == (2, 32)
        assert batch["positions"].shape == (3, 2, 32)
    else:
        assert batch["tokens"].shape == (2, 32)


@pytest.mark.parametrize("algo", sorted(TRACTOGRAPHY))
def test_dmri_generator_per_algorithm(algo):
    p = synth_connectome(n_fibers=32, n_theta=8, n_atoms=16,
                         grid=(8, 8, 8), algorithm=algo, seed=2)
    p.phi.validate()
    assert p.phi.n_coeffs > 0
    assert p.stats["nnz_per_fiber"] > 1
    # dictionary rows are demeaned (ENCODE convention)
    np.testing.assert_allclose(
        np.asarray(p.dictionary).mean(axis=1), 0.0, atol=1e-5)


def test_dmri_deterministic():
    a = synth_connectome(n_fibers=16, n_theta=8, n_atoms=8, grid=(6, 6, 6),
                         seed=9)
    b = synth_connectome(n_fibers=16, n_theta=8, n_atoms=8, grid=(6, 6, 6),
                         seed=9)
    np.testing.assert_array_equal(np.asarray(a.phi.values),
                                  np.asarray(b.phi.values))
