"""Serving subsystem: bucketing, continuous batching, fairness, resume."""
import dataclasses

import numpy as np
import pytest

from repro.core.batched import BatchedLifeEngine
from repro.core.life import LifeConfig, LifeEngine
from repro.serve import (BATCHABLE_FORMATS, JobFailedError, LifeService,
                         Scheduler, dataset_key)
from repro.serve.scheduler import Job


def _cfg(**kw):
    kw.setdefault("executor", "opt")
    kw.setdefault("n_iters", 12)
    kw.setdefault("plan_cache_dir", "")
    return LifeConfig(**kw)


def _poison(problem):
    """Geometry-preserving corruption: a truncated signal keeps the bucket
    key (which has no ``b`` component) so the poisoned job shares its
    micro-batch with healthy same-acquisition tenants — and fails there."""
    return dataclasses.replace(problem, b=np.asarray(problem.b)[:-3])


# ----------------------------------------------------------------------------
# scheduler semantics
# ----------------------------------------------------------------------------

def test_batched_bucket_matches_direct_engine(tiny_cohort):
    """One bucket served in slices == one BatchedLifeEngine run, exactly."""
    svc = LifeService(_cfg(), slice_iters=5)
    ids = [svc.submit(p, n_iters=12, format="coo") for p in tiny_cohort]
    results = svc.run()
    W, _ = BatchedLifeEngine(tiny_cohort, _cfg()).run()
    for i, jid in enumerate(ids):
        w, losses = results[jid]
        np.testing.assert_array_equal(np.asarray(w), np.asarray(W[i]))
        assert losses.shape == (12,)


def test_sell_jobs_get_solo_buckets(tiny_problem):
    """SELL operands don't stack under vmap — jobs run solo but still match
    the LifeEngine result through the same stepped interface."""
    svc = LifeService(_cfg(), slice_iters=5)
    jid = svc.submit(tiny_problem, n_iters=12, format="sell")
    w, losses = svc.run()[jid]
    w_ref, l_ref = LifeEngine(tiny_problem,
                              _cfg(format="sell", n_iters=12)).run()
    np.testing.assert_array_equal(np.asarray(w), np.asarray(w_ref))
    np.testing.assert_array_equal(losses, l_ref)


def test_fcoo_jobs_get_solo_buckets(tiny_problem):
    """F-COO chunk/segment-map shapes are per-subject static — jobs run
    solo (like SELL) but still match the direct LifeEngine result."""
    svc = LifeService(_cfg(), slice_iters=5)
    jid = svc.submit(tiny_problem, n_iters=12, format="fcoo")
    w, losses = svc.run()[jid]
    w_ref, l_ref = LifeEngine(tiny_problem,
                              _cfg(format="fcoo", n_iters=12)).run()
    np.testing.assert_array_equal(np.asarray(w), np.asarray(w_ref))
    np.testing.assert_array_equal(losses, l_ref)


def test_continuous_batching_admits_late_arrival(tiny_cohort):
    """A job submitted mid-flight joins the bucket's next micro-batch, and
    neither the in-flight jobs' trajectories nor the newcomer's differ from
    their uninterrupted counterparts."""
    svc = LifeService(_cfg(), slice_iters=4)
    first = svc.submit(tiny_cohort[0], n_iters=12, format="coo")
    svc.step()                                      # first runs 4 iters alone
    late = svc.submit(tiny_cohort[1], n_iters=12, format="coo")
    results = svc.run()
    assert set(results) == {first, late}
    for jid, prob in ((first, tiny_cohort[0]), (late, tiny_cohort[1])):
        w_ref, l_ref = LifeEngine(prob, _cfg(n_iters=12)).run()
        w, losses = results[jid]
        np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(losses, l_ref, rtol=1e-3)


def test_priority_orders_buckets(tiny_cohort):
    """With everything else equal, the higher-priority tenant's bucket is
    served first (they must land in different buckets to contend — different
    formats here)."""
    sched = Scheduler(_cfg(), slice_iters=100)      # one slice finishes a job
    lo = Job(job_id="lo", problem=tiny_cohort[0], n_iters=8, priority=0,
             format="coo")
    hi = Job(job_id="hi", problem=tiny_cohort[1], n_iters=8, priority=5,
             format="sell")
    sched.submit(lo)
    sched.submit(hi)
    first = sched.tick()
    assert [j.job_id for j in first] == ["hi"]


def test_deadline_beats_priority(tiny_cohort):
    """EDF is the primary key: a deadline-bearing job preempts a
    higher-priority job with no deadline."""
    sched = Scheduler(_cfg(), slice_iters=100)
    sched.submit(Job(job_id="pri", problem=tiny_cohort[0], n_iters=8,
                     priority=9, format="coo"))
    sched.submit(Job(job_id="ddl", problem=tiny_cohort[1], n_iters=8,
                     priority=0, deadline=1.0, format="sell"))
    assert [j.job_id for j in sched.tick()] == ["ddl"]


def test_fair_time_slicing(tiny_cohort):
    """Two equal-priority buckets alternate slices (vtime fairness): neither
    finishes a long solve before the other has been served."""
    sched = Scheduler(_cfg(), slice_iters=4)
    sched.submit(Job(job_id="a", problem=tiny_cohort[0], n_iters=8,
                     format="coo"))
    sched.submit(Job(job_id="b", problem=tiny_cohort[1], n_iters=8,
                     format="sell"))
    sched.tick()
    a, b = sched.job("a"), sched.job("b")
    served_first = {a.done, b.done}
    assert served_first == {4, 0}
    sched.tick()
    assert (a.done, b.done) == (4, 4)               # the other bucket ran


def test_rejects_unknown_format_and_duplicate_ids(tiny_problem):
    sched = Scheduler(_cfg())
    with pytest.raises(ValueError, match="format"):
        sched.submit(Job(job_id="x", problem=tiny_problem, n_iters=4,
                         format="csr"))
    sched.submit(Job(job_id="x", problem=tiny_problem, n_iters=4,
                     format="coo"))
    with pytest.raises(ValueError, match="already"):
        sched.submit(Job(job_id="x", problem=tiny_problem, n_iters=4,
                         format="coo"))
    with pytest.raises(ValueError, match="/"):
        sched.submit(Job(job_id="a/b", problem=tiny_problem, n_iters=4,
                         format="coo"))


def test_batchable_formats_constant():
    assert set(BATCHABLE_FORMATS) == {"auto", "coo", "alto"}


def test_rejects_compaction_config():
    """Serving drives engines through the stepped API and would silently
    skip LifeEngine.run()'s compaction loop — refuse instead."""
    with pytest.raises(ValueError, match="compact"):
        Scheduler(_cfg(compact_every=10))


# ----------------------------------------------------------------------------
# failure isolation (DESIGN.md §13.3): one bad tenant fails alone
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["coo", "sell", "fcoo"])
def test_poisoned_tenant_fails_alone(fmt, tiny_cohort):
    """An executor exception condemns only the poisoned job: its status is
    ``failed`` with the exception retrievable, every other bucket stays
    servable, and run() still terminates."""
    svc = LifeService(_cfg(), slice_iters=5)
    svc.submit(tiny_cohort[0], job_id="good", n_iters=10, format=fmt)
    svc.submit(_poison(tiny_cohort[0]), job_id="bad", n_iters=10, format=fmt)
    svc.submit(tiny_cohort[1], job_id="other", n_iters=10, format="coo")
    results = svc.run()
    assert set(results) == {"good", "other"}
    for jid in ("good", "other"):
        _, losses = results[jid]
        assert losses.shape == (10,)
    assert svc.status("bad") == "failed"
    assert svc.failed_jobs == ("bad",)
    err = svc.error("bad")
    assert isinstance(err, Exception)
    with pytest.raises(JobFailedError) as ei:
        svc.result("bad")
    assert ei.value.error is err and ei.value.__cause__ is err


def test_quarantine_preserves_survivor_trajectory(tiny_cohort):
    """Bisection probes advance the healthy batch-mate through the same
    single-member engine class, so its solution is exactly what it would
    have been without the poisoned neighbour."""
    svc = LifeService(_cfg(), slice_iters=5)
    svc.submit(tiny_cohort[0], job_id="good", n_iters=12, format="coo")
    svc.submit(_poison(tiny_cohort[1]), job_id="bad", n_iters=12,
               format="coo")
    w, losses = svc.run()["good"]
    W, _ = BatchedLifeEngine([tiny_cohort[0]], _cfg()).run()
    np.testing.assert_array_equal(np.asarray(w), np.asarray(W[0]))
    assert losses.shape == (12,)
    assert svc.failed_jobs == ("bad",)


def test_transient_batch_failure_keeps_survivors(tiny_cohort, monkeypatch):
    """A fault that only bites the stacked batch (both members pass their
    solo probes) fails nobody: the survivors re-bucket and finish."""
    svc = LifeService(_cfg(), slice_iters=4)
    a = svc.submit(tiny_cohort[0], n_iters=8, format="coo")
    b = svc.submit(tiny_cohort[1], n_iters=8, format="coo")
    orig = BatchedLifeEngine.step
    tripped = []

    def flaky(self, states, k):
        if states.w.shape[0] > 1 and not tripped:
            tripped.append(True)
            raise RuntimeError("injected transient fault")
        return orig(self, states, k)

    monkeypatch.setattr(BatchedLifeEngine, "step", flaky)
    results = svc.run()
    assert tripped and set(results) == {a, b}
    assert svc.failed_jobs == ()
    for jid in (a, b):
        assert results[jid][1].shape == (8,)


def test_resume_bit_identical_with_poisoned_batchmate(tiny_cohort, tmp_path):
    """Kill-and-resume stays bit-identical when a failing tenant shared the
    bucket, and the failure (with its error) rides along in the manifest."""
    from repro.checkpoint import manager as CK

    cfg = _cfg(n_iters=24)
    ref = LifeService(cfg, slice_iters=5)
    ref.submit(tiny_cohort[0], job_id="good", n_iters=24, format="coo")
    ref.submit(_poison(tiny_cohort[1]), job_id="bad", n_iters=24,
               format="coo")
    w_ref, l_ref = ref.run()["good"]

    ck = str(tmp_path / "svc")
    svc = LifeService(cfg, ckpt_dir=ck, checkpoint_every=1, slice_iters=5)
    svc.submit(tiny_cohort[0], job_id="good", n_iters=24, format="coo")
    svc.submit(_poison(tiny_cohort[1]), job_id="bad", n_iters=24,
               format="coo")
    svc.step()
    svc.step()
    del svc                                         # the "kill"

    svc2 = LifeService(cfg, ckpt_dir=ck, checkpoint_every=1, slice_iters=5)
    assert "good" in svc2.resumable_jobs
    svc2.submit(tiny_cohort[0], job_id="good")
    w_res, l_res = svc2.run()["good"]
    np.testing.assert_array_equal(np.asarray(w_res), np.asarray(w_ref))
    np.testing.assert_array_equal(l_res, l_ref)
    _, _, manifest = CK.restore(ck)
    assert "error" in manifest["jobs"]["bad"]


def test_submitted_at_zero_boundary(tiny_problem):
    """0.0 is a legitimate monotonic stamp — the falsy-zero regression:
    an explicit 0.0 must survive submit, only None gets stamped."""
    sched = Scheduler(_cfg())
    j = sched.submit(Job(job_id="z", problem=tiny_problem, n_iters=4,
                         format="coo", submitted_at=0.0))
    assert j.submitted_at == 0.0
    j2 = sched.submit(Job(job_id="u", problem=tiny_problem, n_iters=4,
                          format="coo"))
    assert j2.submitted_at is not None and j2.submitted_at > 0.0


def test_latency_spans_service_incarnations(tiny_problem, tmp_path):
    """``serve.job.latency.seconds`` is end-to-end: the manifest's
    cumulative ``elapsed`` restores into ``Job.prior_elapsed`` and the
    observed latency covers every leg, not just the post-resume one."""
    from repro import obs
    from repro.checkpoint import manager as CK

    ck = str(tmp_path / "svc")
    svc = LifeService(_cfg(n_iters=24), ckpt_dir=ck, checkpoint_every=1,
                      slice_iters=5)
    svc.submit(tiny_problem, job_id="t", n_iters=24, format="coo")
    svc.step()
    svc.step()
    del svc
    _, _, manifest = CK.restore(ck)
    elapsed0 = manifest["jobs"]["t"]["elapsed"]
    assert elapsed0 > 0.0

    obs.enable()
    svc2 = LifeService(_cfg(n_iters=24), ckpt_dir=ck, checkpoint_every=1,
                       slice_iters=5)
    svc2.submit(tiny_problem, job_id="t")
    job = svc2.scheduler.job("t")
    assert job.prior_elapsed == pytest.approx(elapsed0)
    job.prior_elapsed = 100.0       # make the restored leg unmistakable
    svc2.run()
    h = obs.histogram("serve.job.latency.seconds")
    assert h.count == 1 and h.min >= 100.0
    # and the final manifest carries the cumulative time forward again
    _, _, m2 = CK.restore(ck)
    assert m2["jobs"]["t"]["elapsed"] >= 100.0


# ----------------------------------------------------------------------------
# resume-after-kill (the acceptance criterion: identical weights,
# coo + sell + fcoo)
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["coo", "sell", "fcoo"])
def test_interrupted_then_resumed_matches_uninterrupted(fmt, tiny_problem,
                                                        tmp_path):
    cfg = _cfg(n_iters=24)
    ref = LifeService(cfg, slice_iters=5)
    jid = ref.submit(tiny_problem, job_id="tenant", n_iters=24, format=fmt)
    w_ref, l_ref = ref.run()[jid]

    ck = str(tmp_path / "svc")
    svc = LifeService(cfg, ckpt_dir=ck, checkpoint_every=1, slice_iters=5)
    svc.submit(tiny_problem, job_id="tenant", n_iters=24, format=fmt)
    svc.step()
    svc.step()                                      # 10 of 24 iters, then die
    assert svc.scheduler.job("tenant").done == 10
    del svc                                         # the "kill"

    svc2 = LifeService(cfg, ckpt_dir=ck, checkpoint_every=1, slice_iters=5)
    assert svc2.resumable_jobs == ("tenant",)
    svc2.submit(tiny_problem, job_id="tenant", format=fmt)
    assert svc2.scheduler.job("tenant").done == 10  # adopted mid-flight
    w_res, l_res = svc2.run()["tenant"]

    np.testing.assert_allclose(np.asarray(w_res), np.asarray(w_ref),
                               rtol=0, atol=1e-6)
    np.testing.assert_array_equal(l_res, l_ref)     # bit-compatible in fact
    assert l_res.shape == (24,)


def test_resume_rejects_different_data(tiny_problem, tiny_cohort, tmp_path):
    """A checkpointed job id can only re-attach to byte-identical data."""
    ck = str(tmp_path / "svc")
    svc = LifeService(_cfg(), ckpt_dir=ck, checkpoint_every=1, slice_iters=4)
    svc.submit(tiny_problem, job_id="t", n_iters=12, format="coo")
    svc.step()
    del svc
    svc2 = LifeService(_cfg(), ckpt_dir=ck)
    with pytest.raises(ValueError, match="digest"):
        svc2.submit(tiny_cohort[0], job_id="t", format="coo")


def test_completed_job_reserves_instantly_after_restart(tiny_problem,
                                                        tmp_path):
    """A kill between a job finishing and the client reading the result
    loses nothing: the final state is in the snapshot, and resubmission
    re-serves it without re-running the solve."""
    ck = str(tmp_path / "svc")
    svc = LifeService(_cfg(), ckpt_dir=ck, checkpoint_every=1, slice_iters=4)
    svc.submit(tiny_problem, job_id="t", n_iters=12, format="coo")
    w_ref, l_ref = svc.run()["t"]
    del svc
    svc2 = LifeService(_cfg(), ckpt_dir=ck)
    assert svc2.resumable_jobs == ("t",)
    svc2.submit(tiny_problem, job_id="t", format="coo")
    assert svc2.scheduler.job("t").remaining == 0   # nothing left to run
    w, losses = svc2.run()["t"]
    np.testing.assert_array_equal(np.asarray(w), np.asarray(w_ref))
    np.testing.assert_array_equal(losses, l_ref)


def test_resume_honors_explicit_overrides(tiny_problem, tmp_path):
    """Explicitly passed n_iters/priority win over checkpointed values
    (extend a solve on resume); a conflicting explicit format is an error,
    and an omitted format restores the checkpointed one."""
    ck = str(tmp_path / "svc")
    svc = LifeService(_cfg(), ckpt_dir=ck, checkpoint_every=1, slice_iters=4)
    svc.submit(tiny_problem, job_id="t", n_iters=12, priority=3,
               format="coo")
    svc.step()
    del svc
    svc2 = LifeService(_cfg(), ckpt_dir=ck, checkpoint_every=1,
                       slice_iters=4)
    with pytest.raises(ValueError, match="format"):
        svc2.submit(tiny_problem, job_id="t", format="sell")
    svc2.submit(tiny_problem, job_id="t", n_iters=20)   # extend 12 -> 20
    job = svc2.scheduler.job("t")
    assert (job.n_iters, job.done) == (20, 4)
    assert job.priority == 3                            # restored
    assert job.format == "coo"                          # restored
    _, losses = svc2.run()["t"]
    assert losses.shape == (20,)


def test_dataset_key_is_content_addressed(tiny_problem, tiny_cohort):
    assert dataset_key(tiny_problem) == dataset_key(tiny_problem)
    assert dataset_key(tiny_problem) != dataset_key(tiny_cohort[0])


def test_checkpoint_roundtrip_includes_loss_history(tiny_problem, tmp_path):
    """The restored job's loss trace is the full history, not just the
    post-resume tail."""
    ck = str(tmp_path / "svc")
    svc = LifeService(_cfg(), ckpt_dir=ck, checkpoint_every=1, slice_iters=6)
    svc.submit(tiny_problem, job_id="t", n_iters=18, format="coo")
    svc.step()
    del svc
    svc2 = LifeService(_cfg(), ckpt_dir=ck, checkpoint_every=1,
                       slice_iters=6)
    svc2.submit(tiny_problem, job_id="t", format="coo")
    _, losses = svc2.run()["t"]
    assert losses.shape == (18,)


# ----------------------------------------------------------------------------
# mesh slices (DESIGN.md §9): sharded executors behind the same scheduler
# ----------------------------------------------------------------------------

def test_mesh_jobs_get_solo_buckets_and_match_shard_engine(tiny_problem):
    """A mesh job runs the sharded executor for its format in a solo bucket
    and matches the direct engine exactly; an identical non-mesh job keeps
    its own (batchable) bucket."""
    import dataclasses
    svc = LifeService(_cfg(), slice_iters=5)
    plain = svc.submit(tiny_problem, n_iters=12, format="coo")
    meshed = svc.submit(tiny_problem, n_iters=12, format="coo", mesh=(1, 1))
    assert len(svc.scheduler._buckets) == 0
    results = svc.run()
    w_ref, l_ref = LifeEngine(
        tiny_problem, dataclasses.replace(_cfg(), executor="shard",
                                          shard_rows=1, shard_cols=1)).run(12)
    w, losses = results[meshed]
    np.testing.assert_array_equal(np.asarray(w), np.asarray(w_ref))
    np.testing.assert_array_equal(losses, l_ref)
    # the plain job matched its own engine too (different executor path)
    w_plain, _ = results[plain]
    np.testing.assert_allclose(np.asarray(w_plain), np.asarray(w_ref),
                               rtol=1e-3, atol=1e-4)


def test_mesh_job_validation(tiny_problem):
    import jax
    sched = Scheduler(_cfg())
    with pytest.raises(ValueError, match="no mesh executor"):
        sched.submit(Job(job_id="a", problem=tiny_problem, n_iters=4,
                         format="alto", mesh=(1, 1)))
    # "auto" would make the topology depend on selection the intake path
    # never ran — mesh jobs must name their cell format explicitly
    with pytest.raises(ValueError, match="explicit cell format"):
        sched.submit(Job(job_id="a2", problem=tiny_problem, n_iters=4,
                         format="auto", mesh=(1, 1)))
    with pytest.raises(ValueError, match="devices"):
        sched.submit(Job(job_id="b", problem=tiny_problem, n_iters=4,
                         format="coo",
                         mesh=(len(jax.devices()) + 1, 2)))
    with pytest.raises(ValueError, match="positive"):
        sched.submit(Job(job_id="c", problem=tiny_problem, n_iters=4,
                         format="coo", mesh=(0, 1)))


@pytest.mark.parametrize("fmt", ["coo", "sell"])
def test_shard_job_interrupted_then_resumed_bit_compatible(fmt, tiny_problem,
                                                           tmp_path):
    """The ISSUE-4 satellite: kill-and-resume under the sharded executors
    (same mesh topology) is bit-compatible with the uninterrupted run."""
    cfg = _cfg(n_iters=24, slot_tile=16)
    ref = LifeService(cfg, slice_iters=5)
    jid = ref.submit(tiny_problem, job_id="tenant", n_iters=24, format=fmt,
                     mesh=(1, 1))
    w_ref, l_ref = ref.run()[jid]

    ck = str(tmp_path / "svc")
    svc = LifeService(cfg, ckpt_dir=ck, checkpoint_every=1, slice_iters=5)
    svc.submit(tiny_problem, job_id="tenant", n_iters=24, format=fmt,
               mesh=(1, 1))
    svc.step()
    svc.step()                                      # 10 of 24 iters, then die
    assert svc.scheduler.job("tenant").done == 10
    del svc                                         # the "kill"

    svc2 = LifeService(cfg, ckpt_dir=ck, checkpoint_every=1, slice_iters=5)
    assert svc2.resumable_jobs == ("tenant",)
    # a conflicting mesh topology is rejected, like a conflicting format
    with pytest.raises(ValueError, match="mesh"):
        svc2.submit(tiny_problem, job_id="tenant", mesh=(2, 1))
    svc2.submit(tiny_problem, job_id="tenant")      # mesh restored from ckpt
    job = svc2.scheduler.job("tenant")
    assert (job.done, job.mesh, job.format) == (10, (1, 1), fmt)
    w_res, l_res = svc2.run()["tenant"]

    np.testing.assert_array_equal(np.asarray(w_res), np.asarray(w_ref))
    np.testing.assert_array_equal(l_res, l_ref)     # bit-compatible
    assert l_res.shape == (24,)


def test_failed_resume_submit_keeps_state_recoverable(tiny_problem,
                                                      tmp_path, monkeypatch):
    """If scheduler.submit rejects a restored job (e.g. the checkpointed
    mesh doesn't fit this host's devices), the resumable entry must survive
    so the state can still be re-adopted — and later checkpoints must keep
    carrying it instead of rotating it out."""
    import jax
    ck = str(tmp_path / "svc")
    svc = LifeService(_cfg(n_iters=24), ckpt_dir=ck, checkpoint_every=1,
                      slice_iters=5)
    svc.submit(tiny_problem, job_id="tenant", n_iters=24, format="coo",
               mesh=(1, 1))
    svc.step()
    del svc

    svc2 = LifeService(_cfg(n_iters=24), ckpt_dir=ck, checkpoint_every=1,
                       slice_iters=5)
    assert svc2.resumable_jobs == ("tenant",)
    # simulate the checkpointed topology not fitting this host
    monkeypatch.setattr(jax, "devices", lambda *a, **k: [])
    with pytest.raises(ValueError, match="devices"):
        svc2.submit(tiny_problem, job_id="tenant")
    monkeypatch.undo()
    assert svc2.resumable_jobs == ("tenant",)       # state not consumed
    # other work checkpoints `keep` times; the unclaimed state must ride
    # along in every snapshot instead of falling out of retention
    svc2.submit(tiny_problem, job_id="other", n_iters=8, format="coo")
    svc2.run()
    del svc2
    svc3 = LifeService(_cfg(n_iters=24), ckpt_dir=ck, checkpoint_every=1,
                       slice_iters=5)
    assert "tenant" in svc3.resumable_jobs
    svc3.submit(tiny_problem, job_id="tenant")
    assert svc3.scheduler.job("tenant").done == 5   # adopted mid-flight
