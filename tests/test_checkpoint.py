"""Checkpointing: roundtrip, atomicity, retention, reshard-on-load."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as CK


def _tree(rng):
    return {"params": {"w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
                       "layers": {"scale": jnp.ones((3,), jnp.bfloat16)}},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path, rng):
    tree = _tree(rng)
    CK.save(str(tmp_path), 7, tree, meta={"arch": "test"})
    step, flat, manifest = CK.restore(str(tmp_path))
    assert step == 7 and manifest["arch"] == "test"
    rebuilt = CK.unflatten_like(jax.eval_shape(lambda: tree), flat)
    np.testing.assert_array_equal(rebuilt["params"]["w"],
                                  np.asarray(tree["params"]["w"]))
    assert rebuilt["params"]["layers"]["scale"].dtype == np.asarray(
        tree["params"]["layers"]["scale"]).dtype


def test_retention_and_latest(tmp_path, rng):
    tree = _tree(rng)
    for s in (1, 2, 3, 4, 5):
        CK.save(str(tmp_path), s, tree, keep=3)
    assert CK.all_steps(str(tmp_path)) == [3, 4, 5]
    assert CK.latest_step(str(tmp_path)) == 5


def test_no_tmp_dirs_left(tmp_path, rng):
    CK.save(str(tmp_path), 1, _tree(rng))
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_same_step_save_replaces_atomically(tmp_path, rng):
    """Saving a step that already exists replaces it (the service's final
    checkpoint can land on the same tick a periodic one just wrote) and
    leaves no .tmp/.old debris or phantom steps behind."""
    tree = _tree(rng)
    CK.save(str(tmp_path), 3, tree, meta={"gen": 1})
    CK.save(str(tmp_path), 3, tree, meta={"gen": 2})
    step, _, manifest = CK.restore(str(tmp_path))
    assert (step, manifest["gen"]) == (3, 2)
    assert CK.all_steps(str(tmp_path)) == [3]
    assert not [d for d in os.listdir(tmp_path)
                if d.endswith((".tmp", ".old"))]


def test_all_steps_ignores_swap_debris(tmp_path, rng):
    """A crash mid-replace can leave step_N.old behind; it must not be
    listed as a step (int() would choke on the suffix) and the next save
    must clear it."""
    CK.save(str(tmp_path), 2, _tree(rng))
    os.makedirs(tmp_path / "step_0000000002.old")
    os.makedirs(tmp_path / "step_0000000002.tmp")
    assert CK.all_steps(str(tmp_path)) == [2]
    assert CK.latest_step(str(tmp_path)) == 2
    CK.save(str(tmp_path), 2, _tree(rng))
    assert not [d for d in os.listdir(tmp_path)
                if d.endswith((".tmp", ".old"))]


def test_service_final_checkpoint_on_periodic_tick(tmp_path, tiny_problem):
    """Regression: LifeService.run() final-checkpoints at the same tick a
    checkpoint_every=1 periodic checkpoint just wrote — the double save of
    one step must replace, not crash."""
    from repro.core.life import LifeConfig
    from repro.serve import LifeService

    svc = LifeService(LifeConfig(executor="opt", n_iters=8,
                                 plan_cache_dir=""),
                      ckpt_dir=str(tmp_path / "svc"), checkpoint_every=1,
                      slice_iters=4)
    svc.submit(tiny_problem, job_id="t", n_iters=8, format="coo")
    results = svc.run()
    assert set(results) == {"t"}
    assert CK.latest_step(str(tmp_path / "svc")) is not None


def test_shape_mismatch_detected(tmp_path, rng):
    CK.save(str(tmp_path), 1, _tree(rng))
    _, flat, _ = CK.restore(str(tmp_path))
    bad_template = {"params": {"w": jax.ShapeDtypeStruct((5, 8), jnp.float32),
                               "layers": {"scale": jax.ShapeDtypeStruct(
                                   (3,), jnp.bfloat16)}},
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}
    with pytest.raises(ValueError, match="shape"):
        CK.unflatten_like(bad_template, flat)


def test_missing_key_detected(tmp_path, rng):
    CK.save(str(tmp_path), 1, _tree(rng))
    _, flat, _ = CK.restore(str(tmp_path))
    template = {"params": {"extra": jax.ShapeDtypeStruct((1,), jnp.float32)}}
    with pytest.raises(KeyError):
        CK.unflatten_like(template, flat)


def test_place_under_sharding(tmp_path, rng):
    """Reshard-on-load path (single device: identity sharding)."""
    tree = _tree(rng)
    CK.save(str(tmp_path), 2, tree)
    _, flat, _ = CK.restore(str(tmp_path))
    rebuilt = CK.unflatten_like(jax.eval_shape(lambda: tree), flat)
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), rebuilt)
    placed = CK.place(rebuilt, shardings)
    np.testing.assert_array_equal(np.asarray(placed["params"]["w"]),
                                  flat["params/w"])
