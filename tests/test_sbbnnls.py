"""SBBNNLS solver: convergence, invariants, reference agreement."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.life import LifeEngine, LifeConfig
from repro.core.sbbnnls import (projected_gradient, sbbnnls_init,
                                sbbnnls_run, sbbnnls_steps)
from repro.core.std import materialize_dense


def _numpy_sbbnnls(m, b, w0, n_iters):
    """Independent numpy reference of Algorithm 1."""
    w = w0.copy()
    losses = []
    for it in range(n_iters):
        y = m @ w - b
        g = m.T @ y
        gt = np.where((w > 0) | (g < 0), g, 0.0)
        v = m @ gt
        if it % 2 == 1:
            den = float(v @ v)
            alpha = float(gt @ gt) / den if den > 0 else 0.0
        else:
            vv = m.T @ v
            vv = np.where((w > 0) | (vv < 0), vv, 0.0)
            den = float(vv @ vv)
            alpha = float(v @ v) / den if den > 0 else 0.0
        w = np.maximum(w - alpha * gt, 0.0)
        losses.append(0.5 * float(y @ y))
    return w, losses


def test_matches_numpy_reference(tiny_problem, tiny_dense):
    p = tiny_problem
    m = np.asarray(tiny_dense, np.float64)
    b = np.asarray(p.b, np.float64).reshape(-1)
    w0 = np.ones(p.phi.n_fibers)
    w_ref, losses_ref = _numpy_sbbnnls(m, b, w0, 10)

    eng = LifeEngine(p, LifeConfig(executor="opt", n_iters=10))
    w_jax, losses_jax = eng.run()
    np.testing.assert_allclose(losses_jax, losses_ref, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(w_jax), w_ref, rtol=2e-2, atol=2e-3)


def test_loss_decreases_and_nonneg(tiny_problem):
    eng = LifeEngine(tiny_problem, LifeConfig(executor="opt", n_iters=40))
    w, losses = eng.run()
    assert losses[-1] < losses[0] * 0.05
    assert float(np.asarray(w).min()) >= 0.0          # NNLS invariant
    assert np.isfinite(losses).all()


def test_executors_agree(tiny_problem):
    results = {}
    for ex in ("naive", "opt", "opt-paper", "kernel"):
        cfg = LifeConfig(executor=ex, n_iters=8, c_tile=64, row_tile=8)
        w, losses = LifeEngine(tiny_problem, cfg).run()
        results[ex] = (np.asarray(w), losses)
    base_w, base_l = results["naive"]
    for ex, (w, l) in results.items():
        np.testing.assert_allclose(l, base_l, rtol=2e-3, err_msg=ex)
        np.testing.assert_allclose(w, base_w, rtol=2e-2, atol=2e-3,
                                   err_msg=ex)


def test_weight_compaction_keeps_solution(tiny_problem):
    ref = LifeEngine(tiny_problem, LifeConfig(executor="opt", n_iters=30))
    w_ref, _ = ref.run()
    eng = LifeEngine(tiny_problem,
                     LifeConfig(executor="opt", n_iters=30, compact_every=10))
    w, losses = eng.run()
    assert eng.phi.n_coeffs <= tiny_problem.phi.n_coeffs
    # pruning result is preserved (zero-weight fibers dropped were inert)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref),
                               rtol=5e-2, atol=5e-3)


def test_recovers_ground_truth_support(tiny_problem):
    eng = LifeEngine(tiny_problem, LifeConfig(executor="opt", n_iters=60))
    w, _ = eng.run()
    stats = eng.prune_stats(w)
    assert stats["recall"] > 0.9          # active fibers retained


def _tiny_ops():
    """Small dense NNLS instance as matvec/rmatvec closures (module-level so
    property tests don't depend on fixtures)."""
    r = np.random.default_rng(7)
    m = jnp.asarray(r.normal(size=(40, 24)), jnp.float32)
    w_true = jnp.asarray(np.maximum(r.normal(size=24), 0), jnp.float32)
    b = m @ w_true + 0.01 * jnp.asarray(r.normal(size=40), jnp.float32)
    return (lambda w: m @ w), (lambda y: m.T @ y), b


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 30))
def test_property_weights_nonneg_every_iteration(n_iters):
    """NNLS invariant holds at *every* intermediate state, not just the
    final one — checked by single-stepping through the stepped API."""
    mv, rmv, b = _tiny_ops()
    state = sbbnnls_init(jnp.ones((24,), jnp.float32))
    for i in range(n_iters):
        state, _ = sbbnnls_steps(mv, rmv, b, state, 1)
        assert float(state.w.min()) >= 0.0, f"negative weight at iter {i}"
        assert int(state.it) == i + 1


def test_loss_nonincreasing_over_bb_windows():
    """Barzilai-Borwein steps are not per-iteration monotone; the paper-level
    guarantee is decrease over step *windows* (one odd/even BB pair per
    window).  Windowed best-so-far loss must never increase."""
    mv, rmv, b = _tiny_ops()
    _, losses = sbbnnls_run(mv, rmv, b, jnp.ones((24,), jnp.float32), 40)
    window = 2                             # one odd + one even BB step
    mins = np.minimum.accumulate(np.asarray(losses))
    per_window = mins[window - 1::window]
    assert (np.diff(per_window) <= 1e-6 * np.abs(per_window[:-1])).all()
    assert per_window[-1] < per_window[0]


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_property_projected_gradient_idempotent(seed):
    """Projection onto the active set is idempotent: projecting an already
    projected gradient changes nothing (the frozen set is stable)."""
    r = np.random.default_rng(seed)
    w = jnp.asarray(np.maximum(r.normal(size=64), 0), jnp.float32)
    g = jnp.asarray(r.normal(size=64), jnp.float32)
    once = projected_gradient(w, g)
    twice = projected_gradient(w, once)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([1, 2, 3, 4, 6, 12]), st.integers(0, 1000))
def test_property_stepped_composition_exact(k, seed):
    """The stepped API composed k x (n/k) is *exactly* one n-iteration run:
    the iteration counter rides in the state, so BB parity and every
    intermediate value are identical (what makes serving-resume safe)."""
    n = 12
    mv, rmv, b = _tiny_ops()
    r = np.random.default_rng(seed)
    w0 = jnp.asarray(r.uniform(0.5, 1.5, 24), jnp.float32)

    _, losses_once = sbbnnls_run(mv, rmv, b, w0, n)
    state_once, _ = sbbnnls_run(mv, rmv, b, w0, n)

    state = sbbnnls_init(w0)
    chunks = []
    for _ in range(n // k):
        state, ls = sbbnnls_steps(mv, rmv, b, state, k)
        chunks.append(np.asarray(ls))
    np.testing.assert_array_equal(np.asarray(state.w),
                                  np.asarray(state_once.w))
    np.testing.assert_array_equal(np.concatenate(chunks),
                                  np.asarray(losses_once))
    assert int(state.it) == n


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_property_projected_gradient(seed):
    r = np.random.default_rng(seed)
    w = jnp.asarray(np.maximum(r.normal(size=50), 0), jnp.float32)
    g = jnp.asarray(r.normal(size=50), jnp.float32)
    gt = np.asarray(projected_gradient(w, g))
    w_np, g_np = np.asarray(w), np.asarray(g)
    # frozen exactly where w==0 and g>0
    frozen = (w_np == 0) & (g_np > 0)
    assert (gt[frozen] == 0).all()
    assert np.allclose(gt[~frozen], g_np[~frozen])
    # one projected step never leaves the nonneg orthant
    assert float(jnp.maximum(w - 0.1 * gt, 0.0).min()) >= 0
