"""SBBNNLS solver: convergence, invariants, reference agreement."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.life import LifeEngine, LifeConfig
from repro.core.sbbnnls import projected_gradient, sbbnnls_run
from repro.core.std import materialize_dense


def _numpy_sbbnnls(m, b, w0, n_iters):
    """Independent numpy reference of Algorithm 1."""
    w = w0.copy()
    losses = []
    for it in range(n_iters):
        y = m @ w - b
        g = m.T @ y
        gt = np.where((w > 0) | (g < 0), g, 0.0)
        v = m @ gt
        if it % 2 == 1:
            den = float(v @ v)
            alpha = float(gt @ gt) / den if den > 0 else 0.0
        else:
            vv = m.T @ v
            vv = np.where((w > 0) | (vv < 0), vv, 0.0)
            den = float(vv @ vv)
            alpha = float(v @ v) / den if den > 0 else 0.0
        w = np.maximum(w - alpha * gt, 0.0)
        losses.append(0.5 * float(y @ y))
    return w, losses


def test_matches_numpy_reference(tiny_problem, tiny_dense):
    p = tiny_problem
    m = np.asarray(tiny_dense, np.float64)
    b = np.asarray(p.b, np.float64).reshape(-1)
    w0 = np.ones(p.phi.n_fibers)
    w_ref, losses_ref = _numpy_sbbnnls(m, b, w0, 10)

    eng = LifeEngine(p, LifeConfig(executor="opt", n_iters=10))
    w_jax, losses_jax = eng.run()
    np.testing.assert_allclose(losses_jax, losses_ref, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(w_jax), w_ref, rtol=2e-2, atol=2e-3)


def test_loss_decreases_and_nonneg(tiny_problem):
    eng = LifeEngine(tiny_problem, LifeConfig(executor="opt", n_iters=40))
    w, losses = eng.run()
    assert losses[-1] < losses[0] * 0.05
    assert float(np.asarray(w).min()) >= 0.0          # NNLS invariant
    assert np.isfinite(losses).all()


def test_executors_agree(tiny_problem):
    results = {}
    for ex in ("naive", "opt", "opt-paper", "kernel"):
        cfg = LifeConfig(executor=ex, n_iters=8, c_tile=64, row_tile=8)
        w, losses = LifeEngine(tiny_problem, cfg).run()
        results[ex] = (np.asarray(w), losses)
    base_w, base_l = results["naive"]
    for ex, (w, l) in results.items():
        np.testing.assert_allclose(l, base_l, rtol=2e-3, err_msg=ex)
        np.testing.assert_allclose(w, base_w, rtol=2e-2, atol=2e-3,
                                   err_msg=ex)


def test_weight_compaction_keeps_solution(tiny_problem):
    ref = LifeEngine(tiny_problem, LifeConfig(executor="opt", n_iters=30))
    w_ref, _ = ref.run()
    eng = LifeEngine(tiny_problem,
                     LifeConfig(executor="opt", n_iters=30, compact_every=10))
    w, losses = eng.run()
    assert eng.phi.n_coeffs <= tiny_problem.phi.n_coeffs
    # pruning result is preserved (zero-weight fibers dropped were inert)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref),
                               rtol=5e-2, atol=5e-3)


def test_recovers_ground_truth_support(tiny_problem):
    eng = LifeEngine(tiny_problem, LifeConfig(executor="opt", n_iters=60))
    w, _ = eng.run()
    stats = eng.prune_stats(w)
    assert stats["recall"] > 0.9          # active fibers retained


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_property_projected_gradient(seed):
    r = np.random.default_rng(seed)
    w = jnp.asarray(np.maximum(r.normal(size=50), 0), jnp.float32)
    g = jnp.asarray(r.normal(size=50), jnp.float32)
    gt = np.asarray(projected_gradient(w, g))
    w_np, g_np = np.asarray(w), np.asarray(g)
    # frozen exactly where w==0 and g>0
    frozen = (w_np == 0) & (g_np > 0)
    assert (gt[frozen] == 0).all()
    assert np.allclose(gt[~frozen], g_np[~frozen])
    # one projected step never leaves the nonneg orthant
    assert float(jnp.maximum(w - 0.1 * gt, 0.0).min()) >= 0
