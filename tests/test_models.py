"""Per-arch smoke tests (reduced configs) + prefill/decode consistency.

Every assigned architecture instantiates a reduced same-family config and
runs one forward/train step on CPU asserting output shapes + no NaNs
(deliverable f).  Five representative families additionally verify
prefill + step-by-step decode == full forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, reduced, input_specs, SHAPES
from repro.models import transformer as T
from repro.launch import steps as ST
from repro.optim.adamw import OptConfig

LM_ARCHS = [a for a in ARCH_IDS if a != "life-stn96"]


def _batch(cfg, rng, B=2, S=32):
    if cfg.family == "audio":
        return dict(
            frame_embeds=jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                     jnp.float32),
            codes=jnp.asarray(rng.integers(0, cfg.vocab_size,
                                           (B, S, cfg.n_codebooks)), jnp.int32))
    if cfg.family == "vlm":
        vt = cfg.vision_tokens
        return dict(
            tokens=jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - vt)),
                               jnp.int32),
            image_embeds=jnp.asarray(rng.normal(size=(B, vt, cfg.d_model)),
                                     jnp.float32),
            positions=jnp.asarray(
                np.broadcast_to(np.arange(S), (3, B, S)).copy(), jnp.int32),
            labels=jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32))
    return dict(tokens=jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
                labels=jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = reduced(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits, aux = T.forward_train(cfg, params, batch)
    B, S = 2, 32
    if cfg.family == "audio":
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one full train step (fwd + bwd + optimizer)
    opt = OptConfig(lr=1e-3)
    step = ST.make_train_step(cfg, opt)
    params2, opt_state, metrics = step(
        params, ST.init_all(cfg, opt, jax.random.PRNGKey(0))[1], batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_full_config_registered_and_consistent(arch):
    cfg = get_config(arch)
    assert cfg.param_count() > 1e9          # full config is full-size
    assert cfg.active_param_count() <= cfg.param_count()
    for shape in SHAPES:
        specs = input_specs(cfg, shape)
        assert isinstance(specs, dict) and specs
        if not cfg.supports(shape):
            assert not cfg.sub_quadratic


@pytest.mark.parametrize("arch", ["deepseek-7b", "granite-34b", "mamba2-2.7b",
                                  "zamba2-1.2b", "phi3.5-moe-42b-a6.6b",
                                  "musicgen-large", "qwen2-vl-7b"])
def test_prefill_decode_matches_forward(arch, rng):
    cfg = reduced(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S, S_pre = 2, 12, 8
    if cfg.family == "vlm":
        cfg = dataclasses.replace(cfg, vision_tokens=4)
    batch = _batch(cfg, rng, B=B, S=S)
    full_logits, _ = T.forward_train(cfg, params, batch)

    pre = {k: v for k, v in batch.items() if k not in ("labels", "codes")}
    if cfg.family == "audio":
        pre["frame_embeds"] = batch["frame_embeds"][:, :S_pre]
    elif cfg.family == "vlm":
        pre["tokens"] = batch["tokens"][:, : S_pre - cfg.vision_tokens]
        pre["positions"] = batch["positions"][:, :, :S_pre]
    else:
        pre["tokens"] = batch["tokens"][:, :S_pre]
    logits_pre, cache = T.prefill(cfg, params, pre)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1], np.float32),
        np.asarray(full_logits[:, S_pre - 1], np.float32),
        rtol=5e-3, atol=5e-3)

    for kn in ("k", "v"):
        if kn in cache:
            kv = cache[kn]
            cache[kn] = jnp.pad(
                kv, ((0, 0), (0, 0), (0, S - kv.shape[2]), (0, 0), (0, 0)))
    idx = jnp.asarray(S_pre, jnp.int32)
    for t in range(S_pre, S):
        db = dict(cache=cache, cache_index=idx)
        if cfg.family == "audio":
            db["frame_embeds"] = batch["frame_embeds"][:, t:t + 1]
        elif cfg.family == "vlm":
            # tokens array excludes the vision_tokens prefix
            tv = t - cfg.vision_tokens
            db["tokens"] = batch["tokens"][:, tv:tv + 1]
            db["positions"] = batch["positions"][:, :, t:t + 1]
        else:
            db["tokens"] = batch["tokens"][:, t:t + 1]
        logits, cache = T.decode_step(cfg, params, db)
        cache.pop("index")
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=2e-2, atol=2e-2)
        idx = idx + 1
